PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast lint bench-throughput bench-step bench-engine bench-recall bench-recall-full bench-walk bench-sanitize bench-attr bench-trace bench-check

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

test-fast:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q -m quick

lint:
	PYTHONPATH=$(PYTHONPATH) python -m repro.lint

bench-throughput:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_throughput.py --quick

bench-step:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_throughput.py --step

bench-engine:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_throughput.py --engine

bench-recall:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_recall.py --quick

# adds the 10M-item arm (device-resident int8 index, host re-rank) + more reps
bench-recall-full:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_recall.py --full

bench-walk:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_throughput.py --walk --full

bench-sanitize:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_throughput.py --sanitize

bench-attr:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_throughput.py --attribution

bench-trace:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/bench_throughput.py --telemetry

# perf-regression gate: fresh quick arms vs the committed BENCH JSONs
# (direction-aware tolerance bands; exit 1 on non-baselined regressions)
bench-check:
	PYTHONPATH=$(PYTHONPATH) python benchmarks/regression.py
