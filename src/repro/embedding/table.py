"""Sharded embedding table — the TPU-native "parameter server" (§3.6).

The paper's parameter server is a key-value store of sparse embeddings:
workers *pull* rows at step start and *push* gradients for asynchronous
updates. Two SPMD equivalents coexist here:

- **Sharded pull/push**: the table's vocab axis is partitioned across the
  ``model`` mesh axis. ``ps_lookup`` under ``shard_map`` is the pull (masked
  local take + ``psum``), and its autodiff transpose is the push (scatter-add
  into the owning shard). No code needed — JAX differentiates ``ps_lookup``.
- **Gather→step→scatter** (the training hot path): per batch, the trainer
  deduplicates the touched ids host-side (``unique_pad_ids`` — PAD-padded in
  front to a power-of-two bucket so jit shapes stay stable), remaps the
  batch's ids onto rows of the gathered sub-table (``remap_ids``), pulls only
  those rows (``gather_rows``), differentiates w.r.t. the sub-table, and
  pushes the row-wise-AdaGrad-updated rows back with ``scatter_rows`` under
  buffer donation. Every step is O(unique ids), never O(num_nodes) — the
  faithful port of the PS's sparse pull/push (see
  ``embedding/optimizer.py`` for the update rule and
  ``train/trainer.py`` for the jitted step).

Lazy initialization is replaced by pre-allocated sharded tables (TPU memory
is statically planned); an optional ``init_mask`` preserves the "row never
seen" semantics for cold-start experiments.

Side information (§3.5): configurable sparse slots, each with multiple
values per node (texts/tags), embedded and **summed** with the ID embedding,
exactly as the paper trains side info. Slot tables participate in the same
gather→step→scatter contract: the unique slot-value ids of a batch are
bucketed and remapped exactly like node ids.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.ragged import ragged_row_offsets


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    name: str
    vocab_size: int
    max_values: int  # fixed-width padding of the ragged slot


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    num_nodes: int
    dim: int
    slots: Tuple[SlotSpec, ...] = ()
    dtype: str = "float32"
    pad_id: int = -1


def init_params(key: jax.Array, cfg: EmbeddingConfig) -> Dict[str, jnp.ndarray]:
    """Node-ID table plus one table per side-info slot."""
    keys = jax.random.split(key, 1 + len(cfg.slots))
    scale = 1.0 / np.sqrt(cfg.dim)
    params = {
        "node": jax.random.normal(keys[0], (cfg.num_nodes, cfg.dim), cfg.dtype) * scale
    }
    for k, slot in zip(keys[1:], cfg.slots):
        params[f"slot:{slot.name}"] = (
            jax.random.normal(k, (slot.vocab_size, cfg.dim), cfg.dtype) * scale
        )
    return params


def abstract_params(cfg: EmbeddingConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {"node": jax.ShapeDtypeStruct((cfg.num_nodes, cfg.dim), cfg.dtype)}
    for slot in cfg.slots:
        out[f"slot:{slot.name}"] = jax.ShapeDtypeStruct(
            (slot.vocab_size, cfg.dim), cfg.dtype
        )
    return out


def param_specs(cfg: EmbeddingConfig, model_axis: str = "model") -> Dict[str, P]:
    """PS sharding: vocab rows over the model axis, dim replicated."""
    specs = {"node": P(model_axis, None)}
    for slot in cfg.slots:
        specs[f"slot:{slot.name}"] = P(model_axis, None)
    return specs


# ----------------------------------------------------------------- lookups
def lookup(table: jnp.ndarray, ids: jnp.ndarray, pad_id: int = -1) -> jnp.ndarray:
    """Plain masked gather (single-device / auto-sharded path).

    PAD ids return zero rows. Under pjit with a row-sharded table, XLA lowers
    this to the same gather+all-reduce pattern ``ps_lookup`` makes explicit.
    """
    safe = jnp.where(ids >= 0, ids, 0)
    rows = jnp.take(table, safe, axis=0)
    return jnp.where((ids >= 0)[..., None], rows, 0.0)


# ------------------------------------------------- unique-id (sparse) path
def unique_pad_ids(
    id_arrays: Sequence[np.ndarray], bucket: int = 0, min_bucket: int = 8
) -> np.ndarray:
    """Deduplicated touched ids, PAD-padded *in front* to a stable bucket.

    Host-side prologue of the gather→step→scatter contract: the returned
    array holds ``width - n`` leading PADs (-1) followed by the ``n`` unique
    non-PAD ids in ascending order. ``width`` is ``max(min_bucket, bucket)``
    doubled until it fits, so a caller that persists the width across batches
    recompiles the jitted step at most O(log n) times and then shapes are
    stable. PADs lead (rather than trail) so scatter consumers that clamp
    PAD to row 0 perform their benign no-op writes *before* row 0's real
    update (see kernels/row_adagrad.py).
    """
    arrays = [np.asarray(a).reshape(-1) for a in id_arrays]
    flat = np.concatenate(arrays) if arrays else np.empty(0, np.int64)
    real = np.unique(flat)
    real = real[real >= 0]
    width = max(int(min_bucket), int(bucket))
    while width < len(real):
        width *= 2
    out = np.full(width, -1, dtype=np.int64)
    if len(real):
        out[width - len(real):] = real
    return out


def remap_ids(uniq: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Global ids -> row indices into ``gather_rows(table, uniq)``.

    Every non-PAD id must be present in ``uniq`` (guaranteed when ``uniq``
    came from ``unique_pad_ids`` over arrays that include ``ids``); PAD stays
    PAD so downstream masking is unchanged.
    """
    ids = np.asarray(ids, dtype=np.int64)
    real = uniq[uniq >= 0]
    if len(real) == 0:
        return np.full(ids.shape, -1, dtype=np.int64)
    offset = len(uniq) - len(real)
    loc = np.searchsorted(real, np.clip(ids, real[0], real[-1]))
    return np.where(ids >= 0, loc + offset, -1)


def gather_rows(table: jnp.ndarray, uniq: jnp.ndarray) -> jnp.ndarray:
    """Pull the touched rows: (bucket, dim). PAD slots clamp to row 0; their
    contents are never referenced by remapped ids and their updates are
    dropped by ``scatter_rows``."""
    return jnp.take(table, jnp.maximum(uniq, 0), axis=0)


def scatter_rows(
    table: jnp.ndarray, uniq: jnp.ndarray, rows: jnp.ndarray
) -> jnp.ndarray:
    """Push updated rows back: ``table[uniq] = rows`` with PAD slots dropped.

    PAD ids are remapped to ``num_rows`` (one past the end) because negative
    scatter indices wrap in JAX; ``mode="drop"`` then discards them. Under
    buffer donation this lowers to an in-place row write — O(bucket), not
    O(num_rows).
    """
    idx = jnp.where(uniq >= 0, uniq, table.shape[0])
    return table.at[idx].set(rows, mode="drop")


def slot_count_matrix(
    slot_indptr: np.ndarray,
    slot_values: np.ndarray,
    num_nodes: int,
    vocab_size: int,
    max_values: int,
) -> np.ndarray:
    """(num_nodes, vocab) float32 matrix of each node's slot-value counts.

    Row n counts the node's first ``max_values`` ragged values — the exact
    set ``pad_slot_values`` would emit — so ``counts[n] @ table`` equals the
    padded gather-and-sum. Built host-side once per table (vectorized
    ``np.add.at``); see ``embed_nodes_bag`` for how it replaces the per-value
    device gather.
    """
    counts = np.zeros((num_nodes, vocab_size), dtype=np.float32)
    starts = np.asarray(slot_indptr[:-1], dtype=np.int64)
    lens = np.minimum(slot_indptr[1:] - starts, max_values).astype(np.int64)
    if lens.sum():
        node_of, off = ragged_row_offsets(lens)
        np.add.at(counts, (node_of, slot_values[starts[node_of] + off]), 1.0)
    return counts


def ps_lookup(
    table: jnp.ndarray,
    ids: jnp.ndarray,
    mesh: Mesh,
    model_axis: str = "model",
    pad_id: int = -1,
) -> jnp.ndarray:
    """Explicit parameter-server pull via shard_map.

    ``table`` is row-sharded over ``model_axis``; ``ids`` replicated along it.
    Each shard serves the rows it owns; psum assembles the full rows. The VJP
    of this function is the "push": scatter-add of grads onto the owner shard.
    """
    num_shards = mesh.shape[model_axis]
    rows_per = table.shape[0] // num_shards

    def _local(local_table: jnp.ndarray, ids_: jnp.ndarray) -> jnp.ndarray:
        shard = jax.lax.axis_index(model_axis)
        lo = shard * rows_per
        local_idx = ids_ - lo
        owned = (ids_ >= lo) & (ids_ < lo + rows_per)
        safe = jnp.clip(local_idx, 0, rows_per - 1)
        out = jnp.take(local_table, safe, axis=0)
        out = jnp.where(owned[..., None], out, 0.0)
        return jax.lax.psum(out, model_axis)

    mapped = _shard_map(_local, mesh, in_specs=(P(model_axis, None), P()), out_specs=P())
    return mapped(table, jnp.where(ids >= 0, ids, 0)) * (ids >= 0)[..., None]


def _shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-tolerant shard_map: new JAX exposes ``jax.shard_map`` with
    ``check_vma``; older releases only have the experimental module with
    ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_exp

    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def embed_nodes(
    params: Mapping[str, jnp.ndarray],
    ids: jnp.ndarray,
    slot_values: Optional[Mapping[str, jnp.ndarray]] = None,
    pad_id: int = -1,
) -> jnp.ndarray:
    """ID embedding + sum of side-info slot embeddings (paper §4.4 RQ3).

    ``slot_values[name]``: (..., max_values) padded value ids aligned with
    ``ids``. Multi-value slots are sum-pooled (bag-of-features).
    """
    h = lookup(params["node"], ids, pad_id)
    if slot_values:
        for name, vals in slot_values.items():
            tab = params[f"slot:{name}"]
            h = h + lookup(tab, vals, pad_id).sum(axis=-2)
    return h


def embed_nodes_bag(
    params: Mapping[str, jnp.ndarray],
    ids: jnp.ndarray,
    slot_counts: Mapping[str, jnp.ndarray],
    pad_id: int = -1,
) -> jnp.ndarray:
    """Side-info embedding via per-node value counts (embedding-bag form).

    ``slot_counts[name]``: (num_nodes, vocab) from ``slot_count_matrix``.
    Exactly equivalent to ``embed_nodes`` over the padded value lists the
    counts were built from — the gathered count row is zero for PAD ids, and
    ``counts_row @ table`` is the same truncated sum — but the per-value
    gather and its backward scatter-add become two GEMMs, which is much
    faster whenever dense count rows are affordable. Large-vocab slots
    should stay on ``embed_nodes`` (counts are dense per node here).
    """
    h = lookup(params["node"], ids, pad_id)
    for name, cmat in slot_counts.items():
        c = lookup(cmat, ids, pad_id)  # (..., vocab); zero row for PAD ids
        h = h + c @ params[f"slot:{name}"]
    return h


def embed_nodes_mixed(
    params: Mapping[str, jnp.ndarray],
    ids: jnp.ndarray,
    slot_values: Optional[Mapping[str, jnp.ndarray]] = None,
    slot_counts: Optional[Mapping[str, jnp.ndarray]] = None,
    pad_id: int = -1,
) -> jnp.ndarray:
    """ID embedding + side info with a per-slot bag/values split.

    Slots may arrive through either representation simultaneously: small
    vocabs as count-matrix GEMMs (``slot_counts``, the 'bag' form), large
    vocabs as padded value lists (``slot_values``) — the fallback the bag
    vocab guard (``core.model.Graph4RecConfig.bag_vocab_limit``) selects so
    no O(num_nodes x vocab) count matrix is ever materialized. A slot must
    appear in at most one of the two mappings.
    """
    h = lookup(params["node"], ids, pad_id)
    if slot_counts:
        for name, cmat in slot_counts.items():
            c = lookup(cmat, ids, pad_id)  # (..., vocab); zero row for PAD ids
            h = h + c @ params[f"slot:{name}"]
    if slot_values:
        for name, vals in slot_values.items():
            h = h + lookup(params[f"slot:{name}"], vals, pad_id).sum(axis=-2)
    return h


# --------------------------------------------------------------- side info
def pad_slot_values(
    slot_indptr: np.ndarray,
    slot_values: np.ndarray,
    ids: np.ndarray,
    max_values: int,
    pad_id: int = -1,
) -> np.ndarray:
    """Host-side: ragged slot values -> (len(ids), max_values) padded.

    Fully vectorized ragged-to-padded scatter: every (row, column) output
    position and its source position in ``slot_values`` are computed as flat
    index arrays, so the copy is one fancy-indexed assignment regardless of
    how many ids are requested.
    """
    ids = np.asarray(ids).reshape(-1)
    out = np.full((len(ids), max_values), pad_id, dtype=np.int64)
    valid = np.flatnonzero(ids >= 0)
    if len(valid) == 0:
        return out
    vids = ids[valid]
    starts = np.asarray(slot_indptr[vids], dtype=np.int64)
    lens = np.minimum(slot_indptr[vids + 1] - starts, max_values).astype(np.int64)
    if lens.sum() == 0:
        return out
    row_of, col = ragged_row_offsets(lens)
    out[valid[row_of], col] = slot_values[starts[row_of] + col]
    return out


def _pad_slot_values_loop(
    slot_indptr: np.ndarray,
    slot_values: np.ndarray,
    ids: np.ndarray,
    max_values: int,
    pad_id: int = -1,
) -> np.ndarray:
    """Reference per-node loop (seed implementation) for equivalence tests
    and the serial arm of benchmarks/bench_throughput.py."""
    ids = np.asarray(ids).reshape(-1)
    out = np.full((len(ids), max_values), pad_id, dtype=np.int64)
    for k, node in enumerate(ids):
        if node < 0:
            continue
        vals = slot_values[slot_indptr[node] : slot_indptr[node + 1]][:max_values]
        out[k, : len(vals)] = vals
    return out


# -------------------------------------------------------------- warm start
def save_table(path: str, params: Mapping[str, jnp.ndarray]) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_table(path: str) -> Dict[str, np.ndarray]:
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def warm_start(
    params: Dict[str, jnp.ndarray], pretrained: Mapping[str, np.ndarray]
) -> Dict[str, jnp.ndarray]:
    """Inherit pre-trained sparse tables (paper §3.6 warm start).

    Any table present in ``pretrained`` with a matching shape replaces the
    fresh initialization; everything else (dense GNN weights) is untouched.
    """
    out = dict(params)
    for k, v in pretrained.items():
        if k in out and tuple(out[k].shape) == tuple(v.shape):
            out[k] = jnp.asarray(v, dtype=out[k].dtype)
    return out
