from repro.embedding.table import (
    EmbeddingConfig, SlotSpec, init_params, abstract_params, param_specs,
    lookup, ps_lookup, embed_nodes, embed_nodes_bag, embed_nodes_mixed,
    pad_slot_values,
    slot_count_matrix,
    unique_pad_ids, remap_ids, gather_rows, scatter_rows,
    save_table, load_table, warm_start,
)
from repro.embedding.optimizer import (
    RowAdagradState, rowwise_adagrad_init, rowwise_adagrad_update,
    rowwise_adagrad_scatter_update,
)
