"""Row-wise sparse optimizers for the embedding tables (PS-side updates).

The paper's parameter server pulls the rows a batch touches and pushes only
their gradients back. This module implements the PS-side update rule —
row-wise AdaGrad, one accumulator per row (the standard PS trick: 1/dim the
memory of full AdaGrad) — in the two forms the trainer uses:

- **Scatter form** (``rowwise_adagrad_scatter_update``) — the
  gather→step→scatter contract: gradients arrive as (bucket, dim) blocks
  w.r.t. the *gathered sub-table* (``embedding.table.gather_rows`` over the
  batch's unique ids), the per-row accumulators for the same rows are
  gathered, updated and scattered back alongside the parameter rows, and PAD
  bucket slots (id < 0, zero grads) are dropped at the scatter. O(unique
  ids) per step regardless of table size; with buffer donation the scatter
  is an in-place row write.
- **Dense form** (``rowwise_adagrad_update`` here, and the optax-style
  ``train.optimizer.rowwise_adagrad``) — the same rule applied to a full
  (num_nodes, dim) gradient. Untouched rows have zero grads (the scatter-add
  cotangent of the gather), so the dense form is mathematically identical to
  the scatter form at O(num_nodes) cost; it remains as the reference /
  fallback path (``TrainerConfig.sparse_updates=False``) and the equivalence
  oracle for tests.
"""
from __future__ import annotations

from typing import Dict, Mapping, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.embedding.table import gather_rows, scatter_rows


class RowAdagradState(NamedTuple):
    accum: Dict[str, jnp.ndarray]  # per-table (rows, 1) accumulators


def rowwise_adagrad_init(
    params: Mapping[str, jnp.ndarray], init_accum: float = 0.0
) -> RowAdagradState:
    return RowAdagradState(
        accum={
            k: jnp.full((v.shape[0], 1), init_accum, v.dtype)
            for k, v in params.items()
        }
    )


def rowwise_adagrad_update(
    params: Mapping[str, jnp.ndarray],
    grads: Mapping[str, jnp.ndarray],
    state: RowAdagradState,
    lr: float = 0.1,
    eps: float = 1e-8,
) -> Tuple[Dict[str, jnp.ndarray], RowAdagradState]:
    """Dense reference form: full-table grads, every row updated."""
    new_params: Dict[str, jnp.ndarray] = {}
    new_accum: Dict[str, jnp.ndarray] = {}
    for k, p in params.items():
        g = grads[k]
        acc = state.accum[k] + jnp.mean(g * g, axis=-1, keepdims=True)
        new_params[k] = p - lr * g / (jnp.sqrt(acc) + eps)
        new_accum[k] = acc
    return new_params, RowAdagradState(accum=new_accum)


def rowwise_adagrad_scatter_update(
    params: Mapping[str, jnp.ndarray],
    sub_grads: Mapping[str, jnp.ndarray],
    uniq: Mapping[str, jnp.ndarray],
    state: RowAdagradState,
    lr: float = 0.1,
    eps: float = 1e-8,
    use_kernel: bool = False,
) -> Tuple[Dict[str, jnp.ndarray], RowAdagradState]:
    """Scatter form: apply the row-wise rule to the touched rows only.

    ``sub_grads[k]``: (bucket, dim) gradient w.r.t.
    ``gather_rows(params[k], uniq[k])``. Parameter and accumulator rows at
    ``uniq[k]`` are gathered, stepped, and scattered back; PAD slots
    (``uniq[k] < 0``) carry zero grads by construction (no remapped id points
    at them) and are dropped by the scatter, so padded buckets never perturb
    the table. ``use_kernel`` routes the gather/apply/scatter through the
    fused Pallas kernel (kernels/row_adagrad.py).
    """
    new_params: Dict[str, jnp.ndarray] = {}
    new_accum: Dict[str, jnp.ndarray] = {}
    for k, p in params.items():
        ids = uniq[k]
        g = sub_grads[k]
        if use_kernel:
            from repro.kernels import ops  # late import: kernels are optional

            new_params[k], new_accum[k] = ops.rowwise_adagrad_scatter(
                p, state.accum[k], ids, g, lr=lr, eps=eps
            )
            continue
        acc_rows = gather_rows(state.accum[k], ids) + jnp.mean(
            g * g, axis=-1, keepdims=True
        )
        rows = gather_rows(p, ids) - lr * g / (jnp.sqrt(acc_rows) + eps)
        new_params[k] = scatter_rows(p, ids, rows)
        new_accum[k] = scatter_rows(state.accum[k], ids, acc_rows)
    return new_params, RowAdagradState(accum=new_accum)
