"""Row-wise sparse optimizers for the embedding tables (PS-side updates).

The paper's parameter server applies asynchronous per-row updates; the SPMD
equivalent is a synchronous dense update whose gradient is structurally
sparse (only touched rows have nonzero grads — scatter-add cotangent of the
gather). Row-wise AdaGrad keeps a single accumulator per row (the standard
PS trick — 1/dim the memory of full AdaGrad) so untouched rows are no-ops up
to float rounding.
"""
from __future__ import annotations

from typing import Dict, Mapping, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class RowAdagradState(NamedTuple):
    accum: Dict[str, jnp.ndarray]  # per-table (rows, 1) accumulators


def rowwise_adagrad_init(params: Mapping[str, jnp.ndarray]) -> RowAdagradState:
    return RowAdagradState(
        accum={k: jnp.zeros((v.shape[0], 1), v.dtype) for k, v in params.items()}
    )


def rowwise_adagrad_update(
    params: Mapping[str, jnp.ndarray],
    grads: Mapping[str, jnp.ndarray],
    state: RowAdagradState,
    lr: float = 0.1,
    eps: float = 1e-8,
) -> Tuple[Dict[str, jnp.ndarray], RowAdagradState]:
    new_params: Dict[str, jnp.ndarray] = {}
    new_accum: Dict[str, jnp.ndarray] = {}
    for k, p in params.items():
        g = grads[k]
        acc = state.accum[k] + jnp.mean(g * g, axis=-1, keepdims=True)
        new_params[k] = p - lr * g / (jnp.sqrt(acc) + eps)
        new_accum[k] = acc
    return new_params, RowAdagradState(accum=new_accum)
