"""Logging shim: consistent format, env-controlled level."""
from __future__ import annotations

import logging
import os
import sys

_CONFIGURED = False


def get_logger(name: str) -> logging.Logger:
    global _CONFIGURED
    if not _CONFIGURED:
        level = os.environ.get("REPRO_LOG_LEVEL", "INFO").upper()
        logging.basicConfig(
            stream=sys.stderr,
            level=getattr(logging, level, logging.INFO),
            format="%(asctime)s %(levelname)s %(name)s | %(message)s",
            datefmt="%H:%M:%S",
        )
        _CONFIGURED = True
    return logging.getLogger(name)
