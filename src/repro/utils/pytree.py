"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import numpy as np


def tree_num_params(tree) -> int:
    """Total number of array elements in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_size_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
    return total
