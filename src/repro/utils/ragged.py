"""Shared ragged-array indexing helper.

One idiom, used by the graph engine's partition build, host-side slot
padding, and slot count-matrix construction: given per-row lengths, produce
flat (row, offset) index arrays addressing every element of the
concatenated rows, so a ragged copy becomes a single vectorized gather.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def ragged_row_offsets(lengths: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(row_of, offset) flat index arrays for rows of the given lengths.

    ``row_of[i]`` is the row the i-th output element belongs to and
    ``offset[i]`` its position within that row; both have length
    ``lengths.sum()``. Source positions in a CSR-like layout are then
    ``starts[row_of] + offset``.
    """
    lengths = np.asarray(lengths, dtype=np.int64)
    total = int(lengths.sum())
    row_of = np.repeat(np.arange(len(lengths), dtype=np.int64), lengths)
    offset = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(lengths) - lengths, lengths
    )
    return row_of, offset
