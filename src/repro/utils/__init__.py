from repro.utils.log import get_logger
from repro.utils.ragged import ragged_row_offsets

# The pytree helpers pull in JAX. They are exported lazily (PEP 562) so that
# NumPy-only consumers of this package — in particular the spawned graph
# service workers, whose import chain reaches repro.utils via
# graph/engine.py's ragged import — never pay the JAX import.
_PYTREE_EXPORTS = ("tree_size_bytes", "tree_num_params")


def __getattr__(name):
    if name in _PYTREE_EXPORTS:
        from repro.utils import pytree

        return getattr(pytree, name)
    raise AttributeError(f"module 'repro.utils' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_PYTREE_EXPORTS))
