from repro.utils.pytree import tree_size_bytes, tree_num_params
from repro.utils.log import get_logger
from repro.utils.ragged import ragged_row_offsets
