from repro.sampling.ego import EgoConfig, EgoBatch, sample_ego_batch, PAD
from repro.sampling.pairs import (
    PairConfig, window_pairs, window_positions, pairs_to_nodes,
    sample_random_negatives,
)
from repro.sampling.pipeline import (
    PipelineConfig, SamplePipeline, TrainBatch, make_train_sampler,
)
