"""Relation-wise ego-graph sampling (Graph4Rec §3.3).

An ego graph of a central node v is the subgraph induced by v's K-hop
neighborhood; with multiple edge types Graph4Rec samples *relation-wise*:
``G_v = {G_{v,r} : r in R}``, so each relation keeps its own neighbor set and
the GNN can aggregate them with per-relation weights (Eq. 3).

Dense batched layout (accelerator-friendly — this is the hardware
adaptation of the paper's message-passing subgraphs): with R relations and
per-hop fanouts (F_1..F_K),

    level 0: (B, 1)            the centers
    level k: (B, W_k)          W_k = W_{k-1} * R * F_k

and the neighbors of level-(k-1) slot j under relation r occupy the slice
``level_k[:, j*R*F_k + r*F_k : j*R*F_k + (r+1)*F_k]``. PAD (-1) marks missing
neighbors; aggregation masks them. Everything downstream (GNN zoo, Pallas
seg_aggr kernel) consumes this layout, which keeps the device graph static —
the same trick the paper uses to decouple GNN compute from the graph engine.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.graph.engine import engine_sample_many

PAD = -1


@dataclasses.dataclass
class EgoConfig:
    relations: Sequence[str]  # relation names, fixed order
    fanouts: Sequence[int]  # neighbors sampled per relation per hop, len = K hops

    @property
    def num_hops(self) -> int:
        return len(self.fanouts)

    @property
    def num_relations(self) -> int:
        return len(self.relations)

    def level_width(self, k: int) -> int:
        w = 1
        for f in self.fanouts[:k]:
            w *= self.num_relations * f
        return w


@dataclasses.dataclass
class EgoBatch:
    """Batched relation-wise ego graphs: one (B, W_k) array per level."""

    config: EgoConfig
    levels: List[np.ndarray]  # levels[0]: (B, 1) centers; levels[k]: (B, W_k)

    @property
    def batch_size(self) -> int:
        return int(self.levels[0].shape[0])

    @property
    def centers(self) -> np.ndarray:
        return self.levels[0][:, 0]

    def num_sampled_nodes(self) -> int:
        return int(sum(l.size for l in self.levels[1:]))

    def take(self, idx: np.ndarray) -> "EgoBatch":
        """Row-select ego graphs (used by ego-first pair generation)."""
        return EgoBatch(self.config, [l[idx] for l in self.levels])

    def concat(self, other: "EgoBatch") -> "EgoBatch":
        return EgoBatch(
            self.config,
            [np.concatenate([a, b], axis=0) for a, b in zip(self.levels, other.levels)],
        )


def sample_ego_batch(
    rng: np.random.Generator,
    engine,  # HeteroGraph or DistributedGraphEngine (same sample_neighbors API)
    centers: np.ndarray,
    config: EgoConfig,
) -> EgoBatch:
    """Sample relation-wise ego graphs for ``centers``.

    Per hop k, issues ONE ``sample_many`` query group covering every
    relation's batched neighbor request for all frontier nodes — matching
    the engine's batched RPC (a single pipelined round-trip per worker on
    the mp backend). PAD frontier slots propagate PAD children.
    """
    centers = np.asarray(centers, dtype=np.int64).reshape(-1)
    B = len(centers)
    levels: List[np.ndarray] = [centers[:, None]]
    frontier = levels[0]  # (B, W)
    R = config.num_relations
    for k, fanout in enumerate(config.fanouts):
        W = frontier.shape[1]
        nxt = np.full((B, W, R, fanout), PAD, dtype=np.int64)
        flat = frontier.reshape(-1)
        valid = flat != PAD
        if valid.any():
            # ONE frontier array shared by every relation's query: the mp
            # client routes queries with identical node arrays once (its
            # cache is keyed by array identity)
            frontier_nodes = flat[valid]
            queries = [
                (frontier_nodes, rel, fanout, PAD) for rel in config.relations
            ]
            for ri, sampled in enumerate(engine_sample_many(engine, rng, queries)):
                block = np.full((B * W, fanout), PAD, dtype=np.int64)
                block[valid] = sampled
                nxt[:, :, ri, :] = block.reshape(B, W, fanout)
        levels.append(nxt.reshape(B, W * R * fanout))
        frontier = levels[-1]
    return EgoBatch(config, levels)
