"""Pair generation from random-walk paths (Graph4Rec §3.4) and negative
sampling strategies (§3.6, RQ4).

Positive pairs are node pairs inside the same walk within ``win_size``
(skip-gram proximity). Negatives are either drawn uniformly from the node set
("random", requires extra engine/PS traffic for the negatives' embeddings and
side info) or taken from the other positives in the batch ("in-batch", no
extra data input — the paper's ≈4× speedup).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

PAD = -1


@dataclasses.dataclass
class PairConfig:
    win_size: int = 2
    neg_mode: str = "inbatch"  # "inbatch" | "random"
    num_negatives: int = 5  # per positive, random mode only


def window_positions(walk_len: int, win_size: int) -> np.ndarray:
    """Static (npos, 2) table of in-window (src_col, dst_col) position pairs.

    The skip-gram window over a length-``walk_len`` path, independent of the
    path contents: src != dst, |src - dst| <= win_size. Shared by the host
    ``window_pairs`` and the fused on-device sampler (whose pair stage is a
    fixed gather of exactly these columns).
    """
    rows = []
    for d in range(1, win_size + 1):
        if d >= walk_len:
            break
        for s in range(0, walk_len - d):
            rows.append((s, s + d))
            rows.append((s + d, s))
    return np.array(rows, dtype=np.int64).reshape(-1, 2)


def window_pairs(paths: np.ndarray, win_size: int) -> np.ndarray:
    """All (src_pos, dst_pos) index pairs within the window, per path.

    Returns (P, 3) int64 rows of (path_row, src_col, dst_col) with
    src != dst, |src-dst| <= win_size, and both nodes valid (not PAD).
    Enumerating *positions* (not node ids) lets the ego-first pipeline reuse
    per-position ego graphs (§3.6 order exchange).
    """
    B, L = paths.shape
    pos = window_positions(L, win_size)  # (L-window combos, 2)
    # cross with batch rows, filter PAD
    path_idx = np.repeat(np.arange(B, dtype=np.int64), len(pos))
    sc = np.tile(pos[:, 0], B)
    dc = np.tile(pos[:, 1], B)
    ok = (paths[path_idx, sc] != PAD) & (paths[path_idx, dc] != PAD)
    return np.stack([path_idx[ok], sc[ok], dc[ok]], axis=1)


def pairs_to_nodes(paths: np.ndarray, pairs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(P,3) position pairs -> (src_ids, dst_ids)."""
    return paths[pairs[:, 0], pairs[:, 1]], paths[pairs[:, 0], pairs[:, 2]]


def sample_random_negatives(
    rng: np.random.Generator,
    num_pos: int,
    num_negatives: int,
    node_range: Tuple[int, int],
) -> np.ndarray:
    """Uniform negatives over a node-id range: (num_pos, num_negatives)."""
    lo, hi = node_range
    return rng.integers(lo, hi, size=(num_pos, num_negatives)).astype(np.int64)
