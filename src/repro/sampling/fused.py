"""Fused on-device walk -> pair -> ego sampling (the device-resident pipeline).

PRs 1-4 made every pipeline *stage* fast but left the stage boundaries on
the host: walks, window pairs, and ego gathers are produced by NumPy against
the graph engine and shipped to the device per batch. For small/medium
graphs whose padded adjacency fits in device memory that round-trip is the
dominant cost, so this module runs the whole sampling front end as ONE
jitted program over device-resident tables:

- **walk**: ``walk.metapath.jax_walk_multi`` over a stacked (R, N, max_deg)
  padded adjacency, with a per-walk metapath draw (uniform over the
  configured metapaths) and per-metapath start-type ranges;
- **pair**: the static skip-gram window gather
  (``kernels.window_pairs`` Pallas kernel / jnp reference), then a uniform
  inverse-CDF draw of ``batch_pairs`` valid pairs;
- **ego**: relation-wise K-hop gathers from the same padded adjacency,
  PAD-propagating exactly like ``sampling.ego.sample_ego_batch``;
- **side info**: value slots as a device-resident (N, max_values) padded
  table, bag slots as the same (N, vocab) count matrices the host 'bag'
  path uses.

The emitted batch has exactly the fixed-shape PAD-padded structure
``core.model.loss_fn`` consumes (``device_batch`` layout, global ids), so
the trainer can fuse sampling INTO its jitted grad step — zero host work
per step. Distribution contract vs the host pipeline: identical walk, pair
and ego-child distributions (uniform neighbor draws over the same
adjacency, same window table, same uniform negatives); what differs is
bookkeeping only — batches are drawn per-step rather than carried across
rounds, and repeated pair endpoints get fresh ego samples (the host
``walk_pair_ego`` diversity semantics). ``tests/test_fused_sampling.py``
pins this contract backend-against-backend.

Eligibility: the device tables cost
``R * N * (max_degree + 1)`` int32s plus slot/count tables;
``fused_eligibility`` sizes them against a configurable budget so callers
(train.trainer) can fall back to the host pipeline for graphs that do not
fit (that regime belongs to the multi-process engine anyway).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.embedding import table as emb
from repro.graph.hetero_graph import HeteroGraph, Relation
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.sampling.pairs import window_positions
from repro.sampling.pipeline import PipelineConfig
from repro.walk.metapath import jax_walk_multi, parse_metapath

PAD = -1


@dataclasses.dataclass(frozen=True)
class FusedConfig:
    """Knobs of the fused device sampler (threaded from TrainerConfig)."""

    # Padded-adjacency width: rows wider than this are uniformly subsampled
    # once at build time (HeteroGraph.padded_adjacency).
    max_degree: int = 32
    # Device-table budget for the eligibility check, in MiB.
    budget_mb: float = 256.0
    # Route the pair gather through the Pallas kernel (interpret mode off
    # TPU) instead of the jnp reference.
    use_kernel_pairs: bool = True
    # Candidate pairs generated per emitted pair (safety factor against
    # PAD-invalidated candidates). Walks per batch =
    # ceil(oversample * batch_pairs / window_positions).
    oversample: float = 2.0


def _union_relations(config: PipelineConfig) -> List[str]:
    rels = {r for mp in config.walk.metapaths for r in parse_metapath(mp)}
    if config.ego is not None:
        rels |= set(config.ego.relations)
    return sorted(rels)


def fused_device_bytes(
    graph: HeteroGraph,
    config: PipelineConfig,
    value_slots: Sequence[emb.SlotSpec] = (),
    bag_slots: Sequence[emb.SlotSpec] = (),
    max_degree: int = 32,
) -> int:
    """Bytes of device-resident tables the fused sampler would build."""
    N = graph.num_nodes
    R = len(_union_relations(config))
    total = R * N * (max_degree + 1) * 4  # adjacency + degrees, int32
    for spec in value_slots:
        total += N * spec.max_values * 4  # padded value table, int32
    for spec in bag_slots:
        total += N * spec.vocab_size * 4  # count matrix, float32
    return total


def fused_eligibility(
    graph: HeteroGraph,
    config: PipelineConfig,
    value_slots: Sequence[emb.SlotSpec] = (),
    bag_slots: Sequence[emb.SlotSpec] = (),
    fused: FusedConfig = FusedConfig(),
    measured_bytes: Optional[int] = None,
) -> Tuple[bool, str]:
    """(eligible?, human-readable reason) for the memory-based gate.

    Without ``measured_bytes`` the gate runs on the shape-derived
    *estimate* (``fused_device_bytes`` — nothing is resident yet). Once a
    sampler exists, callers re-check with ``measured_bytes=
    sampler.device_table_bytes()`` — the actual footprint of the arrays
    ``jax.device_put`` shipped — so the logged budget decision names
    measured bytes, not predicted ones (the trainer does this in
    ``_build_fused``).
    """
    if measured_bytes is not None:
        need, kind = int(measured_bytes), "measured"
    else:
        need = fused_device_bytes(
            graph, config, value_slots, bag_slots, max_degree=fused.max_degree
        )
        kind = "estimated"
    budget = int(fused.budget_mb * (1 << 20))
    if need > budget:
        return False, (
            f"padded device tables need {need / (1 << 20):.1f} MiB "
            f"({kind}) > budget {fused.budget_mb:.1f} MiB"
        )
    return True, f"device tables fit: {need / (1 << 20):.1f} MiB ({kind})"


class FusedSampler:
    """Device-resident walk->pair->ego sampler with a single jittable entry.

    ``sample(key)`` is a pure function of the PRNG key (all tables are baked
    at construction), so callers can jit it alone or inline it into a larger
    jitted step (the trainer fuses it with the grad step). Shapes are fully
    static: every batch carries exactly ``config.batch_pairs`` pairs.
    """

    def __init__(
        self,
        graph: HeteroGraph,
        config: PipelineConfig,
        value_slots: Sequence[emb.SlotSpec] = (),
        bag_slots: Sequence[emb.SlotSpec] = (),
        fused: FusedConfig = FusedConfig(),
        bag_counts: Optional[Mapping[str, jnp.ndarray]] = None,
        seed: int = 0,
    ):
        if config.order not in ("walk_ego_pair", "walk_pair_ego"):
            raise ValueError(f"unknown order {config.order!r}")
        self.graph = graph
        self.config = config
        self.fused = fused
        self.value_slots = tuple(value_slots)
        self.bag_slots = tuple(bag_slots)
        self.ego = config.ego
        # Build-time seed for the padded-adjacency hub subsample: two
        # samplers built with the same seed share bitwise-identical tables.
        self.seed = seed

        # All H2D shipping below is explicit jax.device_put (lint rule H002):
        # the one transfer spelling jax.transfer_guard("disallow") certifies,
        # and the visible-in-profiles hook for the ROADMAP's double-buffered
        # device_put item.

        # ---------------- relation tables: one stacked padded adjacency
        self._rel_names = _union_relations(config)
        rel_id = {r: i for i, r in enumerate(self._rel_names)}
        adjs, degs = [], []
        for r in self._rel_names:
            a, d = graph.padded_adjacency(
                r, fused.max_degree, pad_id=PAD, seed=seed
            )
            adjs.append(a.astype(np.int32))
            degs.append(d.astype(np.int32))
        self._adj = jax.device_put(np.stack(adjs))  # (R, N, max_degree)
        self._deg = jax.device_put(np.stack(degs))  # (R, N)

        # ---------------- walk schedule + per-metapath start ranges
        paths = [parse_metapath(mp) for mp in config.walk.metapaths]
        if not paths:
            raise ValueError("need at least one metapath")
        L = config.walk.walk_len
        sched = np.zeros((len(paths), max(L - 1, 1)), dtype=np.int32)
        start_lo = np.zeros(len(paths), dtype=np.int32)
        start_cnt = np.zeros(len(paths), dtype=np.int32)
        for pi, rels in enumerate(paths):
            for s in range(max(L - 1, 1)):
                sched[pi, s] = rel_id[rels[s % len(rels)]]
            lo, cnt = graph.node_type_ranges[Relation.parse(rels[0]).src_type]
            start_lo[pi], start_cnt[pi] = lo, cnt
        self.num_paths = len(paths)
        self._sched = jax.device_put(sched)
        self._start_lo = jax.device_put(start_lo)
        self._start_cnt = jax.device_put(start_cnt)

        # ---------------- pair stage: static window table + walk count
        self._positions = window_positions(L, config.pair.win_size)
        npos = max(len(self._positions), 1)
        self.num_walks = max(
            1, int(np.ceil(fused.oversample * config.batch_pairs / npos))
        )
        self._spos = jax.device_put(self._positions[:, 0].astype(np.int32))
        self._dpos = jax.device_put(self._positions[:, 1].astype(np.int32))

        # ---------------- ego relation ids (indices into the stacked adj)
        if self.ego is not None:
            self._ego_rel_ids = [rel_id[r] for r in self.ego.relations]

        # ---------------- side-info tables
        self._slot_pad: Dict[str, jnp.ndarray] = {}
        for spec in self.value_slots:
            sf = graph.slots[spec.name]
            self._slot_pad[spec.name] = jax.device_put(
                emb.pad_slot_values(
                    sf.indptr, sf.values,
                    np.arange(graph.num_nodes, dtype=np.int64),
                    spec.max_values, pad_id=PAD,
                ).astype(np.int32)
            )
        self._bag_counts: Dict[str, jnp.ndarray] = {}
        if self.bag_slots:
            if bag_counts is not None:
                self._bag_counts = {
                    s.name: jax.device_put(bag_counts[s.name])
                    for s in self.bag_slots
                }
            else:
                self._bag_counts = {
                    s.name: jax.device_put(
                        emb.slot_count_matrix(
                            graph.slots[s.name].indptr, graph.slots[s.name].values,
                            graph.num_nodes, s.vocab_size, s.max_values,
                        )
                    )
                    for s in self.bag_slots
                }

    def device_table_bytes(self) -> int:
        """Measured footprint of the resident device tables.

        Sums ``.nbytes`` of every array the constructor shipped with
        ``jax.device_put`` — what ``fused_eligibility(measured_bytes=...)``
        gates on once the sampler exists, replacing the shape-derived
        estimate with ground truth.
        """
        tables = [
            self._adj, self._deg, self._sched, self._start_lo,
            self._start_cnt, self._spos, self._dpos,
            *self._slot_pad.values(), *self._bag_counts.values(),
        ]
        return int(sum(int(t.nbytes) for t in tables))

    # ------------------------------------------------------------- stages
    def _slot_values(self, ids: jnp.ndarray) -> Optional[Dict[str, jnp.ndarray]]:
        """Device equivalent of ``core.model._slots_for_ids``: PAD ids map
        to all-PAD value rows; shape ids.shape + (max_values,)."""
        if not self.value_slots:
            return None
        out = {}
        for spec in self.value_slots:
            tab = self._slot_pad[spec.name]
            vals = tab[jnp.maximum(ids, 0)]
            out[spec.name] = jnp.where((ids >= 0)[..., None], vals, PAD)
        return out

    def _ego_levels(self, key: jax.Array, centers: jnp.ndarray) -> List[jnp.ndarray]:
        """Relation-wise K-hop gather; PAD frontier slots propagate PAD —
        level layout identical to ``sampling.ego.sample_ego_batch``."""
        cfg = self.ego
        levels = [centers[:, None]]
        frontier = levels[0]
        R = len(self._ego_rel_ids)
        for k, fanout in enumerate(cfg.fanouts):
            B, W = frontier.shape
            # one bits draw per hop (threefry calls dominate small hops on
            # CPU); bits % degree has negligible O(max_degree/2^32) bias
            bits = jax.random.bits(
                jax.random.fold_in(key, k), (B, W, R, fanout), jnp.uint32
            )
            safe = jnp.maximum(frontier, 0)
            outs = []
            for ri, rid in enumerate(self._ego_rel_ids):
                deg = self._deg[rid][safe]  # (B, W)
                off = (
                    bits[:, :, ri]
                    % jnp.maximum(deg, 1).astype(jnp.uint32)[..., None]
                ).astype(deg.dtype)
                child = self._adj[rid][safe[..., None], off]  # (B, W, fanout)
                ok = (frontier >= 0) & (deg > 0)
                outs.append(jnp.where(ok[..., None], child, PAD))
            nxt = jnp.stack(outs, axis=2)  # (B, W, R, fanout)
            levels.append(nxt.reshape(B, W * R * fanout))
            frontier = levels[-1]
        return levels

    def _part(self, key: jax.Array, ids: jnp.ndarray):
        """One batch part in ``device_batch`` layout: (ids, slots) for
        walk-based models, (levels, per-level slots) for GNNs."""
        if self.ego is None:
            return (ids, self._slot_values(ids))
        levels = self._ego_levels(key, ids)
        slots = None
        if self.value_slots:
            slots = [self._slot_values(l) for l in levels]
        return (levels, slots)

    # ------------------------------------------------------------- sample
    def sample(self, key: jax.Array) -> Dict:
        """One fixed-shape training batch from one PRNG key (jit-safe)."""
        cfg = self.config
        P = cfg.batch_pairs
        k_path, k_start, k_walk, k_sel, k_neg, k_se, k_de, k_ne = (
            jax.random.split(key, 8)
        )
        W = self.num_walks

        # walk: per-walk metapath draw, then the fused multi-metapath scan
        # (bits % n instead of randint: one threefry draw, negligible bias)
        path_of = (
            jax.random.bits(k_path, (W,), jnp.uint32) % self.num_paths
        ).astype(jnp.int32)
        starts = self._start_lo[path_of] + (
            jax.random.bits(k_start, (W,), jnp.uint32)
            % self._start_cnt[path_of].astype(jnp.uint32)
        ).astype(jnp.int32)
        paths = jax_walk_multi(
            k_walk, self._adj, self._deg, starts,
            self._sched, path_of, cfg.walk.walk_len,
        )

        # pair: static window gather, then draw batch_pairs valid candidates
        if self.fused.use_kernel_pairs:
            src_all, dst_all = kernel_ops.window_pair_ids(paths, self._positions)
        else:
            src_all, dst_all = kernel_ref.window_pair_ids_ref(
                paths, self._positions
            )
        src_f, dst_f = src_all.reshape(-1), dst_all.reshape(-1)
        valid = src_f != PAD
        # Uniform draw of batch_pairs candidates from the VALID ones by
        # inverse CDF: cumsum(valid) + searchsorted is far cheaper than a
        # shuffle (argsort dominates the whole program on CPU). The draw is
        # with replacement — the marginal pair distribution is identical to
        # the host pipeline's (which also repeats a pair appearing in
        # several walks); only within-batch duplicate statistics differ.
        cum = jnp.cumsum(valid.astype(jnp.int32))
        n_valid = cum[-1]
        r = (
            jax.random.bits(k_sel, (P,), jnp.uint32)
            % jnp.maximum(n_valid, 1).astype(jnp.uint32)
        ).astype(jnp.int32)
        idx = jnp.minimum(
            jnp.searchsorted(cum, r + 1), src_f.shape[0] - 1
        )
        src, dst = src_f[idx], dst_f[idx]
        # an all-dead round keeps the pairs PAD: they embed to zero rows
        all_dead = n_valid == 0
        src = jnp.where(all_dead, PAD, src)
        dst = jnp.where(all_dead, PAD, dst)

        out: Dict = {}
        if self.ego is not None and cfg.order == "walk_ego_pair":
            # §3.6 order exchange, fused form: ONE ego per (walk, position)
            # — O(W·L) gathers — and the selected pairs index into the
            # shared levels, exactly like the host ego-first pipeline.
            npos = len(self._positions)
            L = cfg.walk.walk_len
            flat_levels = self._ego_levels(k_se, paths.reshape(-1))
            # all-dead rounds PAD the shared towers themselves — every
            # pair indexes into them, so this matches PADding each side
            flat_levels = [
                jnp.where(all_dead, PAD, l) for l in flat_levels
            ]
            slots = (
                [self._slot_values(l) for l in flat_levels]
                if self.value_slots else None
            )
            # Shared-tower layout: the GNN embeds each of the W*L unique
            # (walk, position) towers ONCE; the loss gathers the per-pair
            # src/dst embeddings by index afterwards. Per-tower encoder
            # compute is row-independent, so this is numerically identical
            # to gathering duplicated towers first — but skips embedding
            # each shared ego up to window-size times.
            out["shared"] = (flat_levels, slots)
            row = idx // npos
            pcol = idx % npos
            out["src_sel"] = row * L + self._spos[pcol]
            out["dst_sel"] = row * L + self._dpos[pcol]
        else:
            out["src"] = self._part(k_se, src)
            out["dst"] = self._part(k_de, dst)
        if cfg.pair.neg_mode == "random":
            neg = jax.random.randint(
                k_neg, (P, cfg.pair.num_negatives), 0, self.graph.num_nodes,
                dtype=src.dtype,
            )
            out["neg"] = self._part(k_ne, neg.reshape(-1))
        if self._bag_counts:
            out["slot_counts"] = dict(self._bag_counts)
        return out
