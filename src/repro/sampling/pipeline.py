"""Training-sample pipeline: walk -> {pair, ego} in either order (§3.6).

Graph4Rec's "Walk, Sample, Pair: Order Matters" optimization: generating
pairs first and then sampling an ego graph per pair element costs O(wL) ego
samplings per path (repeated nodes re-sampled); sampling ego graphs per path
*position* first and letting pairs index into them costs O(L). The trade-off
is sample diversity (repeated nodes share one ego sample within a batch).
Both orders are implemented; benchmarks/bench_order.py measures the speed /
recall trade-off (paper Table 7), with the engine's request counters
providing the communication-cost signal.

The pipeline emits fixed-size batches (shape-static for jit): exactly
``batch_pairs`` pairs per batch, trimming the tail.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.sampling.ego import EgoBatch, EgoConfig, sample_ego_batch
from repro.sampling.pairs import (
    PairConfig,
    pairs_to_nodes,
    sample_random_negatives,
    window_pairs,
)
from repro.walk.metapath import MetapathWalker, WalkConfig

PAD = -1


@dataclasses.dataclass
class TrainBatch:
    """One contrastive training batch of ego-graph pairs (or bare id pairs)."""

    src_ids: np.ndarray  # (P,)
    dst_ids: np.ndarray  # (P,)
    neg_ids: Optional[np.ndarray]  # (P, M) random-negative mode, else None
    src_ego: Optional[EgoBatch]  # None for walk-only models
    dst_ego: Optional[EgoBatch]
    neg_ego: Optional[EgoBatch]  # (P*M,) flattened, random-negative mode w/ GNN


@dataclasses.dataclass
class PipelineConfig:
    walk: WalkConfig
    pair: PairConfig
    ego: Optional[EgoConfig] = None  # None -> walk-based model (skip ego stage)
    order: str = "walk_ego_pair"  # "walk_ego_pair" (fast) | "walk_pair_ego" (diverse)
    batch_pairs: int = 512
    walks_per_round: int = 64


class SamplePipeline:
    """Streams TrainBatches from a graph engine. CPU-side, feeds the device."""

    def __init__(self, engine, config: PipelineConfig, seed: int = 0):
        self.engine = engine
        self.config = config
        self.walker = MetapathWalker(engine, config.walk)
        self.rng = np.random.default_rng(seed)
        graph = engine.graph if hasattr(engine, "graph") else engine
        self._node_range = (0, graph.num_nodes)
        # stats mirrored from ego sampling for RQ5 accounting
        self.ego_sampling_ops = 0

    # ------------------------------------------------------------------ round
    def _round(self) -> Iterator[Tuple[np.ndarray, np.ndarray, Optional[EgoBatch], Optional[EgoBatch]]]:
        cfg = self.config
        paths = self.walker.generate(self.rng, cfg.walks_per_round)
        pairs = window_pairs(paths, cfg.pair.win_size)
        if len(pairs) == 0:
            return
        self.rng.shuffle(pairs)
        if cfg.ego is None:
            src, dst = pairs_to_nodes(paths, pairs)
            yield src, dst, None, None
            return

        if cfg.order == "walk_ego_pair":
            # O(L): one ego sample per (path, position); pairs reference them.
            B, L = paths.shape
            flat_nodes = paths.reshape(-1)
            valid = flat_nodes != PAD
            egos_flat = sample_ego_batch(
                self.rng, self.engine, np.where(valid, flat_nodes, 0), cfg.ego
            )
            self.ego_sampling_ops += int(valid.sum())
            src_idx = pairs[:, 0] * L + pairs[:, 1]
            dst_idx = pairs[:, 0] * L + pairs[:, 2]
            src, dst = pairs_to_nodes(paths, pairs)
            yield src, dst, egos_flat.take(src_idx), egos_flat.take(dst_idx)
        elif cfg.order == "walk_pair_ego":
            # O(wL): fresh ego sample per pair endpoint (more diversity).
            src, dst = pairs_to_nodes(paths, pairs)
            src_ego = sample_ego_batch(self.rng, self.engine, src, cfg.ego)
            dst_ego = sample_ego_batch(self.rng, self.engine, dst, cfg.ego)
            self.ego_sampling_ops += len(src) + len(dst)
            yield src, dst, src_ego, dst_ego
        else:
            raise ValueError(f"unknown order {self.config.order!r}")

    # ---------------------------------------------------------------- batches
    def batches(self, num_batches: int) -> Iterator[TrainBatch]:
        cfg = self.config
        P = cfg.batch_pairs
        buf_src: list = []
        buf_dst: list = []
        buf_se: list = []
        buf_de: list = []
        emitted = 0
        while emitted < num_batches:
            for src, dst, se, de in self._round():
                # chunk into fixed-size batches
                n = len(src)
                for lo in range(0, n - P + 1, P):
                    idx = slice(lo, lo + P)
                    sl = np.arange(lo, lo + P)
                    batch = self._finalize(
                        src[idx], dst[idx],
                        se.take(sl) if se is not None else None,
                        de.take(sl) if de is not None else None,
                    )
                    yield batch
                    emitted += 1
                    if emitted >= num_batches:
                        return

    def _finalize(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        src_ego: Optional[EgoBatch],
        dst_ego: Optional[EgoBatch],
    ) -> TrainBatch:
        cfg = self.config
        neg_ids = None
        neg_ego = None
        if cfg.pair.neg_mode == "random":
            neg_ids = sample_random_negatives(
                self.rng, len(src), cfg.pair.num_negatives, self._node_range
            )
            if cfg.ego is not None:
                neg_ego = sample_ego_batch(
                    self.rng, self.engine, neg_ids.reshape(-1), cfg.ego
                )
                self.ego_sampling_ops += neg_ids.size
        return TrainBatch(
            src_ids=src, dst_ids=dst, neg_ids=neg_ids,
            src_ego=src_ego, dst_ego=dst_ego, neg_ego=neg_ego,
        )
