"""Training-sample pipeline: walk -> {pair, ego} in either order (§3.6).

Graph4Rec's "Walk, Sample, Pair: Order Matters" optimization: generating
pairs first and then sampling an ego graph per pair element costs O(wL) ego
samplings per path (repeated nodes re-sampled); sampling ego graphs per path
*position* first and letting pairs index into them costs O(L). The trade-off
is sample diversity (repeated nodes share one ego sample within a batch).
Both orders are implemented; benchmarks/bench_order.py measures the speed /
recall trade-off (paper Table 7), with the engine's request counters
providing the communication-cost signal.

The pipeline emits fixed-size batches (shape-static for jit): exactly
``batch_pairs`` pairs per batch; pairs beyond the last full batch of a round
are carried into the next round, never dropped.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.sampling.ego import EgoBatch, EgoConfig, sample_ego_batch
from repro.sampling.pairs import (
    PairConfig,
    pairs_to_nodes,
    sample_random_negatives,
    window_pairs,
)
from repro.walk.metapath import MetapathWalker, WalkConfig

PAD = -1

# ``batches`` raises after this many consecutive rounds with zero pairs
# instead of spinning forever on a degenerate walk/pair configuration.
_MAX_EMPTY_ROUNDS = 100


def _phase(timer, name: str):
    """Attribution scope: a ``PhaseTimer.phase`` when a timer is wired
    (train.attribution), a no-op context otherwise — zero hot-path cost
    for untimed runs."""
    return contextlib.nullcontext() if timer is None else timer.phase(name)


def _concat_egos(parts: Sequence[EgoBatch]) -> Optional[EgoBatch]:
    if not parts:
        return None
    if len(parts) == 1:
        return parts[0]
    return EgoBatch(
        parts[0].config,
        [
            np.concatenate([p.levels[k] for p in parts], axis=0)
            for k in range(len(parts[0].levels))
        ],
    )


@dataclasses.dataclass
class TrainBatch:
    """One contrastive training batch of ego-graph pairs (or bare id pairs)."""

    src_ids: np.ndarray  # (P,)
    dst_ids: np.ndarray  # (P,)
    neg_ids: Optional[np.ndarray]  # (P, M) random-negative mode, else None
    src_ego: Optional[EgoBatch]  # None for walk-only models
    dst_ego: Optional[EgoBatch]
    neg_ego: Optional[EgoBatch]  # (P*M,) flattened, random-negative mode w/ GNN


@dataclasses.dataclass
class PipelineConfig:
    walk: WalkConfig
    pair: PairConfig
    ego: Optional[EgoConfig] = None  # None -> walk-based model (skip ego stage)
    order: str = "walk_ego_pair"  # "walk_ego_pair" (fast) | "walk_pair_ego" (diverse)
    batch_pairs: int = 512
    walks_per_round: int = 64


def make_train_sampler(
    engine,
    config: "PipelineConfig",
    backend: str = "host",
    seed: int = 0,
    value_slots=(),
    bag_slots=(),
    fused_cfg=None,
    bag_counts=None,
    timer=None,
):
    """Sampling-backend factory for the trainer.

    ``backend="host"`` returns the streaming ``SamplePipeline`` over the
    given engine (any engine backend: HeteroGraph, DistributedGraphEngine,
    or the mp GraphClient). ``backend="fused"`` returns a
    ``sampling.fused.FusedSampler`` built over the engine's graph — the
    whole walk->pair->ego front end as one jittable device program; callers
    should gate it with ``fused.fused_eligibility`` first (the trainer
    does, falling back to "host" with a warning). ``seed`` reaches both
    backends: the host pipeline's stream RNG and the fused sampler's
    build-time padded-adjacency subsample. ``timer`` (a
    ``train.attribution.PhaseTimer``) makes the host pipeline record its
    sampling cost under the "sample" phase; the trainer's auto backend
    calibration degrades cheap samplers to the serial path from exactly
    this measurement (prefetch pays only when a batch costs more to
    produce than to hand over).
    """
    if backend == "host":
        return SamplePipeline(engine, config, seed=seed, timer=timer)
    if backend == "fused":
        from repro.sampling.fused import FusedConfig, FusedSampler

        graph = engine.graph if hasattr(engine, "graph") else engine
        return FusedSampler(
            graph, config,
            value_slots=value_slots, bag_slots=bag_slots,
            fused=fused_cfg if fused_cfg is not None else FusedConfig(),
            bag_counts=bag_counts, seed=seed,
        )
    raise ValueError(f"unknown sampling backend {backend!r}")


class SamplePipeline:
    """Streams TrainBatches from a graph engine. CPU-side, feeds the device."""

    def __init__(
        self, engine, config: PipelineConfig, seed: int = 0, timer=None
    ):
        self.engine = engine
        self.config = config
        self.timer = timer  # optional train.attribution.PhaseTimer
        self.walker = MetapathWalker(engine, config.walk)
        self.rng = np.random.default_rng(seed)
        graph = engine.graph if hasattr(engine, "graph") else engine
        self._node_range = (0, graph.num_nodes)
        # stats mirrored from ego sampling for RQ5 accounting
        self.ego_sampling_ops = 0

    # ------------------------------------------------------------------ round
    def _round(self) -> Iterator[Tuple[np.ndarray, np.ndarray, Optional[EgoBatch], Optional[EgoBatch]]]:
        cfg = self.config
        paths = self.walker.generate(self.rng, cfg.walks_per_round)
        pairs = window_pairs(paths, cfg.pair.win_size)
        if len(pairs) == 0:
            return
        self.rng.shuffle(pairs)
        if cfg.ego is None:
            src, dst = pairs_to_nodes(paths, pairs)
            yield src, dst, None, None
            return

        if cfg.order == "walk_ego_pair":
            # O(L): one ego sample per (path, position); pairs reference them.
            B, L = paths.shape
            flat_nodes = paths.reshape(-1)
            valid = flat_nodes != PAD
            egos_flat = sample_ego_batch(
                self.rng, self.engine, np.where(valid, flat_nodes, 0), cfg.ego
            )
            self.ego_sampling_ops += int(valid.sum())
            src_idx = pairs[:, 0] * L + pairs[:, 1]
            dst_idx = pairs[:, 0] * L + pairs[:, 2]
            src, dst = pairs_to_nodes(paths, pairs)
            yield src, dst, egos_flat.take(src_idx), egos_flat.take(dst_idx)
        elif cfg.order == "walk_pair_ego":
            # O(wL): fresh ego sample per pair endpoint (more diversity).
            src, dst = pairs_to_nodes(paths, pairs)
            src_ego = sample_ego_batch(self.rng, self.engine, src, cfg.ego)
            dst_ego = sample_ego_batch(self.rng, self.engine, dst, cfg.ego)
            self.ego_sampling_ops += len(src) + len(dst)
            yield src, dst, src_ego, dst_ego
        else:
            raise ValueError(f"unknown order {self.config.order!r}")

    # ---------------------------------------------------------------- batches
    def batches(self, num_batches: int) -> Iterator[TrainBatch]:
        """Emit exactly ``num_batches`` fixed-size batches.

        Pairs left over after chunking a round into ``batch_pairs``-sized
        batches are carried into the next round (never dropped), so rounds
        smaller than one batch still make progress and the loop always
        terminates as long as walks keep producing pairs.
        """
        cfg = self.config
        P = cfg.batch_pairs
        buf_src: list = []
        buf_dst: list = []
        buf_se: list = []
        buf_de: list = []
        have = 0
        emitted = 0
        empty_rounds = 0
        while emitted < num_batches:
            got = 0
            with _phase(self.timer, "sample"):
                for src, dst, se, de in self._round():
                    buf_src.append(src)
                    buf_dst.append(dst)
                    if se is not None:
                        buf_se.append(se)
                        buf_de.append(de)
                    got += len(src)
            have += got
            empty_rounds = empty_rounds + 1 if got == 0 else 0
            if empty_rounds >= _MAX_EMPTY_ROUNDS:
                raise RuntimeError(
                    f"{_MAX_EMPTY_ROUNDS} consecutive sampling rounds produced no "
                    "pairs; check walk_len/win_size against the graph"
                )
            if have < P:
                continue
            with _phase(self.timer, "sample"):
                src = np.concatenate(buf_src) if len(buf_src) > 1 else buf_src[0]
                dst = np.concatenate(buf_dst) if len(buf_dst) > 1 else buf_dst[0]
                se = _concat_egos(buf_se)
                de = _concat_egos(buf_de)
            n_full = have // P
            for bi in range(n_full):
                sl = slice(bi * P, (bi + 1) * P)
                yield self._finalize(
                    src[sl], dst[sl],
                    se.take(sl) if se is not None else None,
                    de.take(sl) if de is not None else None,
                )
                emitted += 1
                if emitted >= num_batches:
                    return
            # carry the sub-batch tail into the next round
            lo = n_full * P
            have -= lo
            buf_src = [src[lo:]] if have else []
            buf_dst = [dst[lo:]] if have else []
            tail = slice(lo, None)
            buf_se = [se.take(tail)] if se is not None and have else []
            buf_de = [de.take(tail)] if de is not None and have else []

    def _finalize(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        src_ego: Optional[EgoBatch],
        dst_ego: Optional[EgoBatch],
    ) -> TrainBatch:
        cfg = self.config
        neg_ids = None
        neg_ego = None
        if cfg.pair.neg_mode == "random":
            with _phase(self.timer, "sample"):
                neg_ids = sample_random_negatives(
                    self.rng, len(src), cfg.pair.num_negatives, self._node_range
                )
                if cfg.ego is not None:
                    neg_ego = sample_ego_batch(
                        self.rng, self.engine, neg_ids.reshape(-1), cfg.ego
                    )
                    self.ego_sampling_ops += neg_ids.size
        return TrainBatch(
            src_ids=src, dst_ids=dst, neg_ids=neg_ids,
            src_ego=src_ego, dst_ego=dst_ego, neg_ego=neg_ego,
        )
