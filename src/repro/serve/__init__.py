from repro.serve.engine import ServeConfig, BatchedServer
