"""Minimal batched serving engine over the unified decode path.

Static batching: requests are grouped into fixed-size batches (one jit'd
``decode_step`` per token across the whole batch — the shape-static regime
the pod dry-run lowers). Prompts are left-aligned and stepped through the
cache (prefill-by-decode); finished rows are masked out. Greedy or
temperature sampling.

This is deliberately the simplest production-shaped server: the dry-run's
``decode_32k``/``long_500k`` shapes are exactly one step of this loop at
pod scale.

Telemetry (optional, same convention as the trainer: ``telemetry=None``
disables everything at one is-None test per site): each fixed-size batch
becomes a ``serve.batch`` span, ``serve.queue_depth`` gauges the requests
still waiting when a batch launches (its high-water mark is the burst
depth), and ``serve.request_ns`` is the per-request latency histogram —
every request in a batch observes the batch's wall time, queueing
included, which is what a caller actually waited.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec
from repro.models import transformer as T
from repro.obs.trace import span_scope


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 4
    max_new_tokens: int = 32
    cache_len: int = 256
    temperature: float = 0.0  # 0 -> greedy
    eos_id: Optional[int] = None
    seed: int = 0


class BatchedServer:
    def __init__(
        self, spec: ArchSpec, params, cfg: ServeConfig, telemetry=None
    ):
        assert spec.kind in ("lm", "vlm"), "LM-family archs only"
        self.spec = spec
        self.lm = spec.lm
        self.params = params
        self.cfg = cfg
        if self.lm.sliding_window:
            self.cache_len = min(cfg.cache_len, self.lm.sliding_window)
        else:
            self.cache_len = cfg.cache_len
        self._tracer = telemetry.tracer if telemetry is not None else None
        if telemetry is not None:
            m = telemetry.metrics
            self._m_queue = m.gauge("serve.queue_depth")
            self._m_request_ns = m.histogram("serve.request_ns")
            self._m_requests = m.counter("serve.requests")
        else:
            self._m_queue = None
            self._m_request_ns = None
            self._m_requests = None
        self._step = jax.jit(
            lambda p, c, t: T.decode_step(p, self.lm, c, t)
        )

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1
        ).astype(jnp.int32)

    def _run_batch(self, prompts: List[List[int]]) -> List[List[int]]:
        B = self.cfg.batch_size
        assert len(prompts) <= B
        pad = B - len(prompts)
        prompts = prompts + [[0]] * pad
        max_p = max(len(p) for p in prompts)
        cache = T.init_cache(self.lm, B, self.cache_len)
        key = jax.random.PRNGKey(self.cfg.seed)

        # prefill-by-decode, left-aligned (short prompts repeat last token;
        # their extra steps are overwritten by the first sampled token)
        logits = None
        for i in range(max_p):
            tok = np.array(
                [p[min(i, len(p) - 1)] for p in prompts], dtype=np.int32
            )[:, None]
            logits, cache = self._step(self.params, cache, jnp.asarray(tok))

        outs: List[List[int]] = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        for _ in range(self.cfg.max_new_tokens):
            key, sub = jax.random.split(key)
            nxt = np.asarray(self._sample(logits, sub))
            for b in range(B):
                if not done[b]:
                    outs[b].append(int(nxt[b]))
                    if self.cfg.eos_id is not None and nxt[b] == self.cfg.eos_id:
                        done[b] = True
            if done.all():
                break
            logits, cache = self._step(
                self.params, cache, jnp.asarray(nxt[:, None], jnp.int32)
            )
        return outs[: len(outs) - pad if pad else None]

    def generate(self, prompts: Sequence[Sequence[int]]) -> List[List[int]]:
        """Serve an arbitrary number of requests in fixed-size batches."""
        prompts = [list(p) for p in prompts]
        out: List[List[int]] = []
        B = self.cfg.batch_size
        for lo in range(0, len(prompts), B):
            chunk = prompts[lo : lo + B]
            if self._m_queue is not None:
                # requests still waiting behind this batch: the gauge's
                # high-water mark is the burst depth the server absorbed
                self._m_queue.set(len(prompts) - lo)
            t0 = time.perf_counter_ns()
            with span_scope(
                self._tracer, "serve.batch", cat="serve",
                requests=len(chunk), queued=len(prompts) - lo,
            ):
                out.extend(self._run_batch(chunk))
            if self._m_request_ns is not None:
                # a caller's latency is its batch's wall time (queueing
                # inside the batch included) — observe once per request
                dur = time.perf_counter_ns() - t0
                for _ in chunk:
                    self._m_request_ns.observe(dur)
                self._m_requests.inc(len(chunk))
            if self._m_queue is not None:
                self._m_queue.set(len(prompts) - lo - len(chunk))
        return out
