"""Minimal batched serving engine over the unified decode path.

Static batching: requests are grouped into fixed-size batches (one jit'd
``decode_step`` per token across the whole batch — the shape-static regime
the pod dry-run lowers). Prompts are left-aligned and stepped through the
cache (prefill-by-decode); finished rows are masked out. Greedy or
temperature sampling.

This is deliberately the simplest production-shaped server: the dry-run's
``decode_32k``/``long_500k`` shapes are exactly one step of this loop at
pod scale.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec
from repro.models import transformer as T


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 4
    max_new_tokens: int = 32
    cache_len: int = 256
    temperature: float = 0.0  # 0 -> greedy
    eos_id: Optional[int] = None
    seed: int = 0


class BatchedServer:
    def __init__(self, spec: ArchSpec, params, cfg: ServeConfig):
        assert spec.kind in ("lm", "vlm"), "LM-family archs only"
        self.spec = spec
        self.lm = spec.lm
        self.params = params
        self.cfg = cfg
        if self.lm.sliding_window:
            self.cache_len = min(cfg.cache_len, self.lm.sliding_window)
        else:
            self.cache_len = cfg.cache_len
        self._step = jax.jit(
            lambda p, c, t: T.decode_step(p, self.lm, c, t)
        )

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1
        ).astype(jnp.int32)

    def _run_batch(self, prompts: List[List[int]]) -> List[List[int]]:
        B = self.cfg.batch_size
        assert len(prompts) <= B
        pad = B - len(prompts)
        prompts = prompts + [[0]] * pad
        max_p = max(len(p) for p in prompts)
        cache = T.init_cache(self.lm, B, self.cache_len)
        key = jax.random.PRNGKey(self.cfg.seed)

        # prefill-by-decode, left-aligned (short prompts repeat last token;
        # their extra steps are overwritten by the first sampled token)
        logits = None
        for i in range(max_p):
            tok = np.array(
                [p[min(i, len(p) - 1)] for p in prompts], dtype=np.int32
            )[:, None]
            logits, cache = self._step(self.params, cache, jnp.asarray(tok))

        outs: List[List[int]] = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        for _ in range(self.cfg.max_new_tokens):
            key, sub = jax.random.split(key)
            nxt = np.asarray(self._sample(logits, sub))
            for b in range(B):
                if not done[b]:
                    outs[b].append(int(nxt[b]))
                    if self.cfg.eos_id is not None and nxt[b] == self.cfg.eos_id:
                        done[b] = True
            if done.all():
                break
            logits, cache = self._step(
                self.params, cache, jnp.asarray(nxt[:, None], jnp.int32)
            )
        return outs[: len(outs) - pad if pad else None]

    def generate(self, prompts: Sequence[Sequence[int]]) -> List[List[int]]:
        """Serve an arbitrary number of requests in fixed-size batches."""
        prompts = [list(p) for p in prompts]
        out: List[List[int]] = []
        B = self.cfg.batch_size
        for lo in range(0, len(prompts), B):
            out.extend(self._run_batch(prompts[lo : lo + B]))
        return out
