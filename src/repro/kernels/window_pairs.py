"""Pallas TPU kernel: skip-gram window-pair extraction from walk paths.

The pair stage of the fused on-device sampler (sampling/fused.py): a batch
of walks (B, L) becomes, per walk, the fixed set of in-window (src, dst)
column pairs (``sampling.pairs.window_positions``). Because the position
table is static, the whole stage is a gather of 2*npos fixed columns plus a
joint PAD-validity mask — pure VPU work on an int tile, no dynamic shapes.

Output layout: (B, npos) src ids and (B, npos) dst ids, with BOTH set to
PAD wherever either endpoint of the pair is PAD — so downstream selection
needs a single ``src != PAD`` test per candidate.

Tiling: grid (B/TB,); each step holds the (TB, L) path tile and the two
(TB, npos) output tiles in VMEM. L and npos are small (walk_len <= 32,
npos = O(walk_len * win)), so a generous TB still sits far under VMEM.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD = -1


def _window_pair_kernel(p_ref, src_ref, dst_ref, *, spos, dpos):
    x = p_ref[...]  # (TB, L) int32
    src = jnp.stack([x[:, c] for c in spos], axis=1)  # (TB, npos)
    dst = jnp.stack([x[:, c] for c in dpos], axis=1)
    valid = (src != PAD) & (dst != PAD)
    src_ref[...] = jnp.where(valid, src, PAD)
    dst_ref[...] = jnp.where(valid, dst, PAD)


def window_pair_ids_pallas(
    paths: jnp.ndarray,  # (B, L) int32 walk paths, PAD suffix after dead ends
    positions: Sequence[Tuple[int, int]],  # static (src_col, dst_col) table
    tile_b: int = 256,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, L) paths -> ((B, npos) src ids, (B, npos) dst ids), PAD-masked."""
    B, L = paths.shape
    spos = tuple(int(p[0]) for p in positions)
    dpos = tuple(int(p[1]) for p in positions)
    npos = len(spos)
    paths = paths.astype(jnp.int32)
    tb = min(tile_b, B)
    Bp = -(-B // tb) * tb
    if Bp != B:  # PAD rows produce PAD pairs and are sliced off below
        paths = jnp.pad(paths, ((0, Bp - B), (0, 0)), constant_values=PAD)
    out_shape = jax.ShapeDtypeStruct((Bp, npos), jnp.int32)
    src, dst = pl.pallas_call(
        functools.partial(_window_pair_kernel, spos=spos, dpos=dpos),
        grid=(Bp // tb,),
        in_specs=[pl.BlockSpec((tb, L), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tb, npos), lambda i: (i, 0)),
            pl.BlockSpec((tb, npos), lambda i: (i, 0)),
        ],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(paths)
    return src[:B], dst[:B]
