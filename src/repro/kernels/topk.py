"""Pallas TPU kernel: chunked matmul + streaming top-k (retrieval hot path).

Recall serving (U2I/UCF/ICF, paper §4.2) reduces to maximum-inner-product
search: score every query row against an item table and keep the K best.
Materializing the full (Q, I) similarity matrix is O(Q·I) HBM — 400 GB at
1M items × 100k users — so this kernel streams the item table through VMEM
in fixed chunks and carries a running (TQ, K) best-scores/best-ids state:
memory is O(TQ · (K + chunk)), independent of the item count.

Grid: (Q/TQ, I/chunk) with the chunk axis innermost. The output blocks for
a query tile map to the same (TQ, K) slab for every chunk step, so Pallas
keeps them VMEM-resident across the whole item sweep (the standard
revisited-output accumulation pattern); they double as the running state —
initialized at chunk 0, merged every step, final after the last chunk.

Merge-order tie-break contract (shared with the ``lax`` reference path and
the numpy oracle in ``repro.retrieval.topk``): on equal scores the lower
item id wins. The concatenation [running best | current chunk] preserves it
inductively — running entries hold earlier (smaller) ids and ``lax.top_k``
prefers the first occurrence of a tied value.

``exclude`` masking: each query row carries a padded id list (-1 = empty
slot); a chunk column whose global item id appears in the row's list scores
-inf. This is how retrieval drops a user's training history on-device.

On CPU (this container) the kernel runs with interpret=True; ``lax.top_k``
inside the body lowers to a sort on TPU Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# python float so the kernel body never captures a traced constant
NEG_INF = float("-inf")


def _topk_kernel(
    q_ref,  # (TQ, d)
    it_ref,  # (chunk, d)
    ex_ref,  # (TQ, E) excluded item ids, -1 padded
    os_ref,  # (TQ, K) running / final best scores
    oi_ref,  # (TQ, K) running / final best item ids
    *,
    k: int,
    chunk: int,
    num_items: int,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, NEG_INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    q = q_ref[...].astype(jnp.float32)
    it = it_ref[...].astype(jnp.float32)
    scores = jnp.dot(q, it.T, preferred_element_type=jnp.float32)  # (TQ, chunk)
    gid = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    scores = jnp.where(gid[None, :] < num_items, scores, NEG_INF)
    ex = ex_ref[...]  # (TQ, E)
    hit = (ex[:, :, None] == gid[None, None, :]).any(axis=1)  # (TQ, chunk)
    scores = jnp.where(hit, NEG_INF, scores)

    all_s = jnp.concatenate([os_ref[...], scores], axis=1)  # (TQ, K + chunk)
    all_i = jnp.concatenate(
        [oi_ref[...], jnp.broadcast_to(gid[None, :], scores.shape)], axis=1
    )
    best_s, pos = jax.lax.top_k(all_s, k)
    os_ref[...] = best_s
    oi_ref[...] = jnp.take_along_axis(all_i, pos, axis=1)


def chunked_topk_pallas(
    queries: jnp.ndarray,  # (Q, d)
    items: jnp.ndarray,  # (I, d)
    k: int,
    exclude: jnp.ndarray = None,  # (Q, E) int32, -1 padded; None -> no masking
    item_chunk: int = 1024,
    tile_q: int = 128,
    interpret: bool = False,
):
    """Streaming top-k MIPS: (Q, k) float32 scores + (Q, k) int32 item ids."""
    Q, d = queries.shape
    I = items.shape[0]
    if not 0 < k <= I:
        raise ValueError(f"k={k} must be in [1, num_items={I}]")
    tq = min(tile_q, Q)
    chunk = min(item_chunk, I)
    Qp = -(-Q // tq) * tq
    Ip = -(-I // chunk) * chunk
    if Qp != Q:
        queries = jnp.pad(queries, ((0, Qp - Q), (0, 0)))
    if Ip != I:
        items = jnp.pad(items, ((0, Ip - I), (0, 0)))
    if exclude is None:
        exclude = jnp.full((Qp, 1), -1, jnp.int32)
    else:
        exclude = jnp.asarray(exclude, jnp.int32)
        if exclude.shape[0] != Qp:
            exclude = jnp.pad(
                exclude, ((0, Qp - exclude.shape[0]), (0, 0)), constant_values=-1
            )
    E = exclude.shape[1]
    grid = (Qp // tq, Ip // chunk)
    scores, ids = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, chunk=chunk, num_items=I),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, c: (i, 0)),
            pl.BlockSpec((chunk, d), lambda i, c: (c, 0)),
            pl.BlockSpec((tq, E), lambda i, c: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, c: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, c: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, k), jnp.float32),
            jax.ShapeDtypeStruct((Qp, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, items, exclude)
    return scores[:Q], ids[:Q]
