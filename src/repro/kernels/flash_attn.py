"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

Causal + sliding-window GQA attention for prefill. The KV sequence is the
innermost ("arbitrary"-semantics, sequential) grid axis; running max / sum /
output accumulators live in VMEM scratch across KV steps. Sliding-window
support is what makes long-context prefill for Mixtral/StarCoder2 linear in
sequence length: out-of-band KV blocks are skipped entirely via pl.when.

Layouts: q (B, H, Sq, hd), k/v (B, K, Skv, hd) — heads-major so each grid
step addresses one (q-block, kv-block) pair of one head with hd-contiguous
lanes (MXU-aligned for hd in {64, 128}). The ops.py wrapper transposes from
the model's (B, S, H, hd) and maps GQA kv-head indices via the BlockSpec
index maps (h // group).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, bq: int, bk: int, scale: float, causal: bool, window: Optional[int], n_kv: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level reachability: any (q, k) pair in band?
    in_causal = (not causal) or (k_start <= q_start + bq - 1)
    if window is None:
        in_window = True
    else:
        in_window = k_start + bk - 1 > q_start - window

    @pl.when(jnp.logical_and(in_causal, in_window))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]  # (bq,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # (B, H, Sq, hd)
    k: jnp.ndarray,  # (B, K, Skv, hd)
    v: jnp.ndarray,  # (B, K, Skv, hd)
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    Skv = k.shape[2]
    G = H // K
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    grid = (B, H, Sq // bq, Skv // bk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, scale=1.0 / np.sqrt(hd),
        causal=causal, window=window, n_kv=Skv // bk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
            pltpu.VMEM((bq,), jnp.float32),  # running max
            pltpu.VMEM((bq,), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)
