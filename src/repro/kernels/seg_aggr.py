"""Pallas TPU kernel: masked segment aggregation (the GNN hot spot).

Relation-wise neighbor aggregation (Graph4Rec Eq. 1/3) reduces a
(N, F, D) block of gathered neighbor features over the fanout axis F under a
validity mask. On GPU this is a scatter/segment op; the TPU-native layout is
a *dense reduction over a VMEM-resident tile*: rows are padded to fixed
fanout at sampling time (sampling/ego.py), so the kernel is a masked
reduction with MXU/VPU-aligned tiles — no gather/scatter at all.

Tiling: grid (N/TN, D/TD); each step holds an (TN, F, TD) x-tile and the
(TN, F) mask tile in VMEM. F is small (4-32) by construction; TN*F*TD*4B
stays well under VMEM (default tiles: 8*32*256*4 = 256 KiB + headroom).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _seg_aggr_kernel(x_ref, m_ref, o_ref, *, mode: str):
    x = x_ref[...]  # (TN, F, TD)
    m = m_ref[...]  # (TN, F)
    mf = m.astype(x.dtype)[..., None]  # (TN, F, 1)
    if mode == "sum":
        o_ref[...] = (x * mf).sum(axis=1)
    elif mode == "mean":
        s = (x * mf).sum(axis=1)
        c = jnp.maximum(mf.sum(axis=1), 1.0)
        o_ref[...] = s / c
    elif mode == "max":
        neg = jnp.where(m[..., None], x, NEG_INF)
        out = neg.max(axis=1)
        any_valid = m.any(axis=1, keepdims=True)
        o_ref[...] = jnp.where(any_valid, out, 0.0)
    else:
        raise ValueError(mode)


def seg_aggr_pallas(
    x: jnp.ndarray,  # (N, F, D)
    mask: jnp.ndarray,  # (N, F) bool
    mode: str = "mean",
    tile_n: int = 8,
    tile_d: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    N, F, D = x.shape
    tn = min(tile_n, N)
    td = min(tile_d, D)
    # pad to tile multiples (masked rows contribute zeros)
    Np = -(-N // tn) * tn
    Dp = -(-D // td) * td
    if (Np, Dp) != (N, D):
        x = jnp.pad(x, ((0, Np - N), (0, 0), (0, Dp - D)))
        mask = jnp.pad(mask, ((0, Np - N), (0, 0)))
    grid = (Np // tn, Dp // td)
    out = pl.pallas_call(
        functools.partial(_seg_aggr_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, F, td), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tn, F), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tn, td), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Dp), x.dtype),
        interpret=interpret,
    )(x, mask)
    return out[:N, :D]
