"""Pallas TPU kernel: fused in-batch negative-sampling loss (paper §3.6/RQ4).

Computes, per row tile of P positives, the (TP, P) similarity block against
all in-batch destinations, a numerically-stable log-sum-exp, and the
diagonal positive score — in one VMEM pass, never materializing the P×P
logits in HBM. For P=8192, d=256 the logits would be 256 MiB in HBM; the
kernel streams them through VMEM in (TP, P) stripes instead.

Tiling: grid (P/TP,); each step holds the (TP, d) source tile plus the full
(P, d) destination block in VMEM (P*d*4B — up to ~8 MiB at P=8192, d=256;
larger batches would add a second grid axis with online LSE, not needed at
recsys batch sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _inbatch_kernel(src_ref, dst_ref, o_ref, *, temperature: float, tp: int, p_valid: int):
    i = pl.program_id(0)
    src = src_ref[...]  # (TP, d)
    dst = dst_ref[...]  # (P, d)
    logits = jnp.dot(
        src.astype(jnp.float32), dst.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    ) / temperature  # (TP, P)
    # mask padded columns
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < p_valid, logits, -1e30)
    m = logits.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.exp(logits - m).sum(axis=-1)) + m[:, 0]
    rows = i * tp + jax.lax.broadcasted_iota(jnp.int32, (tp, 1), 0)[:, 0]
    diag = jnp.take_along_axis(logits, rows[:, None], axis=1)[:, 0]
    o_ref[...] = lse - diag  # (TP,)


def inbatch_loss_rows_pallas(
    h_src: jnp.ndarray,  # (P, d)
    h_dst: jnp.ndarray,  # (P, d)
    temperature: float = 1.0,
    tile_p: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-row losses (P,). Mean-reduce (over valid rows) in the wrapper."""
    P, d = h_src.shape
    tp = min(tile_p, P)
    Pp = -(-P // tp) * tp
    if Pp != P:
        h_src = jnp.pad(h_src, ((0, Pp - P), (0, 0)))
        h_dst = jnp.pad(h_dst, ((0, Pp - P), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_inbatch_kernel, temperature=temperature, tp=tp, p_valid=P),
        grid=(Pp // tp,),
        in_specs=[
            pl.BlockSpec((tp, d), lambda i: (i, 0)),
            pl.BlockSpec((Pp, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=interpret,
    )(h_src, h_dst)
    return out[:P]
