"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Tests sweep shapes/dtypes and assert the kernels (interpret=True on CPU)
match these references.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ------------------------------------------------------------------ seg_aggr
def seg_aggr_ref(
    x: jnp.ndarray,  # (N, F, D) neighbor features
    mask: jnp.ndarray,  # (N, F) bool validity
    mode: str = "mean",
) -> jnp.ndarray:
    """Masked segment aggregation over the neighbor axis -> (N, D)."""
    m = mask[..., None].astype(x.dtype)
    if mode == "sum":
        return (x * m).sum(axis=1)
    if mode == "mean":
        s = (x * m).sum(axis=1)
        c = jnp.maximum(m.sum(axis=1), 1.0)
        return s / c
    if mode == "max":
        neg = jnp.where(mask[..., None], x, NEG_INF)
        out = neg.max(axis=1)
        any_valid = mask.any(axis=1, keepdims=True)
        return jnp.where(any_valid, out, 0.0)
    raise ValueError(mode)


# ---------------------------------------------------------- window pairs
def window_pair_ids_ref(
    paths: jnp.ndarray,  # (B, L) int paths, PAD = -1
    positions,  # static (npos, 2) (src_col, dst_col) table
):
    """Skip-gram pair gather oracle -> ((B, npos) src, (B, npos) dst)."""
    pos = np.asarray(positions, dtype=np.int64).reshape(-1, 2)
    paths = paths.astype(jnp.int32)
    src = paths[:, pos[:, 0]]
    dst = paths[:, pos[:, 1]]
    valid = (src != -1) & (dst != -1)
    return jnp.where(valid, src, -1), jnp.where(valid, dst, -1)


# -------------------------------------------------------------- inbatch loss
def inbatch_loss_ref(
    h_src: jnp.ndarray, h_dst: jnp.ndarray, temperature: float = 1.0
) -> jnp.ndarray:
    """In-batch softmax CE with diagonal positives -> scalar mean loss."""
    logits = (h_src @ h_dst.T).astype(jnp.float32) / temperature
    labels = jnp.arange(h_src.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    return (logz - logits[labels, labels]).mean()


def inbatch_loss_rows_ref(
    h_src: jnp.ndarray, h_dst: jnp.ndarray, temperature: float = 1.0
) -> jnp.ndarray:
    logits = (h_src @ h_dst.T).astype(jnp.float32) / temperature
    labels = jnp.arange(h_src.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    return logz - logits[labels, labels]


# -------------------------------------------------------------- attention
def attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, K, hd)
    v: jnp.ndarray,  # (B, Skv, K, hd)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """GQA attention oracle with causal and sliding-window masking."""
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = q.reshape(B, Sq, Kh, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) / np.sqrt(hd)
    qi = jnp.arange(Sq)[:, None] + q_offset
    ki = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    att = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", att, v)
    return out.reshape(B, Sq, H, hd)


# kernels/flash_attn.py exports the same attention contract under the flash
# name; the oracle is identical.
flash_attention_ref = attention_ref


# ----------------------------------------------------------------- topk MIPS
def chunked_topk_ref(
    queries: jnp.ndarray,  # (Q, d)
    items: jnp.ndarray,  # (I, d)
    k: int,
    exclude: Optional[jnp.ndarray] = None,  # (Q, E) int32, -1 padded
):
    """Dense top-k MIPS oracle -> ((Q, k) f32 scores, (Q, k) i32 ids).

    Tie-break matches the streaming kernel: on equal scores the lower item
    id wins (``lax.top_k`` keeps the first occurrence and ids ascend).
    """
    scores = jnp.dot(
        queries.astype(jnp.float32),
        items.astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )  # (Q, I)
    if exclude is not None:
        gid = jnp.arange(items.shape[0], dtype=jnp.int32)
        hit = (exclude[:, :, None] == gid[None, None, :]).any(axis=1)
        scores = jnp.where(hit, float("-inf"), scores)
    best_s, best_i = jax.lax.top_k(scores, k)
    return best_s, best_i.astype(jnp.int32)


# ------------------------------------------------------------ IVF list topk
def ivf_list_topk_ref(
    queries: jnp.ndarray,  # (Q, d) float32
    codes: jnp.ndarray,  # (Ip, d) int8 cell-sorted quantized rows (DMA-padded)
    scales: jnp.ndarray,  # (Ip, 1) float32 per-row dequant scales
    starts: jnp.ndarray,  # (Q, P) int32 packed-row offset of each probed list
    lengths: jnp.ndarray,  # (Q, P) int32 true list lengths
    *,
    lpad: int,  # max list length: the fixed slice width gathered per probe
    shortlist: int,  # survivors kept per query (S)
    batch_size: int = 32,
):
    """Gather-then-score over CSR inverted lists -> per-query shortlist.

    For each (query, probe): slice ``lpad`` packed rows at ``starts``,
    dequantize (asymmetric distance: f32 query x int8 codes x per-row
    scale), mask slots past ``lengths`` to -inf, and keep the ``shortlist``
    best across all probes. Returns ((Q, S) f32 approx scores, (Q, S) i32
    packed-row indices, -1 for empty slots).

    Tie-break: candidates rank in flat (probe, within-list) order and
    ``lax.top_k`` keeps the first occurrence — the same order the Pallas
    kernel's [running | new chunk] merge preserves inductively. Lists
    longer than ``lpad`` are truncated to ``lpad`` entries (the builder
    guarantees ``lengths <= lpad``).

    This is also the production XLA path on non-TPU backends (``lax.map``
    over ``batch_size`` query blocks bounds the gather working set), not
    just the kernel oracle.
    """
    off = jnp.arange(lpad, dtype=jnp.int32)

    def one(args):
        q, st, ln = args  # (d,), (P,), (P,)
        rows = st[:, None] + off[None, :]  # (P, lpad)
        valid = off[None, :] < ln[:, None]
        safe = jnp.where(valid, rows, 0)
        c = codes[safe].astype(jnp.float32)  # (P, lpad, d)
        sc = scales[safe][..., 0]  # (P, lpad)
        s = jnp.einsum("pld,d->pl", c, q.astype(jnp.float32)) * sc
        s = jnp.where(valid, s, float("-inf")).reshape(-1)
        r = jnp.where(valid, rows, -1).reshape(-1)
        best, pos = jax.lax.top_k(s, shortlist)
        return best, r[pos]
    return jax.lax.map(
        one, (queries, starts, lengths),
        batch_size=min(batch_size, queries.shape[0]),
    )


# ------------------------------------------------------------- row adagrad
def row_adagrad_scatter_ref(
    table: jnp.ndarray,  # (N, D)
    accum: jnp.ndarray,  # (N, 1)
    ids: jnp.ndarray,  # (bucket,) int; PADs (-1) allowed, real ids distinct
    grads: jnp.ndarray,  # (bucket, D)
    lr: float = 0.1,
    eps: float = 1e-8,
):
    """Gather/row-AdaGrad/scatter oracle -> updated (table, accum).

    PAD slots (id < 0) are dropped; rows not named in ``ids`` pass through.
    """
    N = table.shape[0]
    ids = ids.astype(jnp.int32)
    rows = jnp.where(ids >= 0, ids, N)  # OOB -> dropped at scatter
    safe = jnp.maximum(ids, 0)
    g = grads
    new_acc = accum[safe] + jnp.mean(g * g, axis=-1, keepdims=True).astype(
        accum.dtype
    )
    new_row = (table[safe] - lr * g / (jnp.sqrt(new_acc) + eps)).astype(table.dtype)
    return (
        table.at[rows].set(new_row, mode="drop"),
        accum.at[rows].set(new_acc, mode="drop"),
    )
