"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with interpret=True — the kernel
body runs in Python on CPU, validating the exact program that lowers to TPU.
On a TPU backend interpret is off and the kernels compile to Mosaic.

``inbatch_loss`` carries a custom VJP (softmax-CE closed-form gradients in
jnp) so the fused forward is usable inside ``jax.grad`` training steps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.inbatch_loss import inbatch_loss_rows_pallas
from repro.kernels.ivf import ivf_list_topk_pallas
from repro.kernels.row_adagrad import row_adagrad_scatter_pallas
from repro.kernels.seg_aggr import seg_aggr_pallas
from repro.kernels.topk import chunked_topk_pallas
from repro.kernels.window_pairs import window_pair_ids_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------- retrieval
def streaming_topk(
    queries: jnp.ndarray,
    items: jnp.ndarray,
    k: int,
    exclude: Optional[jnp.ndarray] = None,
    item_chunk: int = 1024,
    tile_q: int = 128,
):
    """Chunked-matmul streaming top-k (kernels/topk.py): O(chunk) memory
    maximum-inner-product search. Returns ((Q, k) f32 scores, (Q, k) i32 ids);
    same tie-break contract as ``repro.retrieval.topk``."""
    return chunked_topk_pallas(
        queries, items, k, exclude=exclude, item_chunk=item_chunk,
        tile_q=tile_q, interpret=_interpret(),
    )


def ivf_list_topk(
    queries: jnp.ndarray,
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    starts: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    lpad: int,
    shortlist: int,
):
    """IVF gather-then-score over CSR inverted lists (kernels/ivf.py):
    scalar-prefetched list offsets drive per-probe HBM->VMEM DMAs of the
    int8 code table. Returns ((Q, S) f32 approx scores, (Q, S) i32
    packed-row indices); contract matches ``ref.ivf_list_topk_ref``. Called
    from inside ``retrieval.ivf``'s jitted search, so no jit wrapper here.
    """
    return ivf_list_topk_pallas(
        queries, codes, scales, starts, lengths,
        lpad=lpad, shortlist=shortlist, interpret=_interpret(),
    )


# ------------------------------------------------------------ window pairs
def window_pair_ids(paths: jnp.ndarray, positions):
    """(B, L) walk paths -> ((B, npos) src, (B, npos) dst) skip-gram pairs.

    ``positions`` is the static (src_col, dst_col) table from
    ``sampling.pairs.window_positions``; pairs touching a PAD node come back
    with BOTH sides PAD. Called from inside the fused sampler's jitted
    program, so no jit wrapper here.
    """
    return window_pair_ids_pallas(paths, positions, interpret=_interpret())


# ------------------------------------------------------------- row adagrad
def rowwise_adagrad_scatter(
    table: jnp.ndarray,
    accum: jnp.ndarray,
    ids: jnp.ndarray,
    grads: jnp.ndarray,
    lr: float = 0.1,
    eps: float = 1e-8,
):
    """Fused gather/row-wise-AdaGrad/scatter over the touched rows.

    ``ids`` follows the unique-bucket layout (PADs first; see
    embedding.table.unique_pad_ids). Called from inside the trainer's jitted
    sparse step, so no jit wrapper here.
    """
    return row_adagrad_scatter_pallas(
        table, accum, ids, grads, lr=lr, eps=eps, interpret=_interpret()
    )


# ------------------------------------------------------------------ seg_aggr
@functools.partial(jax.jit, static_argnames=("mode",))
def seg_aggr(x: jnp.ndarray, mask: jnp.ndarray, mode: str = "mean") -> jnp.ndarray:
    """(N, F, D), (N, F) -> (N, D) masked segment aggregation."""
    return seg_aggr_pallas(x, mask, mode=mode, interpret=_interpret())


# -------------------------------------------------------------- inbatch loss
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def inbatch_loss(h_src: jnp.ndarray, h_dst: jnp.ndarray, temperature: float = 1.0):
    rows = inbatch_loss_rows_pallas(
        h_src, h_dst, temperature=temperature, interpret=_interpret()
    )
    return rows.mean()


def _inbatch_fwd(h_src, h_dst, temperature):
    return inbatch_loss(h_src, h_dst, temperature), (h_src, h_dst)


def _inbatch_bwd(temperature, res, g):
    h_src, h_dst = res
    P = h_src.shape[0]
    logits = (h_src @ h_dst.T).astype(jnp.float32) / temperature
    soft = jax.nn.softmax(logits, axis=-1)
    dlogits = (soft - jnp.eye(P)) * (g / (P * temperature))
    dsrc = (dlogits @ h_dst.astype(jnp.float32)).astype(h_src.dtype)
    ddst = (dlogits.T @ h_src.astype(jnp.float32)).astype(h_dst.dtype)
    return dsrc, ddst


inbatch_loss.defvjp(_inbatch_fwd, _inbatch_bwd)


# ---------------------------------------------------------------- attention
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, hd) — model layout
    k: jnp.ndarray,  # (B, S, K, hd)
    v: jnp.ndarray,  # (B, S, K, hd)
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Flash attention in the model's (B, S, H, hd) layout."""
    qh = jnp.swapaxes(q, 1, 2)  # (B, H, S, hd)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out = flash_attention_pallas(
        qh, kh, vh, causal=causal, window=window, interpret=_interpret()
    )
    return jnp.swapaxes(out, 1, 2)
