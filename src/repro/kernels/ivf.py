"""Pallas TPU kernel: IVF gather-then-score over packed inverted lists.

The IVF search hot loop is "for each (query, probed cell): fetch that
cell's packed quantized rows, score them against the query, fold into the
query's running shortlist". The host-loop version of that is exactly the
retrieval bug this kernel exists to kill: the list offsets live in scalar
memory (``PrefetchScalarGridSpec``), so each grid step DMAs its own
``lpad``-row slice of the int8 code table straight from HBM into a VMEM
scratch buffer — no per-call upload, no dense (nlist, max_len) padding, no
(Q, C, d) candidate tensor.

Grid: (Q, nprobe) with the probe axis innermost. The (1, S) output blocks
for a query map to the same slab for every probe step (the revisited-output
accumulation pattern shared with kernels/topk.py): initialized at probe 0,
merged every step, final after the last probe. Scoring is asymmetric: f32
query x int8 codes x per-row f32 dequant scale — the codes stay int8 in
HBM and VMEM, and only the ``lpad x d`` working slice is ever dequantized.

Tie-break contract: the [running | new chunk] concatenation ranks
candidates in flat (probe, within-list) order and ``lax.top_k`` keeps the
first occurrence of a tied value — identical to ``ref.ivf_list_topk_ref``'s
flat top-k (the conformance oracle). The exact re-rank stage above this
kernel re-sorts survivors by item id, so the end-to-end lower-id-wins
contract never depends on probe order.

On CPU (this container) the kernel runs with interpret=True; on TPU the
async copies become real HBM->VMEM DMAs overlapped with the VPU scoring of
the previous probe's slice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# python float so the kernel body never captures a traced constant
NEG_INF = float("-inf")


def _ivf_list_kernel(
    starts_ref,  # (Q, P) scalar-prefetch: packed-row offset per (query, probe)
    lens_ref,  # (Q, P) scalar-prefetch: true list length per (query, probe)
    q_ref,  # (1, d) query block
    codes_ref,  # (Ip, d) int8 code table, HBM/ANY
    scales_ref,  # (Ip, 1) f32 dequant scales, HBM/ANY
    os_ref,  # (1, S) running / final shortlist scores
    or_ref,  # (1, S) running / final shortlist packed-row indices
    codes_vmem,  # (lpad, d) int8 scratch: the DMA landing slab
    scales_vmem,  # (lpad, 1) f32 scratch
    csem,
    ssem,
    *,
    lpad: int,
    shortlist: int,
):
    qi = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, NEG_INF)
        or_ref[...] = jnp.full_like(or_ref, -1)

    start = starts_ref[qi, p]
    ln = lens_ref[qi, p]
    ccp = pltpu.make_async_copy(
        codes_ref.at[pl.ds(start, lpad), :], codes_vmem, csem
    )
    scp = pltpu.make_async_copy(
        scales_ref.at[pl.ds(start, lpad), :], scales_vmem, ssem
    )
    ccp.start()
    scp.start()
    q = q_ref[...].astype(jnp.float32)[0]  # (d,)
    ccp.wait()
    scp.wait()
    # asymmetric distance: f32 query x int8 codes, per-row dequant scale
    raw = jnp.dot(
        codes_vmem[...].astype(jnp.float32), q, preferred_element_type=jnp.float32
    )  # (lpad,)
    scores = raw * scales_vmem[...][:, 0]
    off = jax.lax.broadcasted_iota(jnp.int32, (lpad,), 0)
    valid = off < ln
    scores = jnp.where(valid, scores, NEG_INF)
    rows = jnp.where(valid, start + off, -1)

    all_s = jnp.concatenate([os_ref[0, :], scores])  # (S + lpad,)
    all_r = jnp.concatenate([or_ref[0, :], rows])
    best, pos = jax.lax.top_k(all_s, shortlist)
    os_ref[...] = best[None]
    or_ref[...] = jnp.take(all_r, pos)[None]


def ivf_list_topk_pallas(
    queries: jnp.ndarray,  # (Q, d) float32
    codes: jnp.ndarray,  # (Ip, d) int8; Ip >= max(starts) + lpad (DMA pad)
    scales: jnp.ndarray,  # (Ip, 1) float32
    starts: jnp.ndarray,  # (Q, P) int32
    lengths: jnp.ndarray,  # (Q, P) int32, <= lpad
    *,
    lpad: int,
    shortlist: int,
    interpret: bool = False,
):
    """Scalar-prefetch-driven gather-then-score -> per-query shortlist.

    Returns ((Q, S) f32 approx scores, (Q, S) i32 packed-row indices, -1
    for empty slots). Contract matches ``ref.ivf_list_topk_ref`` exactly;
    the builder guarantees the code table carries ``lpad`` rows of zero
    padding so the fixed-width DMA slice never reads out of bounds.
    """
    Q, d = queries.shape
    P = starts.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, d), lambda qi, p, s_ref, l_ref: (qi, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, shortlist), lambda qi, p, s_ref, l_ref: (qi, 0)),
            pl.BlockSpec((1, shortlist), lambda qi, p, s_ref, l_ref: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((lpad, d), codes.dtype),
            pltpu.VMEM((lpad, 1), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    out_s, out_r = pl.pallas_call(
        functools.partial(_ivf_list_kernel, lpad=lpad, shortlist=shortlist),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, shortlist), jnp.float32),
            jax.ShapeDtypeStruct((Q, shortlist), jnp.int32),
        ],
        interpret=interpret,
    )(starts.astype(jnp.int32), lengths.astype(jnp.int32), queries, codes, scales)
    return out_s, out_r
