from repro.kernels import ref
from repro.kernels.row_adagrad import row_adagrad_scatter_pallas
