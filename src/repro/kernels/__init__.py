from repro.kernels import ref
