"""Pallas TPU kernel: fused gather-rows / row-wise AdaGrad / scatter-rows.

The sparse training step's optimizer tail is three row-indexed passes in XLA
(gather param+accum rows, apply the row-wise rule, scatter both back). This
kernel fuses them into one pass over the touched rows: grid step i reads the
scalar-prefetched ``ids[i]``, whose value drives the BlockSpec index maps so
the (1, dim) parameter row and (1, 1) accumulator row stream through VMEM,
the VPU applies AdaGrad against the matching gradient row, and input/output
aliasing writes the result back onto the same rows in place — no
O(num_rows) traffic and no separate gather/scatter kernels.

PAD handling: PAD slots (id < 0) clamp to row 0 and write the row back
*unchanged*. ``embedding.table.unique_pad_ids`` orders PADs first, so under
the sequential TPU grid every no-op PAD write of row 0 lands before row 0's
real update (row 0 is the only row two grid steps can touch; real ids are
distinct by construction) — the final table state is exact.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _row_adagrad_kernel(ids_ref, g_ref, t_ref, a_ref, ot_ref, oa_ref, *, lr, eps):
    i = pl.program_id(0)
    valid = ids_ref[i] >= 0
    g = g_ref[...]  # (1, D)
    row = t_ref[...]  # (1, D)
    acc = a_ref[...]  # (1, 1)
    new_acc = acc + jnp.mean(g * g, axis=-1, keepdims=True)
    new_row = row - lr * g / (jnp.sqrt(new_acc) + eps)
    ot_ref[...] = jnp.where(valid, new_row, row)
    oa_ref[...] = jnp.where(valid, new_acc, acc)


def row_adagrad_scatter_pallas(
    table: jnp.ndarray,  # (N, D)
    accum: jnp.ndarray,  # (N, 1)
    ids: jnp.ndarray,  # (bucket,) int; PADs (-1) first, then distinct rows
    grads: jnp.ndarray,  # (bucket, D) grads w.r.t. the gathered rows
    lr: float = 0.1,
    eps: float = 1e-8,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ``accum[ids] += mean(g**2); table[ids] -= lr*g/sqrt(accum[ids])``.

    Returns the updated (table, accum). Rows not named in ``ids`` pass
    through untouched (aliasing), so callers treat this exactly like the
    XLA gather/update/scatter sequence it replaces.
    """
    N, D = table.shape
    bucket = ids.shape[0]
    ids = ids.astype(jnp.int32)

    def _row(i, ids_ref):  # PAD clamps to row 0; the kernel masks its write
        return (jnp.maximum(ids_ref[i], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bucket,),
        in_specs=[
            pl.BlockSpec((1, D), lambda i, ids_ref: (i, 0)),  # grads
            pl.BlockSpec((1, D), _row),  # table rows
            pl.BlockSpec((1, 1), _row),  # accum rows
        ],
        out_specs=[
            pl.BlockSpec((1, D), _row),
            pl.BlockSpec((1, 1), _row),
        ],
    )
    new_table, new_accum = pl.pallas_call(
        functools.partial(_row_adagrad_kernel, lr=lr, eps=eps),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((N, D), table.dtype),
            jax.ShapeDtypeStruct((N, 1), accum.dtype),
        ],
        # operand indices include the scalar-prefetch arg: 2=table, 3=accum
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(ids, grads, table, accum)
    return new_table, new_accum
