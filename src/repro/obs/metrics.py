"""Metrics registry: counters, gauges, fixed-bucket histograms.

The aggregate half of the telemetry layer (spans are the timeline half):
cheap thread-safe scalar instruments the trainer, ``GraphClient``, and
retrieval paths update on their hot paths *only when telemetry is enabled*
— disabled call sites hold ``None`` and pay one ``is None`` test.

Histograms use **fixed** bucket boundaries chosen at construction (the
default is a 1-2-5 ladder from 1 µs to 50 s in nanoseconds), so ``observe``
is a bisect + one counter increment — no per-sample allocation, no
unbounded reservoir. Percentiles interpolate linearly inside the selected
bucket (values below the first boundary interpolate from 0; the overflow
bucket reports its lower edge), which is the standard fixed-bucket estimate:
deterministic, bounded error of one bucket width, and pinned exactly by
``tests/test_obs.py``.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Sequence, Tuple

# 1-2-5 ladder, 1 µs .. 50 s, in nanoseconds: round-latency scales from a
# hybrid local round (~10 µs) to a pickle-fallback mp round (~100 ms) all
# land mid-ladder with <= one-bucket relative error.
DEFAULT_NS_BUCKETS: Tuple[int, ...] = tuple(
    m * 10 ** e for e in range(3, 11) for m in (1, 2, 5)
)


class Counter:
    """Monotonic event counter."""

    __slots__ = ("name", "_lock", "_n")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._n = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._n += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._n


class Gauge:
    """Last-value instrument; also tracks the high-water mark."""

    __slots__ = ("name", "_lock", "_value", "_max", "_set")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = 0.0
        self._set = False

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)
            self._max = self._value if not self._set else max(self._max, self._value)
            self._set = True

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    @property
    def max(self) -> float:
        with self._lock:
            return self._max


class Histogram:
    """Fixed-bucket histogram with interpolated percentiles.

    Bucket ``i`` covers ``(bounds[i-1], bounds[i]]`` (bucket 0 starts at 0);
    one extra overflow bucket catches values above the last boundary.
    """

    __slots__ = ("name", "bounds", "_lock", "_counts", "_sum")

    def __init__(self, name: str, buckets: Sequence[float] = DEFAULT_NS_BUCKETS):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError("histogram buckets must be non-empty and ascending")
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self._counts[i] += 1
            self._sum += value

    @property
    def count(self) -> int:
        with self._lock:
            return sum(self._counts)

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, p: float) -> float:
        """Interpolated percentile estimate (``p`` in [0, 100])."""
        with self._lock:
            counts = list(self._counts)
        total = sum(counts)
        if total == 0:
            return 0.0
        rank = (p / 100.0) * total
        cum = 0
        for i, c in enumerate(counts):
            if c and cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else lo
                if hi <= lo:  # overflow bucket: report its lower edge
                    return lo
                return lo + (max(rank - cum, 0.0) / c) * (hi - lo)
            cum += c
        return self.bounds[-1]

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": round(self.sum, 3),
            "p50": round(self.percentile(50.0), 3),
            "p99": round(self.percentile(99.0), 3),
        }


class MetricsRegistry:
    """Name-keyed get-or-create registry for the three instrument kinds."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            got = self._counters.get(name)
            if got is None:
                got = self._counters[name] = Counter(name)
            return got

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            got = self._gauges.get(name)
            if got is None:
                got = self._gauges[name] = Gauge(name)
            return got

    def histogram(
        self, name: str, buckets: Sequence[float] = DEFAULT_NS_BUCKETS
    ) -> Histogram:
        with self._lock:
            got = self._histograms.get(name)
            if got is None:
                got = self._histograms[name] = Histogram(name, buckets)
            return got

    def summary(self) -> Dict[str, Dict]:
        """JSON-ready snapshot of every registered instrument."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {
                n: {"value": g.value, "max": g.max}
                for n, g in sorted(gauges.items())
            },
            "histograms": {
                n: h.summary() for n, h in sorted(histograms.items())
            },
        }
