"""Chrome trace-event JSON export + text summary for the telemetry layer.

``chrome_trace`` renders a :class:`~repro.obs.trace.Tracer` (plus an
optional :class:`~repro.obs.metrics.MetricsRegistry`) as a Chrome
trace-event JSON object — the format Perfetto (https://ui.perfetto.dev)
and ``chrome://tracing`` load directly:

- every span becomes one complete ("X") event with microsecond ``ts`` /
  ``dur`` (span timestamps are ``perf_counter_ns``; the exporter divides by
  1000),
- each recording thread gets its own ``tid`` track inside the tracer's
  process (``pid``), named via "M" (metadata) events,
- spans ingested from other processes (graph-service workers) keep their
  own ``pid`` tracks, so a traced mp run shows the trainer's threads and
  every worker side by side on one clock-corrected timeline, and a worker
  serve span lines up under the client round that issued it (correlate by
  the ``rid`` in ``args``).

``text_summary`` is the terminal rendering: per-track span aggregates plus
the metrics registry snapshot — the quick look before reaching for
Perfetto.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


def _meta(pid: int, tid: int, name: str, value: str) -> Dict:
    return {
        "ph": "M", "pid": pid, "tid": tid, "ts": 0,
        "name": name, "args": {"name": value},
    }


def trace_events(tracer: Tracer) -> List[Dict]:
    """Flatten a tracer into a Chrome trace-event list."""
    events: List[Dict] = [_meta(tracer.pid, 0, "process_name", tracer.process_name)]
    for tid, thread_name, spans, _dropped in tracer.threads():
        events.append(_meta(tracer.pid, tid, "thread_name", thread_name))
        for name, cat, t0, dur, args in spans:
            ev = {
                "ph": "X", "pid": tracer.pid, "tid": tid, "name": name,
                "cat": cat, "ts": t0 / 1e3, "dur": dur / 1e3,
            }
            if args:
                ev["args"] = args
            events.append(ev)
    # instant ("i") events: warning-path marks, drawn process-wide so a
    # degraded run is visible at any zoom level
    for name, cat, t0, args in tracer.marks():
        ev = {
            "ph": "i", "s": "p", "pid": tracer.pid, "tid": 0,
            "name": name, "cat": cat, "ts": t0 / 1e3,
        }
        if args:
            ev["args"] = args
        events.append(ev)
    for process_name, pid, spans, _dropped in tracer.foreign():
        events.append(_meta(pid, 0, "process_name", process_name))
        events.append(_meta(pid, 1, "thread_name", "serve"))
        for name, cat, t0, dur, args in spans:
            ev = {
                "ph": "X", "pid": pid, "tid": 1, "name": name,
                "cat": cat, "ts": t0 / 1e3, "dur": dur / 1e3,
            }
            if args:
                ev["args"] = args
            events.append(ev)
    return events


def chrome_trace(
    tracer: Tracer, metrics: Optional[MetricsRegistry] = None
) -> Dict:
    """The loadable trace object ({"traceEvents": [...], ...})."""
    out: Dict = {
        "traceEvents": trace_events(tracer),
        "displayTimeUnit": "ms",
    }
    other: Dict = {"dropped_spans": tracer.dropped_count()}
    if metrics is not None:
        other["metrics"] = metrics.summary()
    out["otherData"] = other
    return out


def write_trace(
    path: str, tracer: Tracer, metrics: Optional[MetricsRegistry] = None
) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer, metrics), f, indent=1)
        f.write("\n")
    return path


def text_summary(
    tracer: Tracer, metrics: Optional[MetricsRegistry] = None
) -> str:
    """Terminal rendering: per-track span aggregates + metrics snapshot."""
    lines: List[str] = ["telemetry summary"]
    tracks = [
        (f"{tracer.process_name}/{tname}", spans, dropped)
        for _tid, tname, spans, dropped in tracer.threads()
    ] + [
        (f"{pname}(pid {pid})/serve", spans, dropped)
        for pname, pid, spans, dropped in tracer.foreign()
    ]
    for track, spans, dropped in tracks:
        agg: Dict[str, List[float]] = {}
        for name, _cat, _t0, dur, _args in spans:
            agg.setdefault(name, []).append(dur)
        note = f" (dropped {dropped})" if dropped else ""
        lines.append(f"  [{track}] {len(spans)} spans{note}")
        for name in sorted(agg):
            durs = agg[name]
            tot = sum(durs)
            lines.append(
                f"    {name:<24} x{len(durs):<6} total {tot / 1e6:>10.2f}ms"
                f"  mean {tot / len(durs) / 1e3:>9.1f}us"
            )
    if metrics is not None:
        snap = metrics.summary()
        if snap["counters"]:
            lines.append("  counters:")
            for name, v in snap["counters"].items():
                lines.append(f"    {name:<32} {v}")
        if snap["gauges"]:
            lines.append("  gauges (last/max):")
            for name, g in snap["gauges"].items():
                lines.append(f"    {name:<32} {g['value']:g}/{g['max']:g}")
        if snap["histograms"]:
            lines.append("  histograms (count, p50, p99):")
            for name, h in snap["histograms"].items():
                lines.append(
                    f"    {name:<32} n={h['count']} p50={h['p50'] / 1e6:.3f}ms"
                    f" p99={h['p99'] / 1e6:.3f}ms"
                )
    return "\n".join(lines)
