"""Device-memory accounting: per-phase high-water gauges over live arrays.

The fused-sampling budget gate (``fused_budget_mb``) has so far run on an
*estimate* — ``fused_device_bytes`` multiplies shapes before anything is
resident. This module closes the loop with two measured sources:

- :func:`live_array_bytes` — ``jax.live_arrays()`` summed by ``.nbytes``:
  every array the process currently holds alive on any device. Exact on
  all backends (CPU included), but enumeration walks a global registry,
  so it is a *phase-boundary* probe, never a per-step one.
- :func:`device_memory_stats` — the backend allocator's own counters
  (``device.memory_stats()``), which exist on real accelerators and
  return ``None`` on the CPU backend; gated, never required.

:class:`MemoryAccountant` samples those at coarse lifecycle boundaries
(tables built, fused adjacency resident, steady-state loop, eval) into
``memory.<phase>_bytes`` gauges whose high-water mark is the per-phase
peak, and ``summary()`` feeds the ``memory`` section of
``BENCH_throughput.json``. The trainer separately asks the
``FusedSampler`` for its *actual* device-table footprint
(``device_table_bytes()`` — the sum of the resident adjacency/schedule/
slot arrays) and re-runs ``fused_eligibility`` on the measured number, so
the budget decision is logged against bytes that exist rather than bytes
that were predicted.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional

from repro.utils import get_logger

log = get_logger("repro.obs.memory")


def live_array_bytes() -> int:
    """Total bytes of every live JAX array in this process (all devices).

    Returns 0 when JAX is unavailable or the registry walk fails — memory
    accounting is advisory and must never take a run down.
    """
    try:
        import jax

        return int(sum(int(a.nbytes) for a in jax.live_arrays()))
    except Exception as e:
        log.debug("live_array_bytes unavailable: %s", e)
        return 0


def device_memory_stats() -> Dict[str, Dict[str, int]]:
    """Allocator statistics per device, ``{} `` where unsupported.

    Real accelerator backends report dicts like ``{"bytes_in_use": ...,
    "peak_bytes_in_use": ...}``; the CPU backend returns ``None`` from
    ``memory_stats()`` and contributes nothing here.
    """
    out: Dict[str, Dict[str, int]] = {}
    try:
        import jax

        for dev in jax.devices():
            try:
                stats = dev.memory_stats()
            except Exception:
                stats = None
            if stats:
                out[str(dev)] = {
                    k: int(v) for k, v in stats.items()
                    if isinstance(v, (int, float))
                }
    except Exception as e:
        log.debug("device_memory_stats unavailable: %s", e)
    return out


def memory_snapshot() -> Dict:
    """One point-in-time reading: live-array total + allocator stats."""
    return {
        "live_array_bytes": live_array_bytes(),
        "device_stats": device_memory_stats(),
    }


class MemoryAccountant:
    """Phase-boundary high-water memory sampling.

    ``sample(phase)`` reads the live-array total, folds it into the
    per-phase peak, and (when a registry is wired) sets the
    ``memory.<phase>_bytes`` gauge — whose ``.max`` is then the phase's
    high-water mark across the run. ``scope(phase)`` samples on exit, the
    natural fit for ``span_scope``-bracketed regions.
    """

    def __init__(self, metrics=None):
        self._metrics = metrics
        self.peaks: Dict[str, int] = {}

    def sample(self, phase: str) -> int:
        n = live_array_bytes()
        if n > self.peaks.get(phase, -1):
            self.peaks[phase] = n
        if self._metrics is not None:
            self._metrics.gauge(f"memory.{phase}_bytes").set(n)
        return n

    @contextlib.contextmanager
    def scope(self, phase: str):
        """Sample at region exit — the footprint once the phase's arrays
        are resident (entry readings just repeat the previous phase)."""
        try:
            yield self
        finally:
            self.sample(phase)

    def summary(self) -> Dict:
        """The ``memory`` section: per-phase peaks + a final snapshot."""
        out: Dict = {"phase_peak_bytes": dict(self.peaks)}
        out.update(memory_snapshot())
        return out


def sample_scope(accountant: Optional[MemoryAccountant], phase: str):
    """Null-safe ``accountant.scope``: no accountant, no cost."""
    if accountant is None:
        return contextlib.nullcontext()
    return accountant.scope(phase)
