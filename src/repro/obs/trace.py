"""Ring-buffered cross-process span tracing (the telemetry tentpole's core).

A ``Tracer`` records *spans* — ``(name, category, start_ns, duration_ns,
args)`` tuples on the ``time.perf_counter_ns`` clock — into fixed-capacity
per-thread ring buffers. The design constraints mirror
``train/attribution.py``'s (they now share this layer):

- **Sync-free, allocation-bounded hot path.** Each recording thread owns
  one preallocated ring; an append is two list/int operations with no lock
  (single writer per ring — the tracer lock is taken only once per thread,
  at ring creation). Memory is bounded by ``capacity`` spans per thread;
  overflow overwrites the oldest spans and is reported as a drop count,
  never an allocation.
- **Zero-cost when disabled.** Disabled telemetry is the *absence* of a
  tracer (``telemetry=None`` everywhere); instrumented call sites thread
  one optional object and pay a single ``is None`` test. ``span_scope``
  returns a shared ``nullcontext`` for that case.
- **Monotonic clocks only.** Spans are timestamped with
  ``perf_counter_ns`` — never ``time.time()``, which NTP can step
  mid-interval (lint rule O001 enforces this across the instrumented
  modules).

Cross-process spans: graph-service workers record their serve loop into a
plain local ring (worker.py — no obs import, workers stay numpy-only) and
ship the tuples back piggybacked on the ``stats`` control round. The client
feeds them to :meth:`Tracer.ingest` with a clock offset estimated from the
round-trip midpoint (``offset = worker_clock - (t0 + t1) / 2``), correcting
each worker's ``perf_counter_ns`` epoch into the client's timebase so the
exported timeline lines up across processes. Spans carry the request ``rid``
in ``args``, which is what correlates a worker serve span with the client
round that issued it.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# (name, category, start_ns, duration_ns, args-or-None)
Span = Tuple[str, str, int, int, Optional[Dict]]


class DurationRing:
    """Fixed-capacity ring of float durations with count-extrapolated totals.

    The storage primitive behind ``PhaseTimer``: long runs stay O(capacity)
    memory, and :meth:`total` scales the retained window back up by the true
    count so totals remain unbiased estimates.
    """

    __slots__ = ("_cap", "_buf", "_n")

    def __init__(self, capacity: int):
        self._cap = int(capacity)
        self._buf = np.zeros(self._cap, np.float64)
        self._n = 0

    def add(self, value: float) -> None:
        self._buf[self._n % self._cap] = value
        self._n += 1

    @property
    def count(self) -> int:
        return self._n

    def total(self) -> float:
        """Sum of all recorded values (ring window extrapolated by count)."""
        if self._n == 0:
            return 0.0
        kept = min(self._n, self._cap)
        return float(self._buf[:kept].sum()) * (self._n / kept)


class _SpanRing:
    """One thread's bounded span buffer (single writer, lock-free append)."""

    __slots__ = ("cap", "buf", "n", "thread_name")

    def __init__(self, cap: int, thread_name: str):
        self.cap = cap
        self.buf: List[Optional[Span]] = [None] * cap
        self.n = 0
        self.thread_name = thread_name

    def add(self, span: Span) -> None:
        self.buf[self.n % self.cap] = span
        self.n += 1

    def snapshot(self) -> List[Span]:
        """Retained spans, oldest first."""
        if self.n <= self.cap:
            return [s for s in self.buf[: self.n]]
        i = self.n % self.cap
        return [s for s in self.buf[i:] + self.buf[:i]]

    @property
    def dropped(self) -> int:
        return max(0, self.n - self.cap)


class Tracer:
    """Thread-safe span recorder with per-thread rings and foreign ingest."""

    def __init__(self, capacity: int = 16384, process_name: str = "trainer"):
        self.capacity = int(capacity)
        self.process_name = process_name
        self.pid = os.getpid()
        self._lock = threading.Lock()  # ring registry + foreign ingest only
        self._local = threading.local()
        self._rings: List[_SpanRing] = []
        # ingested remote spans: (process label, pid, spans, dropped count)
        self._foreign: List[Tuple[str, int, List[Span], int]] = []
        # instant marks (rare, warning-path events); bounded, locked
        self._marks: List[Tuple[str, str, int, Optional[Dict]]] = []
        self._marks_cap = 1024

    def _ring(self) -> _SpanRing:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            ring = _SpanRing(self.capacity, threading.current_thread().name)
            with self._lock:
                self._rings.append(ring)
            self._local.ring = ring
        return ring

    def add_span(
        self,
        name: str,
        cat: str,
        start_ns: int,
        dur_ns: int,
        args: Optional[Dict] = None,
    ) -> None:
        """Record one completed span (timestamps on ``perf_counter_ns``)."""
        self._ring().add((name, cat, start_ns, dur_ns, args))

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "trainer", **args):
        """``with tracer.span("client.wait", rid=7): ...``"""
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            self._ring().add(
                (name, cat, t0, time.perf_counter_ns() - t0, args or None)
            )

    def mark(self, name: str, cat: str = "mark", **args) -> None:
        """Record an instant event — a degraded-mode flag on the timeline.

        Marks are for rare warning-path conditions (wedged prefetch
        producer, fused fallback, degraded worker): they take the tracer
        lock and are capacity-bounded, so they must never sit on a
        per-step path — that is what spans and counters are for.
        """
        t0 = time.perf_counter_ns()
        with self._lock:
            if len(self._marks) < self._marks_cap:
                self._marks.append((name, cat, t0, args or None))

    def marks(self) -> List[Tuple[str, str, int, Optional[Dict]]]:
        with self._lock:
            return list(self._marks)

    def ingest(
        self,
        process_name: str,
        pid: int,
        spans: Sequence[Span],
        offset_ns: int = 0,
        dropped: int = 0,
    ) -> None:
        """Adopt spans recorded in another process.

        ``offset_ns`` maps the remote ``perf_counter_ns`` epoch into this
        process's: callers estimate it from a control round-trip midpoint
        (``remote_clock - (t_send + t_recv) / 2``), so a remote timestamp
        ``t`` lands at ``t - offset_ns`` on the local timeline.
        """
        corrected = [
            (name, cat, int(t0 - offset_ns), dur, args)
            for name, cat, t0, dur, args in spans
        ]
        with self._lock:
            self._foreign.append((process_name, int(pid), corrected, dropped))

    # --------------------------------------------------------------- readers
    def threads(self) -> List[Tuple[int, str, List[Span], int]]:
        """Per-thread (tid, thread name, spans, dropped) snapshots."""
        with self._lock:
            rings = list(self._rings)
        return [
            (tid, r.thread_name, r.snapshot(), r.dropped)
            for tid, r in enumerate(rings, start=1)
        ]

    def foreign(self) -> List[Tuple[str, int, List[Span], int]]:
        with self._lock:
            return list(self._foreign)

    def span_count(self) -> int:
        """Retained spans across local rings and ingested processes."""
        return sum(len(s) for _, _, s, _ in self.threads()) + sum(
            len(s) for _, _, s, _ in self.foreign()
        )

    def dropped_count(self) -> int:
        return sum(d for _, _, _, d in self.threads()) + sum(
            d for _, _, _, d in self.foreign()
        )


_NULL = contextlib.nullcontext()


def span_scope(tracer: Optional[Tracer], name: str, cat: str = "trainer", **args):
    """``tracer.span(...)`` when tracing is wired, else a shared no-op
    context — call sites thread one optional tracer without branching."""
    if tracer is None:
        return _NULL
    return tracer.span(name, cat=cat, **args)
