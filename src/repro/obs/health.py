"""Run-health guardrails: flight recorder, stall watchdog, loss anomaly gate.

Traces (PR 8) answer *where the time went* after the fact; this module
answers *is the run still healthy right now*, and leaves a usable
post-mortem behind when it is not. One :class:`HealthMonitor` — owned by
the trainer via ``TrainerConfig.health`` — watches three failure families:

- **Stalls.** The step loop beats the monitor once per completed step (and
  the ``PhaseTimer`` pulses it at every phase boundary, so "steps stopped
  but phases still move" is distinguishable from "everything froze"). A
  named watchdog thread checks the beat age every ``poll_interval_s``;
  past ``stall_timeout_s`` it dumps a **flight record** — a Perfetto trace
  snapshot, an all-thread stack dump (``faulthandler``), and the run's
  health/metrics/worker state as JSON — into ``flightrec_dir``, then
  records a :class:`RunStalledError` that the step loop (and the
  prefetcher's poll loop, so a consumer blocked on a wedged producer still
  aborts) raises on its next check. Even when the process is hard-stuck
  and must be killed externally, the dump is already on disk — that is the
  flight recorder's whole point.
- **Loss anomalies.** ``observe_losses`` rides the trainer's *async* loss
  drain — values that were coming to the host anyway, so no extra device
  sync. NaN/Inf fails immediately; divergence is a windowed EWMA z-score
  (``|x - ewma| > zmax * sigma`` after ``divergence_window`` healthy
  observations). Both dump a flight record and raise
  :class:`LossAnomalyError` from the training thread.
- **Worker liveness.** When the trainer runs the mp graph engine, the
  watchdog folds in ``GraphClient.heartbeat()`` rounds (the existing
  ``stats`` control op — no new IPC). A worker silent for
  ``worker_silent_rounds`` consecutive heartbeats marks the run *degraded*
  (counter + trace mark, run continues) before the client's own
  ``EngineWorkerError`` path hard-fails it.

Monitoring never touches the training stream: a beat is two attribute
stores, loss checks see only already-drained host floats, and heartbeats
ride a control channel — so a monitored run's losses are bitwise identical
to an unmonitored one (``tests/test_health.py`` pins this).

Timing hygiene (lint rule O001): deadlines use ``time.monotonic``,
timestamps ``time.perf_counter_ns`` — never wall clock.
"""
from __future__ import annotations

import dataclasses
import faulthandler
import json
import math
import os
import threading
import time
from typing import Dict, List, Optional

from repro.utils import get_logger

log = get_logger("repro.obs.health")


class RunStalledError(RuntimeError):
    """No training step completed within the stall timeout.

    ``flightrec`` carries the dump directory path (None if the dump
    itself failed)."""

    def __init__(self, message: str, flightrec: Optional[str] = None):
        super().__init__(message)
        self.flightrec = flightrec


class LossAnomalyError(RuntimeError):
    """The loss stream went NaN/Inf or diverged beyond the z-score band."""

    def __init__(self, message: str, flightrec: Optional[str] = None):
        super().__init__(message)
        self.flightrec = flightrec


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Knobs of the run-health monitor (``TrainerConfig.health``)."""

    # No completed step (or phase pulse) for this long -> flight-record
    # dump + RunStalledError. Size it well above the slowest expected step
    # INCLUDING compile time: the first step of a run pays jit.
    stall_timeout_s: float = 120.0
    # Watchdog wake interval. Stall detection latency is timeout + poll.
    poll_interval_s: float = 1.0
    # Loss checks (cost: a float compare per drained loss).
    nan_check: bool = True
    # Healthy observations required before z-scoring starts, and the
    # rejection band width. 0 window disables divergence detection
    # (NaN/Inf stays on).
    divergence_window: int = 32
    divergence_zmax: float = 8.0
    # EWMA smoothing for the divergence mean/variance estimates.
    ewma_alpha: float = 0.05
    # Worker-liveness heartbeat cadence for the mp engine (0 disables).
    # Each round is one bounded `stats` control op per worker.
    worker_heartbeat_s: float = 10.0
    worker_heartbeat_timeout_s: float = 5.0
    # Consecutive silent heartbeats before the run is marked degraded.
    worker_silent_rounds: int = 3
    # Flight-record dumps land in <flightrec_dir>/<pid>-<seq>-<reason>/.
    flightrec_dir: str = "flightrec"
    # Drained-loss tail retained for the flight record.
    loss_tail: int = 64


class HealthMonitor:
    """Flight recorder + watchdog over one training run.

    Lifecycle: construct, ``start()`` right before the step loop,
    ``beat(step)`` per completed step, ``observe_losses`` on every drained
    window, ``stop()`` in the run's ``finally``. ``check()`` is the cheap
    fault gate (one attribute load when healthy) for poll loops.
    """

    def __init__(
        self,
        config: HealthConfig = HealthConfig(),
        telemetry=None,
        client=None,
    ):
        self.cfg = config
        self._telemetry = telemetry
        self._client = client  # GraphClient (mp engine) or None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # Single-writer fields read cross-thread without a lock: Python
        # attribute stores are atomic, and the watchdog only compares ages.
        self._last_beat: float = 0.0
        self._last_pulse: float = 0.0
        self._last_step: int = -1
        self.fault: Optional[BaseException] = None
        self.degraded: bool = False
        # EWMA divergence state (training-thread only)
        self._ewma: float = 0.0
        self._ewma_var: float = 0.0
        self._n_obs: int = 0
        self._loss_tail: List[float] = []
        # dump bookkeeping (any thread)
        self._dump_lock = threading.Lock()
        self._dump_seq = 0
        self._silent: Dict[int, int] = {}  # worker -> consecutive misses
        if telemetry is not None:
            m = telemetry.metrics
            self._c_stall = m.counter("health.stalls")
            self._c_anomaly = m.counter("health.loss_anomalies")
            self._c_silent = m.counter("health.worker_silent")
            self._g_degraded = m.gauge("health.degraded")
        else:
            self._c_stall = self._c_anomaly = self._c_silent = None
            self._g_degraded = None

    # -------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Arm the watchdog (idempotent). Beats/pulses start counting now."""
        if self._thread is not None:
            return
        now = time.monotonic()
        self._last_beat = now
        self._last_pulse = now
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._watch, name="repro-health-watchdog", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        """Retire the watchdog. Idempotent; the pending fault (if any)
        survives for a final ``check()``."""
        self._stop.set()
        thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout=5.0)
            if thread.is_alive():
                log.warning(
                    "health watchdog still running after stop(); it is a "
                    "daemon and will exit with the process"
                )

    # ------------------------------------------------------------ hot hooks
    def beat(self, step: int) -> None:
        """One completed training step. Raises the pending fault, if any."""
        self._last_step = step
        self._last_beat = time.monotonic()
        if self.fault is not None:
            raise self.fault

    def pulse(self) -> None:
        """Sub-step liveness bump (phase boundaries): steps may be slow,
        but the pipeline is provably still moving."""
        self._last_pulse = time.monotonic()

    def check(self) -> None:
        """Raise the pending fault, if any (for poll loops that may never
        reach the next ``beat``)."""
        if self.fault is not None:
            raise self.fault

    def observe_losses(self, values) -> None:
        """Feed drained host losses (called from the training thread on
        the async drain — values were coming to the host anyway)."""
        cfg = self.cfg
        for v in values:
            v = float(v)
            self._loss_tail.append(v)
            if not math.isfinite(v):
                if not cfg.nan_check:
                    continue
                self._anomaly(
                    f"non-finite loss {v!r} at step <= {self._last_step}"
                )
            if cfg.divergence_window > 0:
                self._observe_one(v)
        del self._loss_tail[: -cfg.loss_tail]

    def _observe_one(self, v: float) -> None:
        cfg = self.cfg
        if self._n_obs >= cfg.divergence_window:
            sigma = math.sqrt(max(self._ewma_var, 1e-12))
            z = abs(v - self._ewma) / sigma
            if z > cfg.divergence_zmax:
                self._anomaly(
                    f"loss diverged: {v:.6g} is {z:.1f} sigma from the "
                    f"EWMA {self._ewma:.6g} (sigma {sigma:.3g}, "
                    f"zmax {cfg.divergence_zmax}) at step <= {self._last_step}"
                )
        a = cfg.ewma_alpha
        if self._n_obs == 0:
            self._ewma = v
        else:
            delta = v - self._ewma
            self._ewma += a * delta
            self._ewma_var = (1.0 - a) * (self._ewma_var + a * delta * delta)
        self._n_obs += 1

    def _anomaly(self, message: str) -> None:
        if self._c_anomaly is not None:
            self._c_anomaly.inc()
        path = self.dump("loss-anomaly", context={"message": message})
        err = LossAnomalyError(
            f"{message} (flight record: {path})", flightrec=path
        )
        self.fault = err
        raise err

    # -------------------------------------------------------------- watchdog
    def _watch(self) -> None:
        cfg = self.cfg
        next_hb = time.monotonic() + cfg.worker_heartbeat_s
        while not self._stop.wait(cfg.poll_interval_s):
            now = time.monotonic()
            alive_age = now - max(self._last_beat, self._last_pulse)
            if self.fault is None and alive_age > cfg.stall_timeout_s:
                self._on_stall(alive_age)
                return  # one dump per run; the fault is armed
            if (
                self._client is not None
                and cfg.worker_heartbeat_s > 0
                and now >= next_hb
            ):
                self._heartbeat_round()
                next_hb = time.monotonic() + cfg.worker_heartbeat_s

    def _on_stall(self, age_s: float) -> None:
        if self._c_stall is not None:
            self._c_stall.inc()
        beat_age = time.monotonic() - self._last_beat
        path = self.dump(
            "stall",
            context={
                "beat_age_s": round(beat_age, 3),
                "alive_age_s": round(age_s, 3),
            },
        )
        self.fault = RunStalledError(
            f"no training step for {beat_age:.1f}s (no activity for "
            f"{age_s:.1f}s, stall_timeout_s={self.cfg.stall_timeout_s}); "
            f"flight record: {path}",
            flightrec=path,
        )
        log.error("%s", self.fault)

    def _heartbeat_round(self) -> None:
        try:
            alive = self._client.heartbeat(
                timeout=self.cfg.worker_heartbeat_timeout_s
            )
        except Exception as e:  # client racing shutdown: not a health event
            log.debug("worker heartbeat skipped: %s", e)
            return
        for w, ok in alive.items():
            if ok:
                self._silent[w] = 0
                continue
            self._silent[w] = self._silent.get(w, 0) + 1
            if self._silent[w] == self.cfg.worker_silent_rounds:
                self._mark_degraded(
                    f"graph worker {w} silent for {self._silent[w]} "
                    "heartbeat rounds"
                )

    def _mark_degraded(self, why: str) -> None:
        self.degraded = True
        if self._c_silent is not None:
            self._c_silent.inc()
        if self._g_degraded is not None:
            self._g_degraded.set(1)
        if self._telemetry is not None:
            self._telemetry.tracer.mark("health.degraded", reason=why)
        log.warning("run degraded: %s", why)

    # --------------------------------------------------------- flight record
    def dump(self, reason: str, context: Optional[Dict] = None) -> str:
        """Write one flight-record directory and return its path.

        Contents (the schema CI's trace-smoke job asserts):

        - ``trace.json`` — Perfetto-loadable snapshot of the telemetry
          tracer + metrics (present when telemetry is wired),
        - ``stacks.txt`` — ``faulthandler`` dump of every thread,
        - ``health.json`` — reason, step/beat ages, drained-loss tail,
          degraded flag, per-worker last stats, metrics snapshot.
        """
        with self._dump_lock:
            seq = self._dump_seq
            self._dump_seq += 1
        # pid+sequence naming: unique per process without wall-clock reads
        # (lint rule O001 keeps wall time out of obs modules)
        path = os.path.join(
            self.cfg.flightrec_dir, f"{os.getpid()}-{seq:02d}-{reason}"
        )
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, "stacks.txt"), "w") as f:
            faulthandler.dump_traceback(file=f, all_threads=True)
        if self._telemetry is not None:
            try:
                self._telemetry.write_trace(os.path.join(path, "trace.json"))
            except Exception as e:  # a failed snapshot must not mask the fault
                log.warning("flight-record trace snapshot failed: %s", e)
        now = time.monotonic()
        payload: Dict = {
            "reason": reason,
            "steps_done": self._last_step + 1,
            "beat_age_s": round(now - self._last_beat, 3),
            "pulse_age_s": round(now - self._last_pulse, 3),
            "degraded": self.degraded,
            "losses_tail": self._loss_tail[-self.cfg.loss_tail:],
            "context": context or {},
        }
        if self._client is not None:
            payload["workers"] = {
                "last_stats": {
                    str(w): s
                    for w, s in getattr(self._client, "_last_stats", {}).items()
                },
                "dead": {
                    str(w): r
                    for w, r in getattr(self._client, "_dead", {}).items()
                },
                "silent_rounds": {str(w): n for w, n in self._silent.items()},
            }
        if self._telemetry is not None:
            payload["metrics"] = self._telemetry.metrics.summary()
        with open(os.path.join(path, "health.json"), "w") as f:
            json.dump(payload, f, indent=1, default=str)
            f.write("\n")
        log.info("flight record (%s) -> %s", reason, path)
        return path
