"""repro.obs — unified telemetry: span tracing, metrics, Perfetto export.

The diagnostic substrate for the multi-process pipeline (the ROADMAP's
multi-host and serving tentpoles stand on it): one :class:`Telemetry`
bundle carries a ring-buffered cross-process span :class:`Tracer` and a
:class:`MetricsRegistry`, threaded explicitly — never a global — through
``TrainerConfig.telemetry`` into the trainer loop, the prefetcher, the
``GraphClient`` request rounds, the graph-service workers (spans ship back
on the ``stats`` control round, clock-offset-corrected), and retrieval.

Usage (see docs/observability.md for the full tour)::

    from repro.obs import Telemetry

    tel = Telemetry()
    trainer = Graph4RecTrainer(..., TrainerConfig(..., telemetry=tel))
    trainer.train(params)
    tel.write_trace("out.trace.json")   # open in https://ui.perfetto.dev
    print(tel.text_summary())

Disabled telemetry is ``telemetry=None`` (the default) everywhere: no
rings are allocated, no events are emitted, and instrumented call sites
pay one ``is None`` test (``make bench-trace`` pins the overhead).
"""
from repro.obs.export import chrome_trace, text_summary, trace_events, write_trace
from repro.obs.metrics import (
    DEFAULT_NS_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.health import (
    HealthConfig,
    HealthMonitor,
    LossAnomalyError,
    RunStalledError,
)
from repro.obs.memory import (
    MemoryAccountant,
    device_memory_stats,
    live_array_bytes,
    memory_snapshot,
)
from repro.obs.trace import DurationRing, Span, Tracer, span_scope


class Telemetry:
    """One tracer + one metrics registry, wired together for export."""

    def __init__(self, span_capacity: int = 16384, process_name: str = "trainer"):
        self.tracer = Tracer(capacity=span_capacity, process_name=process_name)
        self.metrics = MetricsRegistry()

    def span(self, name: str, cat: str = "trainer", **args):
        return self.tracer.span(name, cat=cat, **args)

    def chrome_trace(self) -> dict:
        return chrome_trace(self.tracer, self.metrics)

    def write_trace(self, path: str) -> str:
        return write_trace(path, self.tracer, self.metrics)

    def text_summary(self) -> str:
        return text_summary(self.tracer, self.metrics)


__all__ = [
    "Counter",
    "DEFAULT_NS_BUCKETS",
    "DurationRing",
    "Gauge",
    "HealthConfig",
    "HealthMonitor",
    "Histogram",
    "LossAnomalyError",
    "MemoryAccountant",
    "MetricsRegistry",
    "RunStalledError",
    "Span",
    "Telemetry",
    "Tracer",
    "chrome_trace",
    "device_memory_stats",
    "live_array_bytes",
    "memory_snapshot",
    "span_scope",
    "text_summary",
    "trace_events",
    "write_trace",
]
