"""Flat-npz pytree checkpointing (offline container: no orbax).

Pytrees of jnp/np arrays are flattened to ``key.path`` -> array and stored in
a single .npz; restore rebuilds the dict pytree. Sufficient for warm-start
hand-off (metapath2vec -> GNN) and trainer resumption.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}|"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}|"))
    else:
        out[prefix.rstrip("|")] = np.asarray(tree)
    return out


def normalize_path(path: str) -> str:
    """The on-disk name for ``path``: np.savez appends ``.npz`` when the
    suffix is missing, so save and load must agree on the same rule."""
    return path if path.endswith(".npz") else path + ".npz"


def save(path: str, tree: Any) -> str:
    path = normalize_path(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))
    return path


def load_flat(path: str) -> Dict[str, np.ndarray]:
    # accept both the name the caller passed to save() and the actual file
    if not os.path.exists(path):
        path = normalize_path(path)
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def load_dict(path: str) -> Dict[str, Any]:
    """Restore a (possibly nested-by-'|') dict pytree."""
    flat = load_flat(path)
    out: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("|")
        cur = out
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = val
    return out
