"""Minimal pure-JAX optimizers (no optax offline): sgd / adagrad / adam / adamw.

API mirrors optax: ``opt = adam(lr); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply_updates``.
Works on arbitrary pytrees. A ``masked`` combinator applies different
optimizers to sparse (embedding) vs dense parameters — the PS-style split the
paper uses (sparse rows on the server via adagrad, dense weights via adam).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u, params, updates)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), state
        new_m = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state, grads)
        return jax.tree_util.tree_map(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


def adagrad(lr: float, eps: float = 1e-8, init_accum: float = 0.1) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.full_like(p, init_accum), params
        )

    def update(grads, state, params=None):
        new_acc = jax.tree_util.tree_map(lambda a, g: a + g * g, state, grads)
        upd = jax.tree_util.tree_map(
            lambda g, a: -lr * g / (jnp.sqrt(a) + eps), grads, new_acc
        )
        return upd, new_acc

    return Optimizer(init, update)


def rowwise_adagrad(lr: float, eps: float = 1e-8, init_accum: float = 0.1) -> Optimizer:
    """PS row-wise AdaGrad, dense application: one (rows, 1) accumulator per
    table, accumulating the per-row mean of squared grads.

    This is the optax-style twin of
    ``embedding.optimizer.rowwise_adagrad_scatter_update``: untouched rows
    have zero grads (gather cotangent), so updating every row here is
    mathematically the scatter update at O(num_rows) cost. The trainer's
    ``sparse_updates=False`` fallback routes embedding tables through this so
    the two paths stay provably equivalent. Leaves must be >= 1-D (rows
    first); intended for the ``emb/*`` side of the sparse/dense ``masked``
    split only.
    """

    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.full((p.shape[0], 1), init_accum, p.dtype), params
        )

    def update(grads, state, params=None):
        new_acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.mean(g * g, axis=-1, keepdims=True), state, grads
        )
        upd = jax.tree_util.tree_map(
            lambda g, a: -lr * g / (jnp.sqrt(a) + eps), grads, new_acc
        )
        return upd, new_acc

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adam; with weight_decay>0 this is AdamW (decoupled decay)."""

    def init(params):
        z = lambda p: jnp.zeros_like(p)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(z, params),
            nu=jax.tree_util.tree_map(z, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m, v, p):
            upd = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                upd = upd - lr * weight_decay * p
            return upd

        if params is None:
            params = jax.tree_util.tree_map(lambda m: None, mu)
        updates = jax.tree_util.tree_map(u, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


def adamw(lr: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def masked(
    opt_a: Optimizer, opt_b: Optimizer, select_a: Callable[[str], bool]
) -> Optimizer:
    """Dict-pytree combinator: keys where select_a(key) use opt_a, else opt_b.

    Used for the sparse/dense split: adagrad on ``emb/*`` tables (the PS-side
    update), adam on dense GNN weights.
    """

    def _split(tree: Dict[str, Any]):
        a = {k: v for k, v in tree.items() if select_a(k)}
        b = {k: v for k, v in tree.items() if not select_a(k)}
        return a, b

    def init(params):
        a, b = _split(params)
        return (opt_a.init(a), opt_b.init(b))

    def update(grads, state, params=None):
        ga, gb = _split(grads)
        pa, pb = _split(params) if params is not None else (None, None)
        ua, sa = opt_a.update(ga, state[0], pa)
        ub, sb = opt_b.update(gb, state[1], pb)
        return {**ua, **ub}, (sa, sb)

    return Optimizer(init, update)


def clip_by_global_norm(updates, max_norm: float):
    leaves = jax.tree_util.tree_leaves(updates)
    norm = jnp.sqrt(sum(jnp.sum(l * l) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda u: u * scale, updates)
