from repro.train.optimizer import (
    Optimizer, sgd, adagrad, adam, adamw, masked, apply_updates, clip_by_global_norm,
)
from repro.train.trainer import TrainerConfig, TrainResult, Graph4RecTrainer
from repro.train import checkpoint
