"""Graph4Rec trainer: streams pipeline batches through a jitted grad step.

The trainer wires together the paper's five pipeline stages (walk -> ego ->
pair -> GNN -> loss) with the sparse/dense optimizer split and the recall
evaluation. It is the engine behind examples/train_recsys.py and every
RQ benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as model_lib
from repro.core.recall import evaluate_recall
from repro.graph.generator import RecsysDataset
from repro.sampling.pipeline import PipelineConfig, SamplePipeline
from repro.train import optimizer as opt_lib
from repro.utils import get_logger

log = get_logger("repro.train")


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 200
    sparse_lr: float = 0.2
    dense_lr: float = 1e-3
    eval_every: int = 0  # 0 -> only at end
    eval_top_k: int = 100
    eval_max_users: int = 256
    log_every: int = 50
    seed: int = 0


@dataclasses.dataclass
class TrainResult:
    params: Dict
    losses: List[float]
    eval_history: List[Dict[str, float]]  # appended at each eval point
    wall_time_s: float
    pairs_seen: int


class Graph4RecTrainer:
    def __init__(
        self,
        dataset: RecsysDataset,
        engine,
        model_cfg: model_lib.Graph4RecConfig,
        pipe_cfg: PipelineConfig,
        cfg: TrainerConfig = TrainerConfig(),
    ):
        self.dataset = dataset
        self.engine = engine
        self.model_cfg = model_cfg
        self.pipe_cfg = pipe_cfg
        self.cfg = cfg
        self.opt = opt_lib.masked(
            opt_lib.adagrad(cfg.sparse_lr),
            opt_lib.adam(cfg.dense_lr),
            select_a=lambda k: k.startswith("emb/"),
        )
        self._grad_step = jax.jit(self._make_grad_step())

    def _make_grad_step(self):
        mc = self.model_cfg

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model_lib.loss_fn)(params, mc, batch)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return step

    def init_params(self, key: Optional[jax.Array] = None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        return model_lib.init_model_params(key, self.model_cfg)

    def evaluate(self, params, split: str = "val") -> Dict[str, float]:
        ds = self.dataset
        rng = np.random.default_rng(self.cfg.seed + 7)
        all_emb = model_lib.encode_all_nodes(
            params, self.model_cfg, self.engine, rng, ds.graph
        )
        user_emb = all_emb[: ds.num_users]
        item_emb = all_emb[ds.num_users : ds.num_users + ds.num_items]
        train_pairs = np.concatenate(
            [np.stack([u, i], 1) for (u, i) in ds.train_edges.values()], axis=0
        )
        eval_pairs = ds.val_pairs if split == "val" else ds.test_pairs
        return evaluate_recall(
            user_emb, item_emb, train_pairs, eval_pairs,
            top_k=self.cfg.eval_top_k, max_users=self.cfg.eval_max_users,
        )

    def train(self, params: Optional[Dict] = None) -> TrainResult:
        cfg = self.cfg
        params = params if params is not None else self.init_params()
        opt_state = self.opt.init(params)
        pipeline = SamplePipeline(self.engine, self.pipe_cfg, seed=cfg.seed)
        losses: List[float] = []
        evals: List[Dict[str, float]] = []
        pairs_seen = 0
        t0 = time.perf_counter()
        for step, batch in enumerate(pipeline.batches(cfg.num_steps)):
            dev = model_lib.device_batch(self.dataset.graph, batch, self.model_cfg)
            params, opt_state, loss = self._grad_step(params, opt_state, dev)
            losses.append(float(loss))
            pairs_seen += len(batch.src_ids)
            if cfg.log_every and (step + 1) % cfg.log_every == 0:
                log.info("step %d loss %.4f", step + 1, float(loss))
            if cfg.eval_every and (step + 1) % cfg.eval_every == 0:
                evals.append(self.evaluate(params))
        wall = time.perf_counter() - t0
        evals.append(self.evaluate(params))
        return TrainResult(
            params=params, losses=losses, eval_history=evals,
            wall_time_s=wall, pairs_seen=pairs_seen,
        )
