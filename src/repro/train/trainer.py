"""Graph4Rec trainer: streams pipeline batches through a jitted grad step.

The trainer wires together the paper's five pipeline stages (walk -> ego ->
pair -> GNN -> loss) with the sparse/dense optimizer split and the recall
evaluation. It is the engine behind examples/train_recsys.py and every
RQ benchmark.

Throughput design: host-side sampling + device-batch conversion run in a
bounded background prefetch thread (``prefetch_batches`` deep), overlapping
with the jitted grad step, and the loop never forces a device sync per step
(losses stay on device until the end; set ``sync_every_step=True`` for the
strictly serial sample->sync->step loop, e.g. as a benchmark baseline).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as model_lib
from repro.core.recall import evaluate_recall
from repro.graph.generator import RecsysDataset
from repro.sampling.pipeline import PipelineConfig, SamplePipeline
from repro.train import optimizer as opt_lib
from repro.utils import get_logger

log = get_logger("repro.train")


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 200
    sparse_lr: float = 0.2
    dense_lr: float = 1e-3
    eval_every: int = 0  # 0 -> only at end
    eval_top_k: int = 100
    eval_max_users: int = 256
    eval_at_end: bool = True
    log_every: int = 50
    seed: int = 0
    # Depth of the background host->device prefetch queue. 0 disables the
    # prefetch thread and runs the serial sample->step loop.
    prefetch_batches: int = 2
    # Force a device sync (float(loss)) after every step — the seed's serial
    # behavior; benchmarks use it as the baseline arm.
    sync_every_step: bool = False
    # Route GNN aggregation through the Pallas seg_aggr kernel. None leaves
    # the model config (HeteroGNNConfig.use_kernel_aggr) untouched.
    use_kernel_aggr: Optional[bool] = None


@dataclasses.dataclass
class TrainResult:
    params: Dict
    losses: List[float]
    eval_history: List[Dict[str, float]]  # appended at each eval point
    wall_time_s: float
    pairs_seen: int


_DONE = object()


class _Prefetcher:
    """Bounded background-thread prefetch between the host pipeline and the
    device loop. Producer exceptions re-raise in the consumer."""

    def __init__(self, it: Iterator, depth: int):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(depth)))
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._fill, args=(it,), name="repro-prefetch", daemon=True
        )
        self._thread.start()

    def _fill(self, it: Iterator) -> None:
        try:
            for item in it:
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # surfaced via __next__
            self._err = e
        finally:
            # The sentinel must land even when the queue is full, or the
            # consumer would block forever — keep trying until it fits or
            # the consumer has already closed us.
            while not self._stop.is_set():
                try:
                    self._q.put(_DONE, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> "_Prefetcher":
        return self

    def __next__(self):
        while True:
            item = self._q.get()
            if item is _DONE:
                self._thread.join(timeout=5.0)
                if self._err is not None:
                    raise self._err
                raise StopIteration
            return item

    def close(self) -> None:
        """Unblock and retire the producer (early consumer exit).

        The producer only observes the stop flag between queue puts, so a
        thread deep inside one sampling round can outlive the join timeout;
        it is a daemon and will die with the process, but warn so overlapping
        engine use (e.g. an immediate retrain) is explainable.
        """
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
        if self._thread.is_alive():
            log.warning(
                "prefetch producer still running after close(); it will exit "
                "after its current sampling round"
            )


class Graph4RecTrainer:
    def __init__(
        self,
        dataset: RecsysDataset,
        engine,
        model_cfg: model_lib.Graph4RecConfig,
        pipe_cfg: PipelineConfig,
        cfg: TrainerConfig = TrainerConfig(),
    ):
        self.dataset = dataset
        self.engine = engine
        if cfg.use_kernel_aggr is not None and model_cfg.gnn is not None:
            model_cfg = dataclasses.replace(
                model_cfg,
                gnn=dataclasses.replace(
                    model_cfg.gnn, use_kernel_aggr=cfg.use_kernel_aggr
                ),
            )
        self.model_cfg = model_cfg
        self.pipe_cfg = pipe_cfg
        self.cfg = cfg
        self.opt = opt_lib.masked(
            opt_lib.adagrad(cfg.sparse_lr),
            opt_lib.adam(cfg.dense_lr),
            select_a=lambda k: k.startswith("emb/"),
        )
        # 'bag' side info: one count matrix per slot, built once and shared
        # by every batch (see embedding/table.py:embed_nodes_bag).
        self._slot_counts = (
            model_lib.slot_count_arrays(dataset.graph, self.model_cfg)
            if self.model_cfg.use_side_info and self.model_cfg.slot_mode == "bag"
            else None
        )
        self._grad_step = jax.jit(self._make_grad_step())

    def _make_grad_step(self):
        mc = self.model_cfg

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model_lib.loss_fn)(params, mc, batch)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return step

    def init_params(self, key: Optional[jax.Array] = None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        return model_lib.init_model_params(key, self.model_cfg)

    def evaluate(self, params, split: str = "val") -> Dict[str, float]:
        ds = self.dataset
        rng = np.random.default_rng(self.cfg.seed + 7)
        all_emb = model_lib.encode_all_nodes(
            params, self.model_cfg, self.engine, rng, ds.graph
        )
        user_emb = all_emb[: ds.num_users]
        item_emb = all_emb[ds.num_users : ds.num_users + ds.num_items]
        train_pairs = np.concatenate(
            [np.stack([u, i], 1) for (u, i) in ds.train_edges.values()], axis=0
        )
        eval_pairs = ds.val_pairs if split == "val" else ds.test_pairs
        return evaluate_recall(
            user_emb, item_emb, train_pairs, eval_pairs,
            top_k=self.cfg.eval_top_k, max_users=self.cfg.eval_max_users,
        )

    def _device_batches(
        self, pipeline: SamplePipeline, num: int
    ) -> Iterator[Tuple[Dict, int]]:
        """Host pipeline -> (device batch, num pairs); runs inside the
        prefetch thread so jnp conversion overlaps device compute too."""
        for batch in pipeline.batches(num):
            dev = model_lib.device_batch(
                self.dataset.graph, batch, self.model_cfg,
                slot_counts=self._slot_counts,
            )
            yield dev, len(batch.src_ids)

    def train(self, params: Optional[Dict] = None) -> TrainResult:
        cfg = self.cfg
        params = params if params is not None else self.init_params()
        opt_state = self.opt.init(params)
        pipeline = SamplePipeline(self.engine, self.pipe_cfg, seed=cfg.seed)
        loss_hist: List[jax.Array] = []
        evals: List[Dict[str, float]] = []
        pairs_seen = 0
        batch_iter: Iterator = self._device_batches(pipeline, cfg.num_steps)
        prefetcher: Optional[_Prefetcher] = None
        if cfg.prefetch_batches > 0:
            prefetcher = _Prefetcher(batch_iter, cfg.prefetch_batches)
            batch_iter = prefetcher
        t0 = time.perf_counter()
        try:
            for step, (dev, npairs) in enumerate(batch_iter):
                params, opt_state, loss = self._grad_step(params, opt_state, dev)
                loss_hist.append(loss)
                pairs_seen += npairs
                if cfg.sync_every_step:
                    float(loss)
                if cfg.log_every and (step + 1) % cfg.log_every == 0:
                    log.info("step %d loss %.4f", step + 1, float(loss))
                if cfg.eval_every and (step + 1) % cfg.eval_every == 0:
                    evals.append(self.evaluate(params))
        finally:
            if prefetcher is not None:
                prefetcher.close()
        if loss_hist:
            jax.block_until_ready(loss_hist[-1])
        wall = time.perf_counter() - t0
        losses = [float(l) for l in loss_hist]
        if cfg.eval_at_end:
            evals.append(self.evaluate(params))
        return TrainResult(
            params=params, losses=losses, eval_history=evals,
            wall_time_s=wall, pairs_seen=pairs_seen,
        )
