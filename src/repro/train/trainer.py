"""Graph4Rec trainer: streams pipeline batches through a jitted grad step.

The trainer wires together the paper's five pipeline stages (walk -> ego ->
pair -> GNN -> loss) with the sparse/dense optimizer split and the recall
evaluation. It is the engine behind examples/train_recsys.py and every
RQ benchmark.

Throughput design: host-side sampling + device-batch conversion run in a
bounded background prefetch thread (``prefetch_batches`` deep), overlapping
with the jitted grad step — or, with ``sampling_backend="fused"`` on an
eligible graph, sampling moves onto the device entirely: walk, window-pair
and ego gather run inside the jitted grad step (sampling/fused.py) and the
prefetcher becomes a no-op pass-through. The loop never forces a device
sync per step
(losses stay on device until the end, drained in windows so long runs don't
pin unbounded device buffers; set ``sync_every_step=True`` for the strictly
serial sample->sync->step loop, e.g. as a benchmark baseline).

Sparse updates (``sparse_updates=True``, the default — the paper's PS
pull/push, §3.6): the prefetch thread deduplicates each batch's touched ids
per embedding table and remaps the batch onto gathered sub-tables
(core/model.py:sparse_device_batch); the jitted step differentiates w.r.t.
the gathered rows only, applies row-wise AdaGrad to them, and scatters the
updated rows back into the donated tables — O(unique ids) per step instead
of the dense path's O(num_nodes). ``sparse_updates=False`` keeps the dense
full-table grad step (same row-wise AdaGrad rule via
train.optimizer.rowwise_adagrad, so the two paths are numerically
equivalent).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.core import model as model_lib
from repro.core.recall import evaluate_recall
from repro.embedding import optimizer as emb_opt
from repro.embedding import table as emb
from repro.graph.generator import RecsysDataset
from repro.lint.sanitizer import (
    device_barrier,
    host_floats,
    host_scalar,
    transfer_sanitizer,
)
from repro.sampling.fused import FusedConfig, fused_eligibility
from repro.sampling.pipeline import (
    PipelineConfig, SamplePipeline, make_train_sampler,
)
from repro.train import optimizer as opt_lib
from repro.utils import get_logger

log = get_logger("repro.train")


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 200
    sparse_lr: float = 0.2
    dense_lr: float = 1e-3
    eval_every: int = 0  # 0 -> only at end
    eval_top_k: int = 100
    # Similar-neighbor pool size for the ICF/UCF strategies (paper §4.2) —
    # previously hard-coded inside core/recall.py.
    eval_top_n: int = 20
    # 0 evaluates EVERY held-out user (no subsampling — the device retrieval
    # path makes that affordable); >0 restores the old capped behavior.
    eval_max_users: int = 0
    # Retrieval implementation for evaluate(): "device" (chunked streaming
    # top-k, exact), "ivf" (coarse-partition approximate), or "bruteforce"
    # (numpy oracle — the seed path, O(U·I) memory).
    eval_method: str = "device"
    # Fixed chunk width for full-graph inference (infer.embed_all_nodes).
    eval_batch_size: int = 1024
    eval_at_end: bool = True
    log_every: int = 50
    seed: int = 0
    # Depth of the background host->device prefetch queue. 0 disables the
    # prefetch thread and runs the serial sample->step loop.
    prefetch_batches: int = 2
    # Force a device sync (float(loss)) after every step — the seed's serial
    # behavior; benchmarks use it as the baseline arm.
    sync_every_step: bool = False
    # Route GNN aggregation through the Pallas seg_aggr kernel. None leaves
    # the model config (HeteroGNNConfig.use_kernel_aggr) untouched.
    use_kernel_aggr: Optional[bool] = None
    # Gather→step→scatter training (O(unique ids) per step). False falls back
    # to dense full-table grads + row-wise AdaGrad over every row (O(N)).
    sparse_updates: bool = True
    # Initial unique-id bucket width per table (0 = start at 8). Buckets grow
    # to the next power of two on overflow (one jit recompile per width).
    unique_bucket: int = 0
    # Row-wise AdaGrad accumulator init (shared by both update paths).
    adagrad_init_accum: float = 0.1
    # Route the row-wise AdaGrad gather/apply/scatter through the fused
    # Pallas kernel (kernels/row_adagrad.py) instead of XLA gather+scatter.
    use_kernel_rowopt: bool = False
    # Drain completed on-device losses to host floats every this many steps
    # (keeps only the in-flight tail on device). 0 defers to the end of run.
    loss_fetch_every: int = 64
    # Graph engine backend. "inproc" samples from the engine object passed to
    # the trainer; "mp" wraps its graph in a graph/service.GraphClient —
    # partition CSR shards in POSIX shared memory served by worker processes
    # — so the prefetch producer is never sampling-bound on this process's
    # core. Both backends are bitwise-identical under a fixed seed.
    engine_backend: str = "inproc"  # inproc | mp
    # Worker processes for the "mp" backend (clamped to num_partitions).
    num_engine_workers: int = 2
    # Partition count when the "mp" trainer is handed a bare HeteroGraph
    # (the memory-frugal setup: no in-process partition copies are ever
    # built). Ignored when an engine is passed — its partitioning wins.
    num_engine_partitions: int = 4
    # Sampling front end. "host" streams batches from the NumPy pipeline
    # (walker + ego sampler against the graph engine, prefetch thread,
    # sparse dedup); "fused" runs walk->pair->ego as ONE jitted device
    # program (sampling/fused.py) inlined into the grad step — zero host
    # work per step — whenever the padded device tables fit
    # ``fused_budget_mb`` (otherwise it falls back to "host" with a
    # warning). Fused mode bypasses the prefetcher (nothing to prefetch)
    # and always applies the dense-table update — numerically identical
    # to the sparse path's row-wise AdaGrad (tests/test_sparse_updates).
    sampling_backend: str = "host"  # host | fused
    # Padded-adjacency width for the fused sampler's device tables.
    fused_max_degree: int = 32
    # Device-table budget (MiB) for the fused eligibility check.
    fused_budget_mb: float = 256.0
    # Candidate pairs generated per emitted pair in fused mode.
    fused_oversample: float = 2.0
    # Route the fused pair gather through the Pallas window-pair kernel.
    fused_use_kernel_pairs: bool = True
    # Run every jitted step dispatch under jax.transfer_guard("disallow")
    # (repro.lint.sanitizer): an implicit host<->device transfer in the hot
    # loop raises instead of silently serializing the pipeline. Explicit
    # jax.device_put/device_get stay legal; the guard is thread-local, so
    # the prefetch producer is covered by lint rule H002 instead.
    sanitize_transfers: bool = True


@dataclasses.dataclass
class TrainResult:
    params: Dict
    losses: List[float]
    eval_history: List[Dict[str, float]]  # appended at each eval point
    wall_time_s: float
    pairs_seen: int


_DONE = object()


class _Prefetcher:
    """Bounded background-thread prefetch between the host pipeline and the
    device loop. Producer exceptions re-raise in the consumer (original
    traceback preserved), and the consumer never blocks indefinitely: it
    polls the queue so a producer that dies without delivering its sentinel
    (hard crash, killed interpreter thread) surfaces as an error instead of
    hanging ``train()`` forever."""

    def __init__(self, it: Iterator, depth: int):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(depth)))
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._fill, args=(it,), name="repro-prefetch", daemon=True
        )
        self._thread.start()

    def _fill(self, it: Iterator) -> None:
        try:
            for item in it:
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # surfaced via __next__
            self._err = e
        finally:
            # The sentinel must land even when the queue is full, or the
            # consumer would block forever — keep trying until it fits or
            # the consumer has already closed us.
            while not self._stop.is_set():
                try:
                    self._q.put(_DONE, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> "_Prefetcher":
        return self

    def __next__(self):
        while True:
            try:
                item = self._q.get(timeout=0.5)
            except queue.Empty:
                if self._thread.is_alive():
                    continue
                # Producer is gone. It may have enqueued its final batches
                # and sentinel in the window between our timeout and the
                # aliveness check — drain once more before declaring it dead
                # without a sentinel (killed mid-put / crashed outside the
                # guarded region).
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    if self._err is not None:
                        raise self._err
                    raise RuntimeError(
                        "prefetch producer thread died without delivering a "
                        "batch or its error"
                    )
            if item is _DONE:
                self._thread.join(timeout=5.0)
                if self._thread.is_alive():
                    # Mirrors close(): a producer that delivered its sentinel
                    # but wedged before returning would otherwise leak into
                    # the next train() call unannounced.
                    log.warning(
                        "prefetch producer still running after its "
                        "end-of-stream sentinel; it is a daemon and will "
                        "exit with the process"
                    )
                if self._err is not None:
                    # Same exception object -> original producer traceback.
                    raise self._err
                raise StopIteration
            return item

    def close(self) -> None:
        """Unblock and retire the producer (early consumer exit).

        The producer only observes the stop flag between queue puts, so a
        thread deep inside one sampling round can outlive the join timeout;
        it is a daemon and will die with the process, but warn so overlapping
        engine use (e.g. an immediate retrain) is explainable.
        """
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
        if self._thread.is_alive():
            log.warning(
                "prefetch producer still running after close(); it will exit "
                "after its current sampling round"
            )


class Graph4RecTrainer:
    def __init__(
        self,
        dataset: RecsysDataset,
        engine,
        model_cfg: model_lib.Graph4RecConfig,
        pipe_cfg: PipelineConfig,
        cfg: TrainerConfig = TrainerConfig(),
    ):
        self.dataset = dataset
        # "mp" backend: move the partitions out of this process. The client
        # reuses the given engine's partitioning, so switching backends never
        # changes sampling semantics; passing a bare HeteroGraph instead
        # avoids ever materializing in-process partition copies (the client
        # then partitions straight into shared memory,
        # cfg.num_engine_partitions ways).
        self._owned_client = None
        if cfg.engine_backend == "mp":
            from repro.graph.service import GraphClient

            if hasattr(engine, "graph"):  # a built engine: inherit its layout
                engine = GraphClient(engine, num_workers=cfg.num_engine_workers)
            else:
                engine = GraphClient(
                    engine,
                    num_partitions=cfg.num_engine_partitions,
                    num_workers=cfg.num_engine_workers,
                )
            self._owned_client = engine
        elif cfg.engine_backend != "inproc":
            raise ValueError(f"unknown engine_backend {cfg.engine_backend!r}")
        self.engine = engine
        if cfg.use_kernel_aggr is not None and model_cfg.gnn is not None:
            model_cfg = dataclasses.replace(
                model_cfg,
                gnn=dataclasses.replace(
                    model_cfg.gnn, use_kernel_aggr=cfg.use_kernel_aggr
                ),
            )
        self.model_cfg = model_cfg
        self.pipe_cfg = pipe_cfg
        self.cfg = cfg
        # Both paths step embedding tables with the same row-wise AdaGrad
        # rule; dense applies it to every row, sparse to the gathered rows.
        self.opt = opt_lib.masked(
            opt_lib.rowwise_adagrad(
                cfg.sparse_lr, init_accum=cfg.adagrad_init_accum
            ),
            opt_lib.adam(cfg.dense_lr),
            select_a=lambda k: k.startswith("emb/"),
        )
        self._dense_opt = opt_lib.adam(cfg.dense_lr)
        # Per-table unique-id bucket widths; grown (and persisted) by
        # sparse_device_batch so the jitted sparse step keeps stable shapes.
        self._buckets: Dict[str, int] = {}
        if cfg.unique_bucket:
            self._buckets["node"] = cfg.unique_bucket
            for slot in model_cfg.embedding.slots:
                self._buckets[f"slot:{slot.name}"] = cfg.unique_bucket
        # 'bag' side info: one count matrix per slot, built once and shared
        # by every batch (see embedding/table.py:embed_nodes_bag). The sparse
        # path instead ships a per-batch sub count matrix and never builds
        # the O(num_nodes x vocab) one.
        self._slot_counts = (
            model_lib.slot_count_arrays(dataset.graph, self.model_cfg)
            if (
                model_lib.bag_slot_specs(self.model_cfg)
                and not cfg.sparse_updates
            )
            else None
        )
        # Fused device sampling: build the sampler (and the combined
        # sample+grad step) only when the graph passes the memory gate.
        self._fused_sampler = None
        self._fused_step = None
        if cfg.sampling_backend == "fused":
            fused_cfg = FusedConfig(
                max_degree=cfg.fused_max_degree,
                budget_mb=cfg.fused_budget_mb,
                oversample=cfg.fused_oversample,
                use_kernel_pairs=cfg.fused_use_kernel_pairs,
            )
            bspecs = model_lib.bag_slot_specs(self.model_cfg)
            vspecs = model_lib.value_slot_specs(self.model_cfg)
            ok, why = fused_eligibility(
                dataset.graph, pipe_cfg, vspecs, bspecs, fused_cfg
            )
            if ok:
                self._fused_sampler = make_train_sampler(
                    dataset.graph, pipe_cfg, backend="fused", seed=cfg.seed,
                    value_slots=vspecs, bag_slots=bspecs, fused_cfg=fused_cfg,
                    bag_counts=(
                        model_lib.slot_count_arrays(dataset.graph, self.model_cfg)
                        if bspecs else None
                    ),
                )
                self._fused_step = jax.jit(
                    self._make_fused_step(), donate_argnums=(0, 1)
                )
                log.info("fused sampling backend active (%s)", why)
            else:
                log.warning(
                    "sampling_backend='fused' ineligible: %s; falling back "
                    "to the host pipeline", why,
                )
        elif cfg.sampling_backend != "host":
            raise ValueError(f"unknown sampling_backend {cfg.sampling_backend!r}")
        self._grad_step = jax.jit(self._make_grad_step())
        self._sparse_step = jax.jit(
            self._make_sparse_step(), donate_argnums=(0, 1)
        )
        self._train_pairs = np.concatenate(
            [np.stack([u, i], 1) for (u, i) in dataset.train_edges.values()],
            axis=0,
        )

    def _make_grad_step(self):
        mc = self.model_cfg

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model_lib.loss_fn)(params, mc, batch)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return step

    def _make_fused_step(self):
        """Sampling fused INTO the jitted grad step (sampling_backend=
        "fused"): the batch is produced on device from the PRNG key alone,
        so one dispatch per step covers walk, pair, ego, forward, backward
        and the update — the host only advances the key. Tables update
        through the dense full-table rule (identical numerics to the
        sparse path's row-wise AdaGrad) under buffer donation."""
        mc = self.model_cfg
        sampler = self._fused_sampler

        def step(params, opt_state, key):
            batch = sampler.sample(key)
            loss, grads = jax.value_and_grad(model_lib.loss_fn)(params, mc, batch)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return step

    def _make_sparse_step(self):
        """The gather→compute→scatter step (jitted with donated buffers).

        ``batch`` arrives id-remapped from ``sparse_device_batch``: its
        ``uniq`` entry names each table's touched global rows, and every id
        in the model inputs indexes the gathered sub-table. Gradients are
        taken w.r.t. the (bucket, dim) sub-tables only, so nothing in the
        step — forward, backward, or optimizer — is O(num_nodes).
        """
        mc = self.model_cfg
        cfg = self.cfg
        dense_opt = self._dense_opt

        def step(params, opt_state, batch):
            uniq = {f"emb/{k}": v for k, v in batch["uniq"].items()}
            model_batch = {k: v for k, v in batch.items() if k != "uniq"}
            sparse_p, dense_p = model_lib.sparse_dense_split(params)
            row_state, dense_state = opt_state
            # Tables the batch never touches (e.g. slot tables with side info
            # disabled) pass straight through — no gather, no grads.
            touched = {k: v for k, v in sparse_p.items() if k in uniq}
            sub = {k: emb.gather_rows(v, uniq[k]) for k, v in touched.items()}

            def loss_of(sub_tables, dense):
                return model_lib.loss_fn({**dense, **sub_tables}, mc, model_batch)

            loss, (g_sub, g_dense) = jax.value_and_grad(loss_of, argnums=(0, 1))(
                sub, dense_p
            )
            d_updates, dense_state = dense_opt.update(g_dense, dense_state, dense_p)
            dense_p = opt_lib.apply_updates(dense_p, d_updates)
            new_touched, touched_state = emb_opt.rowwise_adagrad_scatter_update(
                touched, g_sub, uniq, row_state,
                lr=cfg.sparse_lr, eps=1e-8, use_kernel=cfg.use_kernel_rowopt,
            )
            row_state = emb_opt.RowAdagradState(
                accum={**row_state.accum, **touched_state.accum}
            )
            params = {**dense_p, **sparse_p, **new_touched}
            return params, (row_state, dense_state), loss

        return step

    def _init_sparse_opt_state(self, params: Dict):
        sparse_p, dense_p = model_lib.sparse_dense_split(params)
        return (
            emb_opt.rowwise_adagrad_init(
                sparse_p, init_accum=self.cfg.adagrad_init_accum
            ),
            self._dense_opt.init(dense_p),
        )

    def init_params(self, key: Optional[jax.Array] = None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        return model_lib.init_model_params(key, self.model_cfg)

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Reap engine worker processes (mp backend). Idempotent; also runs
        automatically when ``train()`` raises and on context-manager exit."""
        if self._owned_client is not None:
            self._owned_client.shutdown()

    def __enter__(self) -> "Graph4RecTrainer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def evaluate(self, params, split: str = "val") -> Dict[str, float]:
        """Full recall evaluation: full-graph inference (repro.infer) +
        device-side retrieval (repro.core.recall / repro.retrieval). Every
        knob the old path hard-coded (top_n, user subsampling, method) is
        TrainerConfig-exposed; by default every held-out user is scored."""
        from repro.infer import embed_all_nodes

        ds = self.dataset
        rng = np.random.default_rng(self.cfg.seed + 7)
        all_emb = embed_all_nodes(
            params, self.model_cfg, self.engine, ds.graph,
            batch_size=self.cfg.eval_batch_size, rng=rng,
        )
        user_emb = all_emb[: ds.num_users]
        item_emb = all_emb[ds.num_users : ds.num_users + ds.num_items]
        eval_pairs = ds.val_pairs if split == "val" else ds.test_pairs
        return evaluate_recall(
            user_emb, item_emb, self._train_pairs, eval_pairs,
            top_k=self.cfg.eval_top_k, top_n=self.cfg.eval_top_n,
            max_users=self.cfg.eval_max_users, method=self.cfg.eval_method,
        )

    def _device_batches(
        self, pipeline: SamplePipeline, num: int
    ) -> Iterator[Tuple[Dict, int]]:
        """Host pipeline -> (device batch, num pairs); runs inside the
        prefetch thread so jnp conversion — and, on the sparse path, the
        unique-id dedup + remap — overlaps device compute."""
        for batch in pipeline.batches(num):
            if self.cfg.sparse_updates:
                dev = model_lib.sparse_device_batch(
                    self.dataset.graph, batch, self.model_cfg,
                    buckets=self._buckets,
                )
            else:
                dev = model_lib.device_batch(
                    self.dataset.graph, batch, self.model_cfg,
                    slot_counts=self._slot_counts,
                )
            yield dev, len(batch.src_ids)

    def _fused_batch_iter(self) -> Iterator[Tuple[jax.Array, int]]:
        """Fused mode's stand-in for the host batch stream: the "batch" fed
        to the jitted step is just the per-step PRNG key (sampling happens
        inside the step), so the prefetcher has nothing to do and is
        bypassed entirely — a no-op pass-through."""
        # one batched split up front: per-step eager fold_in dispatches
        # would cost more than the fused sample itself
        keys = jax.random.split(
            jax.random.PRNGKey(self.cfg.seed), max(self.cfg.num_steps, 1)
        )
        for i in range(self.cfg.num_steps):
            yield keys[i], self.pipe_cfg.batch_pairs

    def train(self, params: Optional[Dict] = None) -> TrainResult:
        cfg = self.cfg
        params = params if params is not None else self.init_params()
        if self._fused_sampler is not None:
            # The fused step donates its param buffers; copy like the
            # sparse path so a caller-held pytree survives. device_put is
            # the explicit H2D spelling (no-op on already-device leaves).
            params = jax.tree_util.tree_map(
                lambda x: jax.device_put(x).copy(), params
            )
            opt_state = self.opt.init(params)
            step_fn = self._fused_step
        elif cfg.sparse_updates:
            # The sparse step donates its param buffers; copy once so a
            # caller-held pytree (e.g. for a later cold-start eval) survives.
            params = jax.tree_util.tree_map(
                lambda x: jax.device_put(x).copy(), params
            )
            opt_state = self._init_sparse_opt_state(params)
            step_fn = self._sparse_step
        else:
            opt_state = self.opt.init(params)
            step_fn = self._grad_step
        loss_hist: List[jax.Array] = []  # in-flight on-device tail
        losses: List[float] = []  # drained, completed losses
        # Keep at least the prefetch window on device before draining; the
        # drained prefix is steps behind the last dispatch, so device_get
        # barely blocks.
        drain_tail = max(1, cfg.prefetch_batches + 1)
        evals: List[Dict[str, float]] = []
        pairs_seen = 0
        prefetcher: Optional[_Prefetcher] = None
        if self._fused_sampler is not None:
            batch_iter: Iterator = self._fused_batch_iter()
        else:
            pipeline = make_train_sampler(
                self.engine, self.pipe_cfg, backend="host", seed=cfg.seed
            )
            batch_iter = self._device_batches(pipeline, cfg.num_steps)
            if cfg.prefetch_batches > 0:
                prefetcher = _Prefetcher(batch_iter, cfg.prefetch_batches)
                batch_iter = prefetcher
        t0 = time.perf_counter()
        try:
            for step, (dev, npairs) in enumerate(batch_iter):
                # Every dispatch runs under the transfer guard: batches were
                # converted in the producer (device_batch) or ARE device
                # values (fused keys), so any transfer here is a regression.
                with transfer_sanitizer(cfg.sanitize_transfers):
                    params, opt_state, loss = step_fn(params, opt_state, dev)
                loss_hist.append(loss)
                pairs_seen += npairs
                if cfg.sync_every_step:
                    host_scalar(loss)
                if (
                    cfg.loss_fetch_every
                    and len(loss_hist) >= cfg.loss_fetch_every + drain_tail
                ):
                    done, loss_hist = loss_hist[:-drain_tail], loss_hist[-drain_tail:]
                    losses.extend(host_floats(done))
                if cfg.log_every and (step + 1) % cfg.log_every == 0:
                    log.info("step %d loss %.4f", step + 1, host_scalar(loss))
                if cfg.eval_every and (step + 1) % cfg.eval_every == 0:
                    evals.append(self.evaluate(params))
        except BaseException:
            # The run is aborted (producer error — possibly a dead engine
            # worker — or a caller interrupt): reap worker processes so
            # nothing outlives the failed train() call.
            self.close()
            raise
        finally:
            if prefetcher is not None:
                prefetcher.close()
        if loss_hist:
            device_barrier(loss_hist[-1])
        wall = time.perf_counter() - t0
        losses.extend(host_floats(loss_hist))
        if cfg.eval_at_end:
            evals.append(self.evaluate(params))
        return TrainResult(
            params=params, losses=losses, eval_history=evals,
            wall_time_s=wall, pairs_seen=pairs_seen,
        )
