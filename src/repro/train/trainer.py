"""Graph4Rec trainer: streams pipeline batches through a jitted grad step.

The trainer wires together the paper's five pipeline stages (walk -> ego ->
pair -> GNN -> loss) with the sparse/dense optimizer split and the recall
evaluation. It is the engine behind examples/train_recsys.py and every
RQ benchmark.

Throughput design: host-side sampling + host-batch assembly run in a
bounded background prefetch thread (``prefetch_batches`` deep), the one
explicit H2D transfer per batch happens in a consumer-side double-buffered
stager (``jax.device_put`` of batch k+1 overlaps the in-flight step k, and
the next device batch is always resident before its dispatch) — or, with
``sampling_backend="fused"`` on an eligible graph, sampling moves onto the
device entirely: walk, window-pair and ego gather run inside the jitted
grad step (sampling/fused.py) and the prefetcher/stager are bypassed. The
loop never forces a device sync per step: losses stay on device and are
drained in windows through a *started-ahead* async readback
(``host_floats_async``), so the fetch of window k resolves while window
k+1's steps dispatch; set ``sync_every_step=True`` for the strictly serial
sample->sync->step loop, e.g. as a benchmark baseline.

Backend selection is measured, not guessed (``auto_backend``, default on):
at the first ``train()`` a short calibration phase times per-batch host
sampling/assembly, the jitted step, the prefetch handoff, and (when
``sampling_backend="auto"``) the fused step, then picks
serial-vs-prefetch-vs-fused from those numbers. Explicit settings always
win; the decision and its measurements are recorded in
``TrainResult.plan``. ``TrainerConfig.attribution`` threads a sync-free
``train.attribution.PhaseTimer`` through the loop (sample / assemble /
batch_wait / h2d / dispatch / loss_fetch) — `make bench-attr` records the
per-combination breakdown into BENCH_throughput.json.

Sparse updates (``sparse_updates=True``, the default — the paper's PS
pull/push, §3.6): the prefetch thread deduplicates each batch's touched ids
per embedding table and remaps the batch onto gathered sub-tables
(core/model.py:sparse_device_batch); the jitted step differentiates w.r.t.
the gathered rows only, applies row-wise AdaGrad to them, and scatters the
updated rows back into the donated tables — O(unique ids) per step instead
of the dense path's O(num_nodes). ``sparse_updates=False`` keeps the dense
full-table grad step (same row-wise AdaGrad rule via
train.optimizer.rowwise_adagrad, so the two paths are numerically
equivalent).
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
import warnings
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.core import model as model_lib
from repro.core.recall import evaluate_recall
from repro.embedding import optimizer as emb_opt
from repro.embedding import table as emb
from repro.graph.generator import RecsysDataset
from repro.lint.sanitizer import (
    device_barrier,
    host_floats,
    host_floats_async,
    host_scalar,
    transfer_sanitizer,
)
from repro.obs.trace import span_scope
from repro.train.attribution import (
    PhaseTimer,
    measure_handoff_overhead,
    median,
    phase_scope,
)
from repro.sampling.fused import FusedConfig, fused_eligibility
from repro.sampling.pipeline import (
    PipelineConfig, SamplePipeline, make_train_sampler,
)
from repro.train import optimizer as opt_lib
from repro.utils import get_logger

log = get_logger("repro.train")

# The sparse step donates its batch so the stager's H2D buffers recycle into
# the update outputs. A batch's int32 id arrays can never alias the float
# outputs, so XLA reports them "not usable" on every (re)compile — expected,
# not actionable; the float buffers (bag-mode count matrices) do alias.
warnings.filterwarnings(
    "ignore", message=r"Some donated buffers were not usable.*int32.*"
)


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 200
    sparse_lr: float = 0.2
    dense_lr: float = 1e-3
    eval_every: int = 0  # 0 -> only at end
    eval_top_k: int = 100
    # Similar-neighbor pool size for the ICF/UCF strategies (paper §4.2) —
    # previously hard-coded inside core/recall.py.
    eval_top_n: int = 20
    # 0 evaluates EVERY held-out user (no subsampling — the device retrieval
    # path makes that affordable); >0 restores the old capped behavior.
    eval_max_users: int = 0
    # Retrieval implementation for evaluate(): "device" (chunked streaming
    # top-k, exact), "ivf" (coarse-partition approximate), or "bruteforce"
    # (numpy oracle — the seed path, O(U·I) memory).
    eval_method: str = "device"
    # Fixed chunk width for full-graph inference (infer.embed_all_nodes).
    eval_batch_size: int = 1024
    eval_at_end: bool = True
    log_every: int = 50
    seed: int = 0
    # Depth of the background host->device prefetch queue. 0 disables the
    # prefetch thread and runs the serial sample->step loop; an explicit
    # int always wins. None defers the serial-vs-prefetch decision to the
    # auto-backend calibration (or the legacy default of 2 when
    # ``auto_backend`` is off / the run is too short to calibrate).
    prefetch_batches: Optional[int] = None
    # Measured backend selection: at the first train() a short calibration
    # phase times per-batch host cost, the jitted step and the prefetch
    # handoff, then resolves every knob left at its "auto" default
    # (prefetch_batches=None, sampling_backend="auto"). Explicit settings
    # are never overridden. Calibration is skipped (legacy defaults apply)
    # when num_steps < calibrate_min_steps — short smoke runs shouldn't
    # pay a measurement phase longer than the run itself.
    auto_backend: bool = True
    # Batches sampled / steps timed during calibration (first one warms
    # caches / compiles and is excluded from the medians).
    calibrate_batches: int = 3
    calibrate_min_steps: int = 32
    # Force a device sync (float(loss)) after every step — the seed's serial
    # behavior; benchmarks use it as the baseline arm.
    sync_every_step: bool = False
    # Route GNN aggregation through the Pallas seg_aggr kernel. None leaves
    # the model config (HeteroGNNConfig.use_kernel_aggr) untouched.
    use_kernel_aggr: Optional[bool] = None
    # Gather→step→scatter training (O(unique ids) per step). False falls back
    # to dense full-table grads + row-wise AdaGrad over every row (O(N)).
    sparse_updates: bool = True
    # Sparse/dense crossover: below this node-table row count the sparse
    # path's dedup+gather+scatter overhead exceeds what it saves
    # (BENCH_throughput.json grad_step: 0.45x dense at 10k rows, 1.66x at
    # 100k), so ``sparse_updates=True`` routes through the dense step for
    # small tables. Both paths are bitwise-equivalent (PR-2 suite); set 0
    # to force the sparse path regardless of size.
    sparse_min_rows: int = 32768
    # Initial unique-id bucket width per table (0 = start at 8). Buckets grow
    # to the next power of two on overflow (one jit recompile per width).
    unique_bucket: int = 0
    # Row-wise AdaGrad accumulator init (shared by both update paths).
    adagrad_init_accum: float = 0.1
    # Route the row-wise AdaGrad gather/apply/scatter through the fused
    # Pallas kernel (kernels/row_adagrad.py) instead of XLA gather+scatter.
    use_kernel_rowopt: bool = False
    # Drain completed on-device losses to host floats every this many steps
    # (keeps only the in-flight tail on device). 0 defers to the end of run.
    loss_fetch_every: int = 64
    # Graph engine backend. "inproc" samples from the engine object passed to
    # the trainer; "mp" wraps its graph in a graph/service.GraphClient —
    # partition CSR shards in POSIX shared memory served by worker processes
    # — so the prefetch producer is never sampling-bound on this process's
    # core. Both backends are bitwise-identical under a fixed seed.
    engine_backend: str = "inproc"  # inproc | mp
    # Worker processes for the "mp" backend (clamped to num_partitions).
    # 0 sizes the fleet automatically: half the visible cores (leaving the
    # rest for the trainer process and XLA's own thread pool), capped by
    # the partition count.
    num_engine_workers: int = 0
    # Partition count when the "mp" trainer is handed a bare HeteroGraph
    # (the memory-frugal setup: no in-process partition copies are ever
    # built). Ignored when an engine is passed — its partitioning wins.
    num_engine_partitions: int = 4
    # Hybrid serving threshold for the "mp" backend: a sampling round whose
    # total node count is at or below this is answered in-process by the
    # GraphClient over zero-copy views of its own shard segments (bitwise
    # identical to a worker reply — same core, same seeding). Small rounds
    # are latency-bound, so skipping the pipe round-trip wins whenever
    # workers contend with the trainer for cores; big rounds still go to
    # the fleet. 0 disables (every round crosses the process boundary).
    engine_local_threshold: int = 8192
    # Sampling front end. "host" streams batches from the NumPy pipeline
    # (walker + ego sampler against the graph engine, prefetch thread,
    # sparse dedup); "fused" runs walk->pair->ego as ONE jitted device
    # program (sampling/fused.py) inlined into the grad step — zero host
    # work per step — whenever the padded device tables fit
    # ``fused_budget_mb`` (otherwise it falls back to "host" with a
    # warning). Fused mode bypasses the prefetcher (nothing to prefetch)
    # and always applies the dense-table update — numerically identical
    # to the sparse path's row-wise AdaGrad (tests/test_sparse_updates).
    # "auto" lets the calibration phase choose: fused when the measured
    # fused step beats the best host-pipeline estimate (and the graph
    # passes the memory gate), host otherwise.
    sampling_backend: str = "host"  # host | fused | auto
    # Padded-adjacency width for the fused sampler's device tables.
    fused_max_degree: int = 32
    # Device-table budget (MiB) for the fused eligibility check.
    fused_budget_mb: float = 256.0
    # Candidate pairs generated per emitted pair in fused mode.
    fused_oversample: float = 2.0
    # Route the fused pair gather through the Pallas window-pair kernel.
    fused_use_kernel_pairs: bool = True
    # Run every jitted step dispatch under jax.transfer_guard("disallow")
    # (repro.lint.sanitizer): an implicit host<->device transfer in the hot
    # loop raises instead of silently serializing the pipeline. Explicit
    # jax.device_put/device_get stay legal; the guard is thread-local, so
    # the prefetch producer is covered by lint rule H002 instead.
    sanitize_transfers: bool = True
    # Record a per-phase time breakdown (sample/assemble/batch_wait/h2d/
    # dispatch/loss_fetch) into TrainResult.attribution via the sync-free
    # ring-buffer PhaseTimer (train/attribution.py). Off by default: zero
    # hot-loop cost beyond a None check.
    attribution: bool = False
    # Unified telemetry (repro.obs.Telemetry, default None = disabled): span
    # tracing across the step loop, prefetcher, GraphClient rounds, graph
    # workers, and retrieval, plus the metrics registry — exported as a
    # Perfetto-loadable Chrome trace (telemetry.write_trace) or text
    # summary. Disabled costs one is-None test per instrumented site
    # (`make bench-trace` pins the overhead at noise level).
    telemetry: Optional[object] = None
    # Run-health guardrails (repro.obs.health.HealthConfig, default None =
    # off): a watchdog thread that flight-records and fails the run on
    # stalls (no step within stall_timeout_s -> Perfetto snapshot +
    # all-thread stack dump + worker last-stats under flightrec/, then
    # RunStalledError), checks the async loss drain for NaN/Inf and EWMA
    # z-score divergence (no extra host sync), and folds in graph-worker
    # liveness from bounded heartbeat rounds. Off is a true no-op on the
    # step loop: losses are bitwise identical either way
    # (tests/test_health.py pins it).
    health: Optional[object] = None


@dataclasses.dataclass
class TrainResult:
    params: Dict
    losses: List[float]
    eval_history: List[Dict[str, float]]  # appended at each eval point
    wall_time_s: float
    pairs_seen: int
    # Resolved execution plan (sampling backend, prefetch depth, and — when
    # calibrated — the per-phase measurements the choice was made from).
    plan: Optional[Dict] = None
    # PhaseTimer summary when TrainerConfig.attribution is on.
    attribution: Optional[Dict] = None


_DONE = object()


class _Prefetcher:
    """Bounded background-thread prefetch between the host pipeline and the
    device loop. Producer exceptions re-raise in the consumer (original
    traceback preserved), and the consumer never blocks indefinitely: it
    polls the queue so a producer that dies without delivering its sentinel
    (hard crash, killed interpreter thread) surfaces as an error instead of
    hanging ``train()`` forever."""

    def __init__(
        self,
        it: Iterator,
        depth: int,
        queue_gauge=None,
        telemetry=None,
        health_check=None,
    ):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(depth)))
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        # optional obs gauge tracking the queue's fill level (a persistently
        # empty queue = starved consumer, persistently full = device-bound)
        self._gauge = queue_gauge
        # wedged-producer incidents become a counter + an instant trace
        # mark (degraded runs visible in Perfetto, not just stderr)
        self._c_wedged = (
            telemetry.metrics.counter("prefetch.wedged_producer")
            if telemetry is not None else None
        )
        self._tracer = telemetry.tracer if telemetry is not None else None
        # optional HealthMonitor.check: a consumer polling an empty queue
        # still observes a watchdog-armed fault instead of spinning on a
        # producer that will never deliver
        self._health_check = health_check
        self._thread = threading.Thread(
            target=self._fill, args=(it,), name="repro-prefetch", daemon=True
        )
        self._thread.start()

    def _fill(self, it: Iterator) -> None:
        try:
            for item in it:
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        if self._gauge is not None:
                            self._gauge.set(self._q.qsize())
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # surfaced via __next__
            self._err = e
        finally:
            # The sentinel must land even when the queue is full, or the
            # consumer would block forever — keep trying until it fits or
            # the consumer has already closed us.
            while not self._stop.is_set():
                try:
                    self._q.put(_DONE, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> "_Prefetcher":
        return self

    def __next__(self):
        while True:
            try:
                item = self._q.get(timeout=0.5)
            except queue.Empty:
                if self._health_check is not None:
                    self._health_check()
                if self._thread.is_alive():
                    continue
                # Producer is gone. It may have enqueued its final batches
                # and sentinel in the window between our timeout and the
                # aliveness check — drain once more before declaring it dead
                # without a sentinel (killed mid-put / crashed outside the
                # guarded region).
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    if self._err is not None:
                        raise self._err
                    raise RuntimeError(
                        "prefetch producer thread died without delivering a "
                        "batch or its error"
                    )
            if item is _DONE:
                self._thread.join(timeout=5.0)
                if self._thread.is_alive():
                    # Mirrors close(): a producer that delivered its sentinel
                    # but wedged before returning would otherwise leak into
                    # the next train() call unannounced.
                    log.warning(
                        "prefetch producer still running after its "
                        "end-of-stream sentinel; it is a daemon and will "
                        "exit with the process"
                    )
                    self._mark_wedged("after-sentinel")
                if self._err is not None:
                    # Same exception object -> original producer traceback.
                    raise self._err
                raise StopIteration
            return item

    def close(self) -> None:
        """Unblock and retire the producer (early consumer exit).

        The producer only observes the stop flag between queue puts, so a
        thread deep inside one sampling round can outlive the join timeout;
        it is a daemon and will die with the process, but warn so overlapping
        engine use (e.g. an immediate retrain) is explainable.
        """
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
        if self._thread.is_alive():
            log.warning(
                "prefetch producer still running after close(); it will exit "
                "after its current sampling round"
            )
            self._mark_wedged("close")

    def _mark_wedged(self, where: str) -> None:
        if self._c_wedged is not None:
            self._c_wedged.inc()
        if self._tracer is not None:
            self._tracer.mark("prefetch.wedged_producer", where=where)


def _round_spikes(durs: List[float]) -> List[int]:
    """Indices of round-paying batches in a per-batch duration series.

    Carry batches drain the round buffer in microseconds; a batch 4x over
    the median paid a sampling round. When every batch pays a round the
    median IS the round cost, nothing clears the threshold, and the caller
    falls back to the plain mean (which is then exact anyway).
    """
    if len(durs) < 2:
        return []
    thr = 4.0 * median(durs)
    return [i for i, d in enumerate(durs) if d > thr]


def _staged_batches(
    it: Iterator,
    timer: Optional[PhaseTimer] = None,
    double_buffer: bool = True,
    staged_gauge=None,
) -> Iterator:
    """Consumer-side H2D stager: the one explicit ``jax.device_put`` per
    batch, double-buffered.

    With ``double_buffer`` on (any prefetching run), batch k+1's host->device
    transfer is issued BEFORE batch k is yielded to the step loop, so the
    transfer overlaps the in-flight grad step k and the next device batch is
    always resident by the time its dispatch needs it — two device batches
    rotate, never more. The serial path (``double_buffer=False``) stages
    batches one at a time: pulling batch k+1 early there would just move
    inline sampling around, not overlap anything.

    Phases: "batch_wait" is time blocked on the upstream iterator (queue
    starvation under prefetch, inline sampling+assembly when serial);
    "h2d" is the device_put itself. Producer errors propagate unchanged.
    """
    it = iter(it)
    if not double_buffer:
        while True:
            with phase_scope(timer, "batch_wait"):
                item = next(it, _DONE)
            if item is _DONE:
                return
            with phase_scope(timer, "h2d"):
                staged = (jax.device_put(item[0]), item[1])
            if staged_gauge is not None:
                staged_gauge.set(1)
            yield staged
    with phase_scope(timer, "batch_wait"):
        item = next(it, _DONE)
    if item is _DONE:
        return
    with phase_scope(timer, "h2d"):
        pending = (jax.device_put(item[0]), item[1])
    while True:
        with phase_scope(timer, "batch_wait"):
            item = next(it, _DONE)
        if item is _DONE:
            if staged_gauge is not None:
                staged_gauge.set(1)
            yield pending
            return
        with phase_scope(timer, "h2d"):
            staged = (jax.device_put(item[0]), item[1])
        if staged_gauge is not None:
            staged_gauge.set(2)  # two device batches resident (double buffer)
        yield pending
        pending = staged


class Graph4RecTrainer:
    def __init__(
        self,
        dataset: RecsysDataset,
        engine,
        model_cfg: model_lib.Graph4RecConfig,
        pipe_cfg: PipelineConfig,
        cfg: TrainerConfig = TrainerConfig(),
    ):
        self.dataset = dataset
        # "mp" backend: move the partitions out of this process. The client
        # reuses the given engine's partitioning, so switching backends never
        # changes sampling semantics; passing a bare HeteroGraph instead
        # avoids ever materializing in-process partition copies (the client
        # then partitions straight into shared memory,
        # cfg.num_engine_partitions ways).
        self._owned_client = None
        # Auto worker sizing (num_engine_workers=0): half the visible cores —
        # the other half stays with the trainer process and XLA's own thread
        # pool. The client additionally clamps to its partition count.
        self._engine_workers = (
            cfg.num_engine_workers
            if cfg.num_engine_workers > 0
            else max(1, (os.cpu_count() or 2) // 2)
        )
        if cfg.engine_backend == "mp":
            from repro.graph.service import GraphClient

            if hasattr(engine, "graph"):  # a built engine: inherit its layout
                engine = GraphClient(
                    engine,
                    num_workers=self._engine_workers,
                    local_threshold=cfg.engine_local_threshold,
                    telemetry=cfg.telemetry,
                )
            else:
                engine = GraphClient(
                    engine,
                    num_partitions=cfg.num_engine_partitions,
                    num_workers=self._engine_workers,
                    local_threshold=cfg.engine_local_threshold,
                    telemetry=cfg.telemetry,
                )
            self._owned_client = engine
        elif cfg.engine_backend != "inproc":
            raise ValueError(f"unknown engine_backend {cfg.engine_backend!r}")
        self.engine = engine
        if cfg.use_kernel_aggr is not None and model_cfg.gnn is not None:
            model_cfg = dataclasses.replace(
                model_cfg,
                gnn=dataclasses.replace(
                    model_cfg.gnn, use_kernel_aggr=cfg.use_kernel_aggr
                ),
            )
        self.model_cfg = model_cfg
        self.pipe_cfg = pipe_cfg
        self.cfg = cfg
        # Both paths step embedding tables with the same row-wise AdaGrad
        # rule; dense applies it to every row, sparse to the gathered rows.
        self.opt = opt_lib.masked(
            opt_lib.rowwise_adagrad(
                cfg.sparse_lr, init_accum=cfg.adagrad_init_accum
            ),
            opt_lib.adam(cfg.dense_lr),
            select_a=lambda k: k.startswith("emb/"),
        )
        self._dense_opt = opt_lib.adam(cfg.dense_lr)
        # Per-table unique-id bucket widths; grown (and persisted) by
        # sparse_device_batch so the jitted sparse step keeps stable shapes.
        self._buckets: Dict[str, int] = {}
        if cfg.unique_bucket:
            self._buckets["node"] = cfg.unique_bucket
            for slot in model_cfg.embedding.slots:
                self._buckets[f"slot:{slot.name}"] = cfg.unique_bucket
        # Sparse/dense crossover (satellite of the throughput PR): on tables
        # below ``sparse_min_rows`` the sparse path's dedup+gather+scatter
        # overhead exceeds what it saves, so sparse_updates routes through
        # the dense step there. Bitwise-equivalent either way (PR-2 suite).
        num_nodes = dataset.graph.num_nodes
        self._sparse_on = cfg.sparse_updates and (
            cfg.sparse_min_rows <= 0 or num_nodes >= cfg.sparse_min_rows
        )
        if cfg.sparse_updates and not self._sparse_on:
            log.info(
                "sparse_updates requested but num_nodes=%d < sparse_min_rows="
                "%d; using the (equivalent, faster-at-this-size) dense step",
                num_nodes, cfg.sparse_min_rows,
            )
        # 'bag' side info: one count matrix per slot, built once and shared
        # by every batch (see embedding/table.py:embed_nodes_bag). The sparse
        # path instead ships a per-batch sub count matrix and never builds
        # the O(num_nodes x vocab) one.
        self._slot_counts = (
            model_lib.slot_count_arrays(dataset.graph, self.model_cfg)
            if (
                model_lib.bag_slot_specs(self.model_cfg)
                and not self._sparse_on
            )
            else None
        )
        # Fused device sampling: built eagerly for an explicit
        # sampling_backend="fused" (memory-gate fallback to host with a
        # warning), lazily by the calibration phase for "auto".
        self._fused_sampler = None
        self._fused_step = None
        # Measured device-table footprint once a fused sampler was built
        # (fed back through fused_eligibility; surfaced in the plan).
        self._fused_measured_bytes: Optional[int] = None
        # Per-train() observability state (run-health monitor + memory
        # accountant), kept for tests and post-mortem inspection.
        self._health_monitor = None
        self._memory = None
        self._plan: Optional[Dict] = None
        if cfg.sampling_backend == "fused":
            ok, why = self._build_fused()
            if ok:
                log.info("fused sampling backend active (%s)", why)
            else:
                log.warning(
                    "sampling_backend='fused' ineligible: %s; falling back "
                    "to the host pipeline", why,
                )
                if cfg.telemetry is not None:
                    cfg.telemetry.metrics.counter(
                        "trainer.fused_fallback"
                    ).inc()
                    cfg.telemetry.tracer.mark(
                        "trainer.fused_fallback", reason=why
                    )
        elif cfg.sampling_backend not in ("host", "auto"):
            raise ValueError(f"unknown sampling_backend {cfg.sampling_backend!r}")
        self._grad_step = jax.jit(self._make_grad_step())
        # The sparse step additionally donates its (single-use, per-step)
        # device batch — the stager's H2D buffers are recycled into the
        # update outputs. The dense step must NOT donate batches: dense
        # bag-mode batches alias the shared slot_count_arrays cache.
        self._sparse_step = jax.jit(
            self._make_sparse_step(), donate_argnums=(0, 1, 2)
        )
        self._train_pairs = np.concatenate(
            [np.stack([u, i], 1) for (u, i) in dataset.train_edges.values()],
            axis=0,
        )

    def _build_fused(self) -> Tuple[bool, str]:
        """Build the fused sampler + combined sample/grad step if the graph
        passes the memory gate. Idempotent; returns (built, reason)."""
        if self._fused_sampler is not None:
            return True, "already built"
        cfg = self.cfg
        fused_cfg = FusedConfig(
            max_degree=cfg.fused_max_degree,
            budget_mb=cfg.fused_budget_mb,
            oversample=cfg.fused_oversample,
            use_kernel_pairs=cfg.fused_use_kernel_pairs,
        )
        bspecs = model_lib.bag_slot_specs(self.model_cfg)
        vspecs = model_lib.value_slot_specs(self.model_cfg)
        ok, why = fused_eligibility(
            self.dataset.graph, self.pipe_cfg, vspecs, bspecs, fused_cfg
        )
        if not ok:
            return False, why
        self._fused_sampler = make_train_sampler(
            self.dataset.graph, self.pipe_cfg, backend="fused",
            seed=cfg.seed, value_slots=vspecs, bag_slots=bspecs,
            fused_cfg=fused_cfg,
            bag_counts=(
                model_lib.slot_count_arrays(self.dataset.graph, self.model_cfg)
                if bspecs else None
            ),
        )
        # The estimate admitted us; re-gate on the MEASURED footprint of
        # the tables the sampler actually shipped, so the logged decision
        # (and the plan) names real bytes. An estimate that undershot
        # enough to bust the budget tears the sampler back down.
        measured = self._fused_sampler.device_table_bytes()
        self._fused_measured_bytes = measured
        ok, why = fused_eligibility(
            self.dataset.graph, self.pipe_cfg, vspecs, bspecs, fused_cfg,
            measured_bytes=measured,
        )
        log.info(
            "fused eligibility: %s (measured %.1f MiB, budget %.1f MiB)",
            why, measured / (1 << 20), cfg.fused_budget_mb,
        )
        if not ok:
            self._fused_sampler = None
            return False, why
        self._fused_step = jax.jit(
            self._make_fused_step(), donate_argnums=(0, 1)
        )
        return True, why

    def _make_grad_step(self):
        mc = self.model_cfg

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model_lib.loss_fn)(params, mc, batch)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return step

    def _make_fused_step(self):
        """Sampling fused INTO the jitted grad step (sampling_backend=
        "fused"): the batch is produced on device from the PRNG key alone,
        so one dispatch per step covers walk, pair, ego, forward, backward
        and the update — the host only advances the key. Tables update
        through the dense full-table rule (identical numerics to the
        sparse path's row-wise AdaGrad) under buffer donation."""
        mc = self.model_cfg
        sampler = self._fused_sampler

        def step(params, opt_state, key):
            batch = sampler.sample(key)
            loss, grads = jax.value_and_grad(model_lib.loss_fn)(params, mc, batch)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return step

    def _make_sparse_step(self):
        """The gather→compute→scatter step (jitted with donated buffers).

        ``batch`` arrives id-remapped from ``sparse_device_batch``: its
        ``uniq`` entry names each table's touched global rows, and every id
        in the model inputs indexes the gathered sub-table. Gradients are
        taken w.r.t. the (bucket, dim) sub-tables only, so nothing in the
        step — forward, backward, or optimizer — is O(num_nodes).
        """
        mc = self.model_cfg
        cfg = self.cfg
        dense_opt = self._dense_opt

        def step(params, opt_state, batch):
            uniq = {f"emb/{k}": v for k, v in batch["uniq"].items()}
            model_batch = {k: v for k, v in batch.items() if k != "uniq"}
            sparse_p, dense_p = model_lib.sparse_dense_split(params)
            row_state, dense_state = opt_state
            # Tables the batch never touches (e.g. slot tables with side info
            # disabled) pass straight through — no gather, no grads.
            touched = {k: v for k, v in sparse_p.items() if k in uniq}
            sub = {k: emb.gather_rows(v, uniq[k]) for k, v in touched.items()}

            def loss_of(sub_tables, dense):
                return model_lib.loss_fn({**dense, **sub_tables}, mc, model_batch)

            loss, (g_sub, g_dense) = jax.value_and_grad(loss_of, argnums=(0, 1))(
                sub, dense_p
            )
            d_updates, dense_state = dense_opt.update(g_dense, dense_state, dense_p)
            dense_p = opt_lib.apply_updates(dense_p, d_updates)
            new_touched, touched_state = emb_opt.rowwise_adagrad_scatter_update(
                touched, g_sub, uniq, row_state,
                lr=cfg.sparse_lr, eps=1e-8, use_kernel=cfg.use_kernel_rowopt,
            )
            row_state = emb_opt.RowAdagradState(
                accum={**row_state.accum, **touched_state.accum}
            )
            params = {**dense_p, **sparse_p, **new_touched}
            return params, (row_state, dense_state), loss

        return step

    def _init_sparse_opt_state(self, params: Dict):
        sparse_p, dense_p = model_lib.sparse_dense_split(params)
        return (
            emb_opt.rowwise_adagrad_init(
                sparse_p, init_accum=self.cfg.adagrad_init_accum
            ),
            self._dense_opt.init(dense_p),
        )

    def init_params(self, key: Optional[jax.Array] = None) -> Dict:
        key = key if key is not None else jax.random.PRNGKey(self.cfg.seed)
        return model_lib.init_model_params(key, self.model_cfg)

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Reap engine worker processes (mp backend). Idempotent; also runs
        automatically when ``train()`` raises and on context-manager exit."""
        if self._owned_client is not None:
            self._owned_client.shutdown()

    def __enter__(self) -> "Graph4RecTrainer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def evaluate(self, params, split: str = "val") -> Dict[str, float]:
        """Full recall evaluation: full-graph inference (repro.infer) +
        device-side retrieval (repro.core.recall / repro.retrieval). Every
        knob the old path hard-coded (top_n, user subsampling, method) is
        TrainerConfig-exposed; by default every held-out user is scored."""
        from repro.infer import embed_all_nodes

        ds = self.dataset
        tel = self.cfg.telemetry
        tracer = tel.tracer if tel is not None else None
        rng = np.random.default_rng(self.cfg.seed + 7)
        with span_scope(tracer, "infer.embed_all_nodes", cat="eval"):
            all_emb = embed_all_nodes(
                params, self.model_cfg, self.engine, ds.graph,
                batch_size=self.cfg.eval_batch_size, rng=rng,
            )
        user_emb = all_emb[: ds.num_users]
        item_emb = all_emb[ds.num_users : ds.num_users + ds.num_items]
        eval_pairs = ds.val_pairs if split == "val" else ds.test_pairs
        return evaluate_recall(
            user_emb, item_emb, self._train_pairs, eval_pairs,
            top_k=self.cfg.eval_top_k, top_n=self.cfg.eval_top_n,
            max_users=self.cfg.eval_max_users, method=self.cfg.eval_method,
            telemetry=tel,
        )

    def _host_batches(
        self, pipeline: SamplePipeline, num: int, timer=None
    ) -> Iterator[Tuple[Dict, int]]:
        """Host pipeline -> (HOST numpy batch pytree, num pairs); runs
        inside the prefetch thread so assembly — and, on the sparse path,
        the unique-id dedup + remap — overlaps device compute. The one H2D
        transfer per batch happens later, in the consumer-side
        ``_staged_batches`` stager, never hidden in this thread."""
        for batch in pipeline.batches(num):
            with phase_scope(timer, "assemble"):
                if self._sparse_on:
                    host = model_lib.sparse_host_batch(
                        self.dataset.graph, batch, self.model_cfg,
                        buckets=self._buckets,
                    )
                else:
                    host = model_lib.host_batch(
                        self.dataset.graph, batch, self.model_cfg,
                        slot_counts=self._slot_counts,
                    )
            yield host, len(batch.src_ids)

    def _fused_batch_iter(self) -> Iterator[Tuple[jax.Array, int]]:
        """Fused mode's stand-in for the batch stream: the "batch" fed to
        the jitted step is just the per-step PRNG key (sampling happens
        inside the step), so the prefetcher/stager have nothing to do and
        are bypassed entirely."""
        # One batched split, materialized eagerly (before the timed loop
        # starts): per-step fold_in dispatches would cost more than the
        # fused sample itself, and a lazy split would bill the first step.
        keys = list(
            jax.random.split(
                jax.random.PRNGKey(self.cfg.seed), max(self.cfg.num_steps, 1)
            )
        )
        npairs = self.pipe_cfg.batch_pairs
        return iter([(k, npairs) for k in keys[: self.cfg.num_steps]])

    # ------------------------------------------------------ backend planning
    def _copy_params(self, params: Dict) -> Dict:
        """Fresh device copies of a param pytree (donation-safe). device_put
        is the explicit H2D spelling (no-op on already-device leaves)."""
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x).copy(), params
        )

    def _calibrate(self, params: Dict) -> Dict:
        """Measure per-batch host cost, the jitted step, the prefetch
        handoff, and (sampling_backend="auto") the fused step.

        Every measurement runs on throwaway state: a SEPARATE same-seed
        pipeline instance (the training pipeline's stream is untouched, so
        a calibrated run is bitwise-identical to an explicitly-configured
        one) and fresh param/opt-state copies per step rep (the sparse and
        fused steps donate their inputs). The first rep of each series pays
        compile/warmup and is excluded from the medians.
        """
        cfg = self.cfg
        n = max(2, cfg.calibrate_batches)
        pipeline = make_train_sampler(
            self.engine, self.pipe_cfg, backend="host", seed=cfg.seed
        )
        # The host pipeline produces batches in ROUNDS: one walk+ego round
        # fills a carry buffer that the next several batches drain in
        # microseconds. Timing individual batches therefore bimodally mixes
        # round-paying spikes with near-free carries — the meaningful number
        # is the amortized cost over whole rounds. Pull batches until two
        # round spikes are visible and average the window between them;
        # when no second spike appears inside the budget (huge rounds, or
        # every batch pays a round so there are no spikes), fall back to
        # the plain mean, which then over- (never under-) estimates the
        # host cost and so can only bias toward prefetching — the safe
        # direction for an expensive sampler.
        cap, budget_s = 64, 0.5
        host_it = self._host_batches(pipeline, cap)
        durs: List[float] = []
        host_batches: List[Dict] = []
        elapsed = 0.0
        for i in range(cap):
            t0 = time.perf_counter()
            try:
                host, _np_ = next(host_it)
            except StopIteration:
                break
            d = time.perf_counter() - t0
            durs.append(d)
            elapsed += d
            if len(host_batches) < n:
                host_batches.append(host)
            if i + 1 < n:
                continue
            spikes = _round_spikes(durs)
            if len(spikes) >= 2 or elapsed >= budget_s:
                break
        spikes = _round_spikes(durs)
        if len(spikes) >= 2:
            host_s = sum(durs[spikes[0]:spikes[-1]]) / (spikes[-1] - spikes[0])
        else:
            host_s = elapsed / max(1, len(durs))
        meas: Dict = {"host_batch_s": host_s}
        step_times: List[float] = []
        for i in range(n):
            p = self._copy_params(params)
            if self._sparse_on:
                st = self._init_sparse_opt_state(p)
                fn = self._sparse_step
            else:
                st = self.opt.init(p)
                fn = self._grad_step
            dev = jax.device_put(host_batches[i % len(host_batches)])
            t0 = time.perf_counter()
            out = fn(p, st, dev)
            device_barrier(out[2])
            step_times.append(time.perf_counter() - t0)
        meas["step_s"] = median(step_times[1:])
        meas["handoff_s"] = measure_handoff_overhead()
        if cfg.sampling_backend == "auto":
            ok, why = self._build_fused()
            if ok:
                keys = jax.random.split(jax.random.PRNGKey(cfg.seed), n)
                fused_times: List[float] = []
                for i in range(n):
                    p = self._copy_params(params)
                    st = self.opt.init(p)
                    t0 = time.perf_counter()
                    out = self._fused_step(p, st, keys[i])
                    device_barrier(out[2])
                    fused_times.append(time.perf_counter() - t0)
                meas["fused_step_s"] = median(fused_times[1:])
            else:
                meas["fused_ineligible"] = why
                if cfg.telemetry is not None:
                    cfg.telemetry.metrics.counter(
                        "trainer.fused_fallback"
                    ).inc()
                    cfg.telemetry.tracer.mark(
                        "trainer.fused_fallback", reason=why
                    )
        return meas

    def _resolve_plan(self, params: Dict) -> Dict:
        """Resolve the run's execution plan: sampling backend + prefetch
        depth. Explicit settings always win; knobs left at their "auto"
        defaults are decided from the calibration measurements (or legacy
        defaults when calibration is off / the run is too short). Cached —
        repeated train() calls on one trainer calibrate once."""
        if self._plan is not None:
            return self._plan
        cfg = self.cfg
        auto_prefetch = cfg.prefetch_batches is None
        auto_sampling = cfg.sampling_backend == "auto"
        plan: Dict = {
            "engine_backend": cfg.engine_backend,
            "engine_workers": (
                self._engine_workers if cfg.engine_backend == "mp" else None
            ),
            "calibrated": False,
        }
        calibrate = (
            cfg.auto_backend
            and (auto_prefetch or auto_sampling)
            and cfg.num_steps >= cfg.calibrate_min_steps
        )
        if not calibrate:
            plan["sampling"] = (
                "fused" if self._fused_sampler is not None
                and cfg.sampling_backend == "fused" else "host"
            )
            plan["prefetch"] = (
                0 if plan["sampling"] == "fused"
                else (2 if auto_prefetch else cfg.prefetch_batches)
            )
            plan["reason"] = (
                "explicit settings" if not (auto_prefetch or auto_sampling)
                else (
                    "auto_backend off" if not cfg.auto_backend
                    else f"run too short to calibrate "
                         f"(num_steps={cfg.num_steps} < "
                         f"{cfg.calibrate_min_steps}); legacy defaults"
                )
            )
            plan["fused_measured_bytes"] = self._fused_measured_bytes
            self._plan = plan
            return plan
        meas = self._calibrate(params)
        plan["calibrated"] = True
        plan["measurements"] = {k: round(v, 6) if isinstance(v, float) else v
                                for k, v in meas.items()}
        host_s, step_s = meas["host_batch_s"], meas["step_s"]
        handoff_s = meas["handoff_s"]
        # Prefetch pays only when BOTH sides have enough work to hide the
        # queue handoff: the pipelined step time is bounded below by the
        # slower side plus the handoff, and what the overlap can save is at
        # most the cheaper side. Require a clear (>10%) predicted win —
        # the probe can't see GIL contention between the producer's NumPy
        # work and the consumer's dispatches, which is exactly what made
        # prefetching a cheap walk-based sampler a 0.85x regression.
        serial_est = host_s + step_s
        prefetch_est = max(host_s, step_s) + handoff_s
        want_prefetch = serial_est > 1.1 * prefetch_est
        sampling = cfg.sampling_backend if not auto_sampling else "host"
        if auto_sampling and "fused_step_s" in meas:
            if meas["fused_step_s"] < min(serial_est, prefetch_est):
                sampling = "fused"
        if sampling == "fused" and self._fused_sampler is None:
            sampling = "host"  # explicit "fused" that failed the memory gate
        plan["sampling"] = sampling
        if sampling == "fused":
            plan["prefetch"] = 0
            plan["reason"] = (
                f"fused step {meas.get('fused_step_s', 0) * 1e3:.2f}ms < host "
                f"pipeline est {min(serial_est, prefetch_est) * 1e3:.2f}ms"
            )
        elif not auto_prefetch:
            plan["prefetch"] = cfg.prefetch_batches
            plan["reason"] = "explicit prefetch_batches"
        elif want_prefetch:
            plan["prefetch"] = 2
            plan["reason"] = (
                f"prefetch: serial est {serial_est * 1e3:.2f}ms > 1.1x "
                f"pipelined est {prefetch_est * 1e3:.2f}ms (host "
                f"{host_s * 1e3:.2f}ms, step {step_s * 1e3:.2f}ms, handoff "
                f"{handoff_s * 1e6:.0f}us)"
            )
        else:
            plan["prefetch"] = 0
            plan["reason"] = (
                f"serial: pipelining would save <10% (serial est "
                f"{serial_est * 1e3:.2f}ms vs pipelined est "
                f"{prefetch_est * 1e3:.2f}ms) — the queue handoff would "
                "cost more than the overlap hides"
            )
        log.info("backend plan: %s", plan["reason"])
        plan["fused_measured_bytes"] = self._fused_measured_bytes
        self._plan = plan
        return plan

    def train(self, params: Optional[Dict] = None) -> TrainResult:
        cfg = self.cfg
        params = params if params is not None else self.init_params()
        plan = self._resolve_plan(params)
        tel = cfg.telemetry
        tracer = tel.tracer if tel is not None else None
        # Run-health guardrails (cfg.health = a HealthConfig): the monitor
        # watches beats/pulses from its own watchdog thread and observes
        # only already-drained host losses, so enabling it never changes
        # the training stream. The instance is kept on self for tests and
        # post-mortems (trainer._health_monitor.fault, .degraded).
        monitor = None
        if cfg.health is not None:
            from repro.obs.health import HealthMonitor

            monitor = HealthMonitor(
                cfg.health, telemetry=tel, client=self._owned_client
            )
        self._health_monitor = monitor
        # Phase-boundary device-memory accounting (telemetry runs only):
        # live-array peaks per lifecycle phase, surfaced in the metrics
        # summary and the bench 'memory' section (trainer._memory).
        mem = None
        if tel is not None:
            from repro.obs.memory import MemoryAccountant

            mem = MemoryAccountant(tel.metrics)
        self._memory = mem
        # Tracing rides the attribution instrumentation: PhaseTimer with a
        # tracer emits every phase interval as a span (per-thread tracks in
        # the exported trace). The pinned TrainResult.attribution summary
        # stays gated on cfg.attribution alone.
        timer = (
            PhaseTimer(
                tracer=tracer,
                pulse=monitor.pulse if monitor is not None else None,
            )
            if (cfg.attribution or tracer is not None or monitor is not None)
            else None
        )
        use_fused = plan["sampling"] == "fused"
        if use_fused:
            # The fused step donates its param buffers; copy like the
            # sparse path so a caller-held pytree survives.
            params = self._copy_params(params)
            opt_state = self.opt.init(params)
            step_fn = self._fused_step
        elif self._sparse_on:
            # The sparse step donates its param buffers; copy once so a
            # caller-held pytree (e.g. for a later cold-start eval) survives.
            params = self._copy_params(params)
            opt_state = self._init_sparse_opt_state(params)
            step_fn = self._sparse_step
        else:
            opt_state = self.opt.init(params)
            step_fn = self._grad_step
        loss_hist: List[jax.Array] = []  # in-flight on-device tail
        losses: List[float] = []  # drained, completed losses
        pending_drains: List = []  # started async readbacks, FIFO
        depth = plan["prefetch"]
        # Keep at least the prefetch window on device before draining; the
        # drained prefix is steps behind the last dispatch, so the readback
        # barely blocks — and it is started async and resolved a full
        # window later anyway.
        drain_tail = max(1, depth + 1)
        evals: List[Dict[str, float]] = []
        pairs_seen = 0
        steps_done = 0
        prefetcher: Optional[_Prefetcher] = None
        if use_fused:
            batch_iter: Iterator = self._fused_batch_iter()
        else:
            pipeline = make_train_sampler(
                self.engine, self.pipe_cfg, backend="host", seed=cfg.seed,
                timer=timer,
            )
            host_iter: Iterator = self._host_batches(
                pipeline, cfg.num_steps, timer
            )
            if depth > 0:
                prefetcher = _Prefetcher(
                    host_iter, depth,
                    queue_gauge=(
                        tel.metrics.gauge("prefetch.queue_depth")
                        if tel is not None else None
                    ),
                    telemetry=tel,
                    health_check=(
                        monitor.check if monitor is not None else None
                    ),
                )
                host_iter = prefetcher
            batch_iter = _staged_batches(
                host_iter, timer, double_buffer=depth > 0,
                staged_gauge=(
                    tel.metrics.gauge("stager.device_batches")
                    if tel is not None else None
                ),
            )
        if mem is not None:
            # everything long-lived is resident by now: params, opt state,
            # engine shards, and (fused runs) the device sampling tables
            mem.sample("fused" if use_fused else "tables")
        t0 = time.perf_counter()
        if monitor is not None:
            monitor.start()
        try:
            for step, (dev, npairs) in enumerate(batch_iter):
                # Every dispatch runs under the transfer guard: batches were
                # staged by an explicit device_put (or ARE device values —
                # fused keys), so any transfer here is a regression.
                with phase_scope(timer, "dispatch"):
                    with transfer_sanitizer(cfg.sanitize_transfers):
                        params, opt_state, loss = step_fn(
                            params, opt_state, dev
                        )
                loss_hist.append(loss)
                pairs_seen += npairs
                steps_done += 1
                if monitor is not None:
                    monitor.beat(step)
                if cfg.sync_every_step:
                    with phase_scope(timer, "loss_fetch"):
                        v = host_scalar(loss)
                    if monitor is not None:
                        monitor.observe_losses((v,))
                if (
                    cfg.loss_fetch_every
                    and len(loss_hist) >= cfg.loss_fetch_every + drain_tail
                ):
                    done, loss_hist = (
                        loss_hist[:-drain_tail], loss_hist[-drain_tail:]
                    )
                    with phase_scope(timer, "loss_fetch"):
                        # Resolve the PREVIOUS window (its copies have had a
                        # full window of dispatches to complete — near-free)
                        # and start this window's readback without blocking.
                        if pending_drains:
                            drained = pending_drains.pop(0).resolve()
                            losses.extend(drained)
                            if monitor is not None:
                                monitor.observe_losses(drained)
                        pending_drains.append(host_floats_async(done))
                if cfg.log_every and (step + 1) % cfg.log_every == 0:
                    log.info("step %d loss %.4f", step + 1, host_scalar(loss))
                if cfg.eval_every and (step + 1) % cfg.eval_every == 0:
                    evals.append(self.evaluate(params))
        except BaseException:
            # The run is aborted (producer error — possibly a dead engine
            # worker — or a caller interrupt): reap worker processes so
            # nothing outlives the failed train() call.
            self.close()
            raise
        finally:
            if monitor is not None:
                monitor.stop()
            if prefetcher is not None:
                prefetcher.close()
        if loss_hist:
            device_barrier(loss_hist[-1])
        wall = time.perf_counter() - t0
        # Everything is complete past the barrier: resolving the started
        # readbacks (FIFO — loss order is the dispatch order) and the tail
        # costs only the copies.
        observed = len(losses)  # mid-run drains already went past the monitor
        for drain in pending_drains:
            losses.extend(drain.resolve())
        losses.extend(host_floats(loss_hist))
        if monitor is not None:
            # the suffix never went through a mid-run drain window: a run
            # that diverged in its last steps still fails loudly
            monitor.observe_losses(losses[observed:])
        if mem is not None:
            mem.sample("steady")
        if cfg.eval_at_end:
            evals.append(self.evaluate(params))
            if mem is not None:
                mem.sample("eval")
        if tracer is not None and self._owned_client is not None:
            # pull worker serve spans recorded since the last stats round
            # into the tracer before the caller exports the trace
            self._owned_client.drain_worker_spans()
        return TrainResult(
            params=params, losses=losses, eval_history=evals,
            wall_time_s=wall, pairs_seen=pairs_seen, plan=dict(plan),
            attribution=(
                timer.summary(wall, steps_done)
                if (timer is not None and cfg.attribution) else None
            ),
        )
