"""Per-step time attribution for the training loop (`make bench-attr`).

BENCH_throughput.json showed the components flying and the pipeline
crawling (fused sampling 2.7-2.9x yet the fused pipeline 1.15x, the mp
engine 2.4x yet mp end-to-end 0.78x): the trainer loop, not the samplers,
had become the bottleneck, and nothing measured *where* a step's wall time
went. This module is the measuring half of the fix: a sync-free phase
timer the trainer threads through the hot loop, plus the handoff-overhead
probe the auto backend calibration uses.

Design constraints (the H001/H002 lint contract):

- **Sync-free on the hot path.** ``PhaseTimer`` records
  ``time.perf_counter_ns()`` durations into preallocated ring buffers
  (``repro.obs.trace.DurationRing`` — the timer is a thin layer over the
  telemetry subsystem, and optionally mirrors each phase interval as an
  obs span) — no device sync, no allocation, no locks per step. The one
  ``device_barrier`` lives at the end of the measured window (the trainer
  already drains there), never per step.
- **Dispatch != execution.** The "dispatch" phase measures enqueue cost
  of the async jitted step, not device execution. Device time shows up as
  the residual ``wall - consumer-side phases`` (and as blocking inside
  "loss_fetch"/"batch_wait" when the device is the straggler).
- **Single writer per phase.** The producer thread records
  "sample"/"assemble", the consumer thread "h2d"/"batch_wait"/
  "dispatch"/"loss_fetch"; phase buffers are independent so no
  synchronization is needed. Producer-side totals can legitimately exceed
  wall time fractions when overlapped with device compute — that overlap
  is exactly what the report makes visible.

Phases:

- ``sample``   — walker + ego sampling rounds (host pipeline, producer side)
- ``assemble`` — TrainBatch -> host numpy pytree (dedup/remap/padding)
- ``batch_wait`` — consumer blocked on the prefetch queue (starvation)
- ``h2d``      — explicit ``jax.device_put`` staging of a host batch
- ``dispatch`` — enqueue of the jitted grad step (async)
- ``loss_fetch`` — draining completed loss scalars to host
"""
from __future__ import annotations

import contextlib
import queue
import threading
import time
from typing import Dict, Iterable, Optional

from repro.obs.trace import DurationRing, Tracer

PHASES = ("sample", "assemble", "batch_wait", "h2d", "dispatch", "loss_fetch")


class PhaseTimer:
    """Ring-buffered wall-clock attribution of trainer-loop phases.

    ``with timer.phase("dispatch"): ...`` appends one duration to the
    phase's ring buffer (an ``obs.trace.DurationRing``). Buffers are
    fixed-size (``capacity`` per phase); when a run exceeds capacity the
    retained window is extrapolated by count in :meth:`summary`, so long
    runs stay O(capacity) memory with no hot-loop branching.

    Rebase note (telemetry PR): the timer is now a thin aggregation layer
    over ``repro.obs`` — durations land in obs duration rings, and when an
    optional ``tracer`` is wired each phase interval is additionally
    emitted as a span, so the attribution phases appear on the Perfetto
    timeline with per-thread tracks for free. The public API and the
    ``summary()`` schema (the pinned ``step_attribution`` benchmark
    format) are unchanged.
    """

    def __init__(
        self,
        capacity: int = 8192,
        tracer: Optional[Tracer] = None,
        pulse=None,
    ):
        self._cap = int(capacity)
        self._dur: Dict[str, DurationRing] = {
            p: DurationRing(self._cap) for p in PHASES
        }
        self._tracer = tracer
        # optional sub-step liveness callback (HealthMonitor.pulse): fired
        # at every phase exit, so the stall watchdog can tell "steps are
        # slow but phases still move" from "everything froze"
        self._pulse = pulse

    def add(self, name: str, seconds: float) -> None:
        self._dur[name].add(seconds)

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            dur_ns = time.perf_counter_ns() - t0
            self._dur[name].add(dur_ns * 1e-9)
            if self._tracer is not None:
                self._tracer.add_span(name, "phase", t0, dur_ns)
            if self._pulse is not None:
                self._pulse()

    def total(self, name: str) -> float:
        """Total seconds attributed to ``name`` (ring window extrapolated)."""
        return self._dur[name].total()

    def summary(
        self, wall_s: Optional[float] = None, steps: Optional[int] = None
    ) -> Dict:
        """Per-phase totals/means + consumer-side accounting vs wall time.

        ``host_visible_s`` sums the phases that run on the consumer thread
        and therefore directly extend the step loop; ``device_residual_s``
        is the remaining wall time — device execution plus anything not
        instrumented. Producer phases ("sample"/"assemble") overlap device
        compute when prefetching, so their fractions are reported against
        wall but may legitimately sum past it.
        """
        phases: Dict[str, Dict] = {}
        for p in PHASES:
            n = self._dur[p].count
            if n == 0:
                continue
            tot = self.total(p)
            entry = {"count": n, "total_s": round(tot, 6),
                     "per_call_us": round(tot / n * 1e6, 2)}
            if wall_s:
                entry["frac_of_wall"] = round(tot / wall_s, 4)
            phases[p] = entry
        out: Dict = {"phases": phases}
        if wall_s is not None:
            out["wall_s"] = round(wall_s, 6)
            consumer = ("batch_wait", "h2d", "dispatch", "loss_fetch")
            host_vis = sum(
                self.total(p) for p in consumer if self._dur[p].count
            )
            out["host_visible_s"] = round(host_vis, 6)
            out["device_residual_s"] = round(max(0.0, wall_s - host_vis), 6)
        if steps:
            out["steps"] = int(steps)
            if wall_s is not None:
                out["wall_us_per_step"] = round(wall_s / steps * 1e6, 2)
        return out


def phase_scope(timer: Optional[PhaseTimer], name: Optional[str]):
    """``timer.phase(name)`` when attribution is wired, else a no-op
    context — call sites thread one optional timer without branching."""
    if timer is None or name is None:
        return contextlib.nullcontext()
    return timer.phase(name)


def measure_handoff_overhead(items: int = 512, depth: int = 2) -> float:
    """Measured per-item cost (seconds) of the prefetch queue handoff.

    Spins a producer thread pushing ``items`` tokens through a bounded
    ``queue.Queue`` (the exact structure ``_Prefetcher`` uses) while the
    caller consumes them, and returns wall / items. This is the floor a
    host sampler must clear for prefetching to pay: when a batch costs
    less to *produce* than to *hand over*, the serial path wins
    (BENCH_throughput.json's 0.85x walk-based prefetch regression). The
    auto backend calibration compares this number against the measured
    per-batch host cost.
    """
    q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
    token = object()

    def produce() -> None:
        for _ in range(items):
            q.put(token)

    t = threading.Thread(
        target=produce, name="repro-handoff-probe", daemon=True
    )
    t0 = time.perf_counter()
    t.start()
    for _ in range(items):
        q.get()
    wall = time.perf_counter() - t0
    t.join()
    return wall / items


def median(xs: Iterable[float]) -> float:
    """Median of a small sample (calibration helper; no numpy dtype games)."""
    s = sorted(xs)
    if not s:
        raise ValueError("median of empty sample")
    mid = len(s) // 2
    if len(s) % 2:
        return s[mid]
    return 0.5 * (s[mid - 1] + s[mid])
