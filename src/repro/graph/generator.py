"""Synthetic heterogeneous recsys datasets (schema-faithful stand-ins).

The paper evaluates on RetailRocket / Rec15 / Tmall / UB — multi-behavior
user--item interaction logs. Those dumps are not available offline, so we
synthesize graphs with the same *shape*: power-law item popularity, per-user
session behavior, multiple edge types (click / buy / cart / fav), timestamps,
and an 80/10/10 per-user temporal split (paper §4.1). Cluster structure is
planted (users/items grouped into latent interest clusters) so that recall@K
is a meaningful signal: a model that learns the latent structure scores far
above chance, which lets us reproduce the paper's *relative* claims.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.graph.hetero_graph import HeteroGraph, SlotFeature


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Scale knobs for a synthetic multi-behavior dataset."""

    name: str
    num_users: int
    num_items: int
    num_clusters: int
    # interactions per behavior (approximate totals)
    behaviors: Mapping[str, int]
    # probability a user interacts inside their own cluster
    affinity: float = 0.85
    # zipf exponent for item popularity inside a cluster
    zipf_a: float = 1.3
    num_side_slots: int = 2
    side_vocab: int = 64


# Small-scale analogues of the paper's four datasets (Table 1), shrunk to run
# on CPU in seconds. Ratios between behaviors follow the originals.
RETAILROCKET = DatasetSpec(
    "retailrocket", num_users=2000, num_items=3000, num_clusters=20,
    behaviors={"click": 18000, "buy": 600, "cart": 1500},
)
REC15 = DatasetSpec(
    "rec15", num_users=5000, num_items=1200, num_clusters=24,
    behaviors={"click": 52000, "buy": 2000},
)
TMALL = DatasetSpec(
    "tmall", num_users=3000, num_items=6000, num_clusters=30,
    behaviors={"click": 60000, "buy": 3600, "cart": 30, "fav": 4000},
)
UB = DatasetSpec(
    "ub", num_users=8000, num_items=20000, num_clusters=40,
    behaviors={"click": 120000, "buy": 2400, "cart": 6600, "fav": 3700},
)
TOY = DatasetSpec(
    "toy", num_users=200, num_items=300, num_clusters=8,
    behaviors={"click": 3000, "buy": 300},
)

SPECS: Dict[str, DatasetSpec] = {
    s.name: s for s in (RETAILROCKET, REC15, TMALL, UB, TOY)
}


@dataclasses.dataclass
class RecsysDataset:
    """A generated dataset: train graph + held-out (user, item) interactions."""

    spec: DatasetSpec
    graph: HeteroGraph  # built from TRAIN interactions only
    train_edges: Dict[str, Tuple[np.ndarray, np.ndarray]]  # behavior -> (u, i) local ids
    val_pairs: np.ndarray  # (Nv, 2) local (user, item)
    test_pairs: np.ndarray  # (Nt, 2) local (user, item)
    user_clusters: np.ndarray
    item_clusters: np.ndarray

    @property
    def num_users(self) -> int:
        return self.spec.num_users

    @property
    def num_items(self) -> int:
        return self.spec.num_items

    def user_global(self, u: np.ndarray) -> np.ndarray:
        return np.asarray(u)  # users occupy [0, num_users)

    def item_global(self, i: np.ndarray) -> np.ndarray:
        return np.asarray(i) + self.spec.num_users


def generate(spec: DatasetSpec, seed: int = 0) -> RecsysDataset:
    rng = np.random.default_rng(seed)
    user_clusters = rng.integers(0, spec.num_clusters, size=spec.num_users)
    item_clusters = rng.integers(0, spec.num_clusters, size=spec.num_items)
    items_by_cluster: List[np.ndarray] = [
        np.flatnonzero(item_clusters == c) for c in range(spec.num_clusters)
    ]
    # guarantee every cluster has items
    for c, arr in enumerate(items_by_cluster):
        if len(arr) == 0:
            items_by_cluster[c] = rng.integers(0, spec.num_items, size=4)

    all_events: List[Tuple[int, int, str, int]] = []  # (u, i, behavior, t)
    t = 0
    for behavior, total in spec.behaviors.items():
        if total <= 0:
            continue
        users = rng.integers(0, spec.num_users, size=total)
        in_cluster = rng.random(total) < spec.affinity
        items = np.empty(total, dtype=np.int64)
        for k in range(total):
            pool = (
                items_by_cluster[user_clusters[users[k]]]
                if in_cluster[k]
                else None
            )
            if pool is not None and len(pool):
                # zipf-ish rank sampling inside the cluster
                rank = int(rng.zipf(spec.zipf_a)) - 1
                items[k] = pool[min(rank, len(pool) - 1)]
            else:
                items[k] = rng.integers(0, spec.num_items)
        times = rng.integers(0, 1_000_000, size=total)
        all_events.extend(
            (int(u), int(i), behavior, int(tt)) for u, i, tt in zip(users, items, times)
        )

    # per-user temporal 80/10/10 split (paper §4.1)
    by_user: Dict[int, List[Tuple[int, int, str, int]]] = {}
    for ev in all_events:
        by_user.setdefault(ev[0], []).append(ev)
    train_ev: List[Tuple[int, int, str]] = []
    val_pairs: List[Tuple[int, int]] = []
    test_pairs: List[Tuple[int, int]] = []
    for u, evs in by_user.items():
        evs.sort(key=lambda e: e[3])
        n = len(evs)
        n_tr = max(1, int(0.8 * n))
        n_va = max(0, int(0.1 * n))
        for e in evs[:n_tr]:
            train_ev.append((e[0], e[1], e[2]))
        for e in evs[n_tr : n_tr + n_va]:
            val_pairs.append((e[0], e[1]))
        for e in evs[n_tr + n_va :]:
            test_pairs.append((e[0], e[1]))

    train_edges: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
    for behavior in spec.behaviors:
        us = np.array([e[0] for e in train_ev if e[2] == behavior], dtype=np.int64)
        is_ = np.array([e[1] for e in train_ev if e[2] == behavior], dtype=np.int64)
        if len(us):
            train_edges[behavior] = (us, is_)

    slots = _make_side_slots(spec, rng, item_clusters, user_clusters)
    graph = HeteroGraph.from_edges(
        node_counts={"u": spec.num_users, "i": spec.num_items},
        edges={f"u2{b}2i": e for b, e in train_edges.items()},
        symmetry=True,
        slots=slots,
    )
    return RecsysDataset(
        spec=spec,
        graph=graph,
        train_edges=train_edges,
        val_pairs=np.array(val_pairs, dtype=np.int64).reshape(-1, 2),
        test_pairs=np.array(test_pairs, dtype=np.int64).reshape(-1, 2),
        user_clusters=user_clusters,
        item_clusters=item_clusters,
    )


def _make_side_slots(
    spec: DatasetSpec,
    rng: np.random.Generator,
    item_clusters: np.ndarray,
    user_clusters: np.ndarray,
) -> Dict[str, SlotFeature]:
    """Side info correlated with the latent clusters (category/brand/profile).

    Slot 0 ("category") is the item's cluster id plus noise — informative.
    Slot 1+ are weakly-informative tags with variable length (1..3 values),
    exercising the paper's multi-value slot support.
    """
    num_nodes = spec.num_users + spec.num_items
    slots: Dict[str, SlotFeature] = {}
    for s in range(spec.num_side_slots):
        lengths = rng.integers(1, 4, size=num_nodes)
        indptr = np.zeros(num_nodes + 1, dtype=np.int64)
        np.cumsum(lengths, out=indptr[1:])
        values = rng.integers(0, spec.side_vocab, size=int(indptr[-1])).astype(np.int32)
        if s == 0:
            # category slot: first value is cluster id (noisy 10%)
            for u in range(spec.num_users):
                if rng.random() > 0.1:
                    values[indptr[u]] = user_clusters[u] % spec.side_vocab
            for i in range(spec.num_items):
                v = spec.num_users + i
                if rng.random() > 0.1:
                    values[indptr[v]] = item_clusters[i] % spec.side_vocab
        slots[f"slot{s}"] = SlotFeature(
            indptr=indptr, values=values, vocab_size=spec.side_vocab
        )
    return slots
