"""Heterogeneous graph structure (Graph4Rec §3.1).

A heterogeneous graph is decomposed into bipartite directed relations. A
relation is named by a triple string ``"<src>2<etype>2<dst>"`` — e.g.
``"u2click2i"`` is user --click--> item, and when ``symmetry=True`` the
reverse relation ``"i2click2u"`` is added automatically, exactly as the paper
describes. A homogeneous graph is the degenerate case ``"u2u"`` /
``"u2u2u"``.

Node ids are global integers. Each node type owns a contiguous id range so
that type-partitioned embedding tables and per-type metrics are cheap.
Adjacency is stored per relation in CSR over the *global* id space (indptr of
length num_nodes+1; rows for nodes that are not of the relation's source type
are empty). This uniform layout keeps every sampler branch-free.

Side information (paper §3.5 "configurable sparse features with multiple
slots", variable length per node) is stored per slot as a ragged
(indptr, values) pair.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

DELIM = "2"  # the paper uses "2" as the triple delimiter


@dataclasses.dataclass(frozen=True)
class Relation:
    """Parsed relation triple (source type, edge type, destination type)."""

    name: str
    src_type: str
    etype: str
    dst_type: str

    @staticmethod
    def parse(name: str) -> "Relation":
        parts = name.split(DELIM)
        if len(parts) == 2:  # homogeneous shorthand "u2u"
            src, dst = parts
            etype = "link"
        elif len(parts) == 3:
            src, etype, dst = parts
        else:
            raise ValueError(
                f"relation {name!r} must be '<src>2<dst>' or '<src>2<etype>2<dst>'"
            )
        return Relation(name=name, src_type=src, etype=etype, dst_type=dst)

    @property
    def reverse_name(self) -> str:
        return f"{self.dst_type}{DELIM}{self.etype}{DELIM}{self.src_type}"


@dataclasses.dataclass
class CSR:
    """Compact adjacency for one relation over the global node id space."""

    indptr: np.ndarray  # int64 (num_nodes + 1,)
    indices: np.ndarray  # int32 (num_edges,)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, node: int) -> np.ndarray:
        return self.indices[self.indptr[node] : self.indptr[node + 1]]


@dataclasses.dataclass
class SlotFeature:
    """Ragged per-node sparse feature slot (variable-length values)."""

    indptr: np.ndarray  # int64 (num_nodes + 1,)
    values: np.ndarray  # int32 (total_values,) — ids into the slot's vocab
    vocab_size: int

    def values_of(self, node: int) -> np.ndarray:
        return self.values[self.indptr[node] : self.indptr[node + 1]]


class HeteroGraph:
    """In-memory heterogeneous graph with per-relation CSR adjacency."""

    def __init__(
        self,
        node_type_ranges: Mapping[str, Tuple[int, int]],
        relations: Mapping[str, CSR],
        slots: Optional[Mapping[str, SlotFeature]] = None,
    ):
        self.node_type_ranges = dict(node_type_ranges)  # type -> (start, count)
        self.num_nodes = int(
            max(start + count for start, count in node_type_ranges.values())
        )
        self.relations: Dict[str, CSR] = dict(relations)
        self.relation_meta: Dict[str, Relation] = {
            name: Relation.parse(name) for name in relations
        }
        self.slots: Dict[str, SlotFeature] = dict(slots or {})
        for name, csr in self.relations.items():
            if csr.indptr.shape[0] != self.num_nodes + 1:
                raise ValueError(
                    f"relation {name}: indptr length {csr.indptr.shape[0]} != "
                    f"num_nodes+1 ({self.num_nodes + 1})"
                )

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_edges(
        node_counts: Mapping[str, int],
        edges: Mapping[str, Tuple[np.ndarray, np.ndarray]],
        symmetry: bool = True,
        slots: Optional[Mapping[str, SlotFeature]] = None,
    ) -> "HeteroGraph":
        """Build from per-relation (src_local, dst_local) edge arrays.

        ``src_local``/``dst_local`` are ids *local to their node type*; this
        constructor lays node types into contiguous global ranges in the
        iteration order of ``node_counts`` and offsets the edges accordingly.
        With ``symmetry=True`` the reverse relation is added for every
        relation whose reverse is not explicitly given (paper §3.1).
        """
        ranges: Dict[str, Tuple[int, int]] = {}
        offset = 0
        for ntype, count in node_counts.items():
            ranges[ntype] = (offset, int(count))
            offset += int(count)
        num_nodes = offset

        # Globalize edges, optionally add reverses.
        glob_edges: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        for name, (src, dst) in edges.items():
            rel = Relation.parse(name)
            s_off = ranges[rel.src_type][0]
            d_off = ranges[rel.dst_type][0]
            gsrc = np.asarray(src, dtype=np.int64) + s_off
            gdst = np.asarray(dst, dtype=np.int64) + d_off
            glob_edges[rel.name] = (gsrc, gdst)
        if symmetry:
            for name in list(glob_edges):
                rel = Relation.parse(name)
                rname = rel.reverse_name
                if rname not in glob_edges:
                    gsrc, gdst = glob_edges[name]
                    glob_edges[rname] = (gdst.copy(), gsrc.copy())

        rels = {
            name: _csr_from_pairs(num_nodes, gsrc, gdst)
            for name, (gsrc, gdst) in glob_edges.items()
        }
        return HeteroGraph(ranges, rels, slots=slots)

    # ----------------------------------------------------------------- access
    def node_type_of(self, node: int) -> str:
        for ntype, (start, count) in self.node_type_ranges.items():
            if start <= node < start + count:
                return ntype
        raise KeyError(node)

    def nodes_of_type(self, ntype: str) -> np.ndarray:
        start, count = self.node_type_ranges[ntype]
        return np.arange(start, start + count, dtype=np.int64)

    def relation_names(self) -> List[str]:
        return list(self.relations)

    @property
    def num_edges(self) -> int:
        return sum(csr.num_edges for csr in self.relations.values())

    def degrees(self, relation: str) -> np.ndarray:
        return self.relations[relation].degrees()

    # --------------------------------------------------------------- sampling
    def sample_neighbors(
        self,
        rng: np.random.Generator,
        nodes: np.ndarray,
        relation: str,
        num_samples: int,
        pad_id: int = -1,
    ) -> np.ndarray:
        """Uniform with-replacement neighbor sampling.

        Returns (len(nodes), num_samples) int64, padded with ``pad_id`` where
        a node has no neighbors under ``relation``. This is the single
        primitive the distributed engine (graph/engine.py) distributes.
        """
        csr = self.relations[relation]
        nodes = np.asarray(nodes, dtype=np.int64)
        starts = csr.indptr[nodes]
        degs = csr.indptr[nodes + 1] - starts
        out = np.full((len(nodes), num_samples), pad_id, dtype=np.int64)
        has = degs > 0
        if has.any():
            offs = rng.integers(
                0, np.maximum(degs[has][:, None], 1), size=(int(has.sum()), num_samples)
            )
            out[has] = csr.indices[starts[has][:, None] + offs]
        return out

    # ------------------------------------------------------ dense jax export
    def padded_adjacency(
        self, relation: str, max_degree: int, pad_id: int = -1, seed: int = 0
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fixed-width adjacency (num_nodes, max_degree) + true degrees.

        Used by the fully-jittable on-device sampler: wide rows are truncated
        (uniform subsample), short rows padded. Returns (adj, degree).

        The subsample is keyed by ``[seed, node id]`` (the partition_rng
        spawn-key idiom), so two builds with the same seed are bitwise
        identical while the caller's seed still reaches every draw.
        """
        from repro.utils.ragged import ragged_row_offsets

        csr = self.relations[relation]
        adj = np.full((self.num_nodes, max_degree), pad_id, dtype=np.int64)
        degs = csr.degrees()
        # rows that fit: one vectorized ragged-to-padded scatter
        clipped = np.minimum(degs, max_degree).astype(np.int64)
        starts = np.asarray(csr.indptr[:-1], dtype=np.int64)
        if clipped.sum():
            row_of, col = ragged_row_offsets(clipped)
            adj[row_of, col] = csr.indices[starts[row_of] + col]
        # over-wide rows: per-row uniform subsample without replacement,
        # deterministically keyed by (seed, node id) — stable across calls
        # AND derived from the caller seed, never the node id alone
        for v in np.flatnonzero(degs > max_degree):
            adj[v] = np.random.default_rng([seed, int(v)]).choice(
                csr.neighbors(v), max_degree, replace=False
            )
        return adj, clipped


def _csr_from_pairs(num_nodes: int, src: np.ndarray, dst: np.ndarray) -> CSR:
    order = np.argsort(src, kind="stable")
    src_sorted = src[order]
    dst_sorted = dst[order].astype(np.int32)
    counts = np.bincount(src_sorted, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(indptr=indptr, indices=dst_sorted)
