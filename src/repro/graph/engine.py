"""Distributed graph engine (Graph4Rec §3.1, "Distributed Graph Engine").

The paper partitions nodes uniformly across machines and stores each node's
adjacency list on its owning server; samplers issue (possibly remote) neighbor
requests. On TPU pods the graph engine remains a *host-side* component — it
never runs on the accelerator in the paper either — so we reproduce it as a
sharded NumPy engine with the same ownership semantics:

- nodes are assigned to partitions by ``node_id % num_partitions``;
- each partition holds CSR rows only for the nodes it owns;
- a batched ``sample_neighbors`` routes each query to its owner and gathers
  the replies, counting *cross-partition requests* — the communication the
  paper's §3.6 order-exchange optimization reduces. These counters are what
  benchmarks/bench_order.py reports alongside wall-clock.

The engine is API-compatible with ``HeteroGraph.sample_neighbors`` so the
sampling pipeline (repro/sampling) can run against either.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.graph.hetero_graph import CSR, HeteroGraph


@dataclasses.dataclass
class EngineStats:
    """Counters mirroring the paper's communication-cost discussion."""

    neighbor_requests: int = 0  # total node->neighbors queries
    cross_partition_requests: int = 0  # queries answered by a remote partition
    batches: int = 0

    def reset(self) -> None:
        self.neighbor_requests = 0
        self.cross_partition_requests = 0
        self.batches = 0


class _Partition:
    """One graph server: adjacency of the nodes it owns, per relation."""

    def __init__(self, part_id: int, num_parts: int, graph: HeteroGraph):
        self.part_id = part_id
        self.num_parts = num_parts
        # Store only owned rows, re-indexed by local row = global // num_parts.
        self.rel_rows: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        owned = np.arange(part_id, graph.num_nodes, num_parts, dtype=np.int64)
        for name, csr in graph.relations.items():
            starts = csr.indptr[owned]
            ends = csr.indptr[owned + 1]
            lengths = ends - starts
            indptr = np.zeros(len(owned) + 1, dtype=np.int64)
            np.cumsum(lengths, out=indptr[1:])
            indices = np.empty(int(indptr[-1]), dtype=csr.indices.dtype)
            for k in range(len(owned)):
                indices[indptr[k] : indptr[k + 1]] = csr.indices[starts[k] : ends[k]]
            self.rel_rows[name] = (indptr, indices)

    def sample(
        self,
        rng: np.random.Generator,
        local_rows: np.ndarray,
        relation: str,
        num_samples: int,
        pad_id: int,
    ) -> np.ndarray:
        indptr, indices = self.rel_rows[relation]
        starts = indptr[local_rows]
        degs = indptr[local_rows + 1] - starts
        out = np.full((len(local_rows), num_samples), pad_id, dtype=np.int64)
        has = degs > 0
        if has.any():
            offs = rng.integers(
                0, np.maximum(degs[has][:, None], 1), size=(int(has.sum()), num_samples)
            )
            out[has] = indices[starts[has][:, None] + offs]
        return out


class DistributedGraphEngine:
    """Node-partitioned graph engine with request routing + stats."""

    def __init__(self, graph: HeteroGraph, num_partitions: int = 4, client_part: int = 0):
        self.graph = graph
        self.num_partitions = int(num_partitions)
        self.client_part = int(client_part)  # partition co-located with the caller
        self.partitions = [
            _Partition(p, self.num_partitions, graph) for p in range(self.num_partitions)
        ]
        self.stats = EngineStats()
        self.relation_names = graph.relation_names()
        self.num_nodes = graph.num_nodes

    # drop-in for HeteroGraph.sample_neighbors
    def sample_neighbors(
        self,
        rng: np.random.Generator,
        nodes: np.ndarray,
        relation: str,
        num_samples: int,
        pad_id: int = -1,
    ) -> np.ndarray:
        nodes = np.asarray(nodes, dtype=np.int64)
        self.stats.batches += 1
        self.stats.neighbor_requests += len(nodes)
        owners = nodes % self.num_partitions
        self.stats.cross_partition_requests += int((owners != self.client_part).sum())
        out = np.empty((len(nodes), num_samples), dtype=np.int64)
        for p in range(self.num_partitions):
            mask = owners == p
            if not mask.any():
                continue
            local_rows = nodes[mask] // self.num_partitions
            out[mask] = self.partitions[p].sample(
                rng, local_rows, relation, num_samples, pad_id
            )
        return out

    # walkers also need single-neighbor steps; reuse the batched path
    def step(
        self, rng: np.random.Generator, nodes: np.ndarray, relation: str, pad_id: int = -1
    ) -> np.ndarray:
        return self.sample_neighbors(rng, nodes, relation, 1, pad_id)[:, 0]
