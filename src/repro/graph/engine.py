"""Distributed graph engine (Graph4Rec §3.1, "Distributed Graph Engine").

The paper partitions nodes uniformly across machines and stores each node's
adjacency list on its owning server; samplers issue (possibly remote) neighbor
requests. On TPU pods the graph engine remains a *host-side* component — it
never runs on the accelerator in the paper either — so we reproduce it as a
sharded NumPy engine with the same ownership semantics:

- nodes are assigned to partitions by ``node_id % num_partitions``;
- each partition holds CSR rows only for the nodes it owns;
- a batched ``sample_neighbors`` routes each query to its owner and gathers
  the replies, counting *cross-partition requests* — the communication the
  paper's §3.6 order-exchange optimization reduces. These counters are what
  benchmarks/bench_order.py reports alongside wall-clock.

The engine is API-compatible with ``HeteroGraph.sample_neighbors`` so the
sampling pipeline (repro/sampling) can run against either.

Randomness contract (shared with the out-of-process engine in
``graph/service``): a ``sample_neighbors``/``sample_many`` call draws ONE
64-bit seed per query from the caller's generator and derives an independent
per-partition generator ``default_rng([seed, part_id])`` for the actual
offset draws. Results therefore depend only on (caller stream, partition
contents) — never on which process answers a partition or how concurrent
callers interleave — which is what makes the multi-process backend
(``graph/service.GraphClient``) bitwise-identical to this one.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.graph.hetero_graph import CSR, HeteroGraph
from repro.utils.ragged import ragged_row_offsets

# Exclusive upper bound for the per-query seed draw (full int64 range).
SEED_BOUND = np.iinfo(np.int64).max


def partition_rng(seed: int, part_id: int) -> np.random.Generator:
    """The per-(query, partition) generator both engine backends use."""
    return np.random.default_rng([int(seed), int(part_id)])


def sample_csr_rows(
    indptr: np.ndarray,
    indices: np.ndarray,
    prng: np.random.Generator,
    local_rows: np.ndarray,
    num_samples: int,
    pad_id: int,
    degs_all: Optional[np.ndarray] = None,
    out: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Uniform with-replacement sampling from CSR rows — the one primitive
    every partition server (in-process or worker process) runs.

    ``degs_all`` (precomputed full-shard degree array) and ``out`` (a
    caller-provided output buffer, e.g. an int32 view into a shared-memory
    reply slab) are worker-process fast paths; results are bitwise-equal to
    the defaults because the random draws see the same numeric bounds.
    """
    starts = indptr[local_rows]
    if degs_all is not None:
        degs = degs_all[local_rows]
    else:
        degs = indptr[local_rows + 1] - starts
    if out is None:
        out = np.full((len(local_rows), num_samples), pad_id, dtype=np.int64)
    else:
        out.fill(pad_id)
    has = degs > 0
    if has.any():
        offs = prng.integers(
            0, np.maximum(degs[has][:, None], 1), size=(int(has.sum()), num_samples)
        )
        out[has] = indices[starts[has][:, None] + offs]
    return out


def engine_sample_many(engine, rng: np.random.Generator, queries: Sequence[Tuple]):
    """Batched multi-query sampling against any engine-like object.

    ``queries`` is a sequence of ``(nodes, relation, num_samples, pad_id)``.
    Engines that implement ``sample_many`` (both graph-engine backends) get
    the whole group in one call — the mp client turns it into one request
    round per worker; plain ``HeteroGraph`` falls back to a per-query loop.
    """
    fn = getattr(engine, "sample_many", None)
    if fn is not None:
        return fn(rng, queries)
    return [
        engine.sample_neighbors(rng, nodes, rel, k, pad_id=pad)
        for nodes, rel, k, pad in queries
    ]


@dataclasses.dataclass
class EngineStats:
    """Counters mirroring the paper's communication-cost discussion.

    Updates go through ``add`` under a lock: the prefetching trainer samples
    from a producer thread while mid-training evaluation samples from the
    main thread, and unguarded ``+=`` would drop increments.
    """

    neighbor_requests: int = 0  # total node->neighbors queries
    cross_partition_requests: int = 0  # queries answered by a remote partition
    batches: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def add(self, requests: int, cross: int) -> None:
        with self._lock:
            self.batches += 1
            self.neighbor_requests += requests
            self.cross_partition_requests += cross

    def reset(self) -> None:
        with self._lock:
            self.neighbor_requests = 0
            self.cross_partition_requests = 0
            self.batches = 0


def _gather_rows(
    indptr: np.ndarray, indices: np.ndarray, rows: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Gather CSR ``rows`` into a compacted sub-CSR with one vectorized slice.

    Builds a flat source-index array mapping every output position to its
    position in ``indices`` (start of its row plus offset within the row), so
    the whole copy is a single fancy-index gather — no per-node Python loop.
    """
    starts = indptr[rows]
    lengths = indptr[rows + 1] - starts
    out_indptr = np.zeros(len(rows) + 1, dtype=np.int64)
    np.cumsum(lengths, out=out_indptr[1:])
    row_of, offsets = ragged_row_offsets(lengths)
    out_indices = indices[starts[row_of] + offsets]
    return out_indptr, out_indices


def _gather_rows_loop(
    indptr: np.ndarray, indices: np.ndarray, rows: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Reference per-node row-copy loop (the seed implementation).

    Kept for the vectorized-equivalence test and benchmarks/bench_throughput's
    loop-vs-vectorized build comparison; not used on the production path.
    """
    starts = indptr[rows]
    ends = indptr[rows + 1]
    lengths = ends - starts
    out_indptr = np.zeros(len(rows) + 1, dtype=np.int64)
    np.cumsum(lengths, out=out_indptr[1:])
    out_indices = np.empty(int(out_indptr[-1]), dtype=indices.dtype)
    for k in range(len(rows)):
        out_indices[out_indptr[k] : out_indptr[k + 1]] = indices[starts[k] : ends[k]]
    return out_indptr, out_indices


class _Partition:
    """One graph server: adjacency of the nodes it owns, per relation."""

    def __init__(
        self, part_id: int, num_parts: int, graph: HeteroGraph, build: str = "vectorized"
    ):
        self.part_id = part_id
        self.num_parts = num_parts
        gather = {"vectorized": _gather_rows, "loop": _gather_rows_loop}[build]
        # Store only owned rows, re-indexed by local row = global // num_parts.
        self.rel_rows: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        owned = np.arange(part_id, graph.num_nodes, num_parts, dtype=np.int64)
        for name, csr in graph.relations.items():
            self.rel_rows[name] = gather(csr.indptr, csr.indices, owned)

    def sample(
        self,
        rng: np.random.Generator,
        local_rows: np.ndarray,
        relation: str,
        num_samples: int,
        pad_id: int,
    ) -> np.ndarray:
        indptr, indices = self.rel_rows[relation]
        return sample_csr_rows(indptr, indices, rng, local_rows, num_samples, pad_id)


class DistributedGraphEngine:
    """Node-partitioned graph engine with request routing + stats."""

    def __init__(
        self,
        graph: HeteroGraph,
        num_partitions: int = 4,
        client_part: int = 0,
        build: str = "vectorized",
    ):
        self.graph = graph
        self.num_partitions = int(num_partitions)
        self.client_part = int(client_part)  # partition co-located with the caller
        self.partitions = [
            _Partition(p, self.num_partitions, graph, build=build)
            for p in range(self.num_partitions)
        ]
        self.stats = EngineStats()
        self.relation_names = graph.relation_names()
        self.num_nodes = graph.num_nodes

    # drop-in for HeteroGraph.sample_neighbors
    def sample_neighbors(
        self,
        rng: np.random.Generator,
        nodes: np.ndarray,
        relation: str,
        num_samples: int,
        pad_id: int = -1,
    ) -> np.ndarray:
        nodes = np.asarray(nodes, dtype=np.int64)
        seed = int(rng.integers(0, SEED_BOUND))
        owners = nodes % self.num_partitions
        self.stats.add(len(nodes), int((owners != self.client_part).sum()))
        out = np.empty((len(nodes), num_samples), dtype=np.int64)
        for p in range(self.num_partitions):
            mask = owners == p
            if not mask.any():
                continue
            local_rows = nodes[mask] // self.num_partitions
            out[mask] = self.partitions[p].sample(
                partition_rng(seed, p), local_rows, relation, num_samples, pad_id
            )
        return out

    def sample_many(
        self, rng: np.random.Generator, queries: Sequence[Tuple]
    ) -> List[np.ndarray]:
        """Serve a group of ``(nodes, relation, num_samples, pad_id)`` queries.

        In-process this is a plain loop; the signature (and the one-seed-per-
        query randomness contract) matches ``GraphClient.sample_many``, which
        dispatches the same group as one pipelined request round per worker.
        """
        return [
            self.sample_neighbors(rng, nodes, rel, k, pad_id=pad)
            for nodes, rel, k, pad in queries
        ]

    # walkers also need single-neighbor steps; reuse the batched path
    def step(
        self, rng: np.random.Generator, nodes: np.ndarray, relation: str, pad_id: int = -1
    ) -> np.ndarray:
        return self.sample_neighbors(rng, nodes, relation, 1, pad_id)[:, 0]
