from repro.graph.hetero_graph import HeteroGraph, Relation, CSR, SlotFeature
from repro.graph.generator import (
    DatasetSpec, RecsysDataset, generate, SPECS,
    RETAILROCKET, REC15, TMALL, UB, TOY,
)
from repro.graph.engine import (
    DistributedGraphEngine, EngineStats, engine_sample_many,
)
from repro.graph.service import EngineWorkerError, GraphClient
