"""Async multi-process graph-engine client (Graph4Rec §3.1, out-of-process).

``GraphClient`` is a drop-in for ``DistributedGraphEngine`` /
``HeteroGraph.sample_neighbors``: same partition ownership
(``node % num_partitions``), same request counters, and — because both
backends derive per-(query, partition) generators from one seed drawn off
the caller's RNG (see ``graph/engine.py``) — bitwise-identical samples under
a fixed seed. The difference is *where* partitions live: CSR shards sit in
POSIX shared memory and are served by dedicated worker processes, so
sampling scales past the trainer's single Python core and the prefetch
thread is never sampling-bound.

Request flow (the paper's batched-RPC graph servers):

- ``submit`` owner-sorts every query's nodes once (stable argsort) and
  dispatches a whole query group — a walker step or ego hop — as one
  request round. Payloads ride in per-worker shared-memory slab slots, not
  pickles: with "balanced" dispatch the chosen worker receives the sorted
  nodes plus the caller-order index and composes its int32 replies in
  caller order inside the slab, so the client's entire per-sample cost is
  one contiguous copy; with "owner" dispatch (the paper's multi-machine
  layout) per-partition sub-requests fan out to each partition's owner and
  the client row-scatters the replies out of the slabs.
- a background reader thread drains reply tags eagerly into an inbox, so a
  worker can never block on a full reply pipe while the client is blocked
  sending (the classic duplex-pipe deadlock), and worker death is noticed
  immediately instead of hanging a ``recv``.
- ``gather`` waits on the inbox and assembles per-query output arrays;
  slab slots are recycled through a per-worker semaphore ring, which also
  bounds pipelining depth.
- ``sample_many`` / ``sample_neighbors`` are the synchronous wrappers the
  walker, ego sampler, and pipeline consume unchanged.
- with ``local_threshold > 0`` the client serves *small* rounds itself from
  zero-copy views over its own shard segments (hybrid serving): tiny rounds
  are latency-bound, and skipping the pipe round-trip beats any worker on
  hosts where workers share cores with the trainer. The sampling core and
  seeding are exactly the worker's, so results stay bitwise identical.

Every failure mode raises ``EngineWorkerError`` (worker traceback, death, or
timeout) rather than blocking: the trainer's prefetch thread propagates it
to ``train()`` which reaps the workers. Shutdown is idempotent and also
hooked to a ``weakref.finalize`` + the worker-side orphan watchdog, so
worker processes are reaped on trainer exit, exception, or crash.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import threading
import time
import weakref
from multiprocessing import shared_memory
from multiprocessing.connection import wait as conn_wait
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph.engine import (
    SEED_BOUND,
    EngineStats,
    partition_rng,
    sample_csr_rows,
)
from repro.graph.service import shm as shm_lib
from repro.graph.service.worker import worker_main


class EngineWorkerError(RuntimeError):
    """A graph-service worker failed, died, or timed out.

    ``slot_safe`` records whether the worker is provably done with the
    request's slab slot (it replied with an error, or is dead): the client
    then recycles the slot. On a timeout the worker may still be writing,
    so the slot is deliberately leaked instead of risking reuse.

    Diagnostic context rides on the exception so a crash report is
    actionable without a re-run: ``worker_id``, the last request id
    (``rid``), and the worker's counter snapshot at failure (``stats`` —
    shipped inside the error payload, or the last stats round the client
    saw for a worker that died / timed out; None when no round ever
    completed).
    """

    def __init__(
        self,
        message: str,
        slot_safe: bool = False,
        worker_id: Optional[int] = None,
        rid: Optional[int] = None,
        stats: Optional[Dict] = None,
    ):
        super().__init__(message)
        self.slot_safe = slot_safe
        self.worker_id = worker_id
        self.rid = rid
        self.stats = stats


@dataclasses.dataclass
class PendingRequest:
    """In-flight ``submit`` handle: outputs + per-worker scatter plan."""

    rid: int
    outs: List[np.ndarray]
    # worker -> list of (query_index, scatter row indices, num_samples)
    plan: Dict[int, List[Tuple[int, np.ndarray, int]]]
    # worker -> reply-slab slot reserved for this request
    slots: Dict[int, int]
    # balanced ("sampleq") calls: per-query (n, k) plus the slot layout
    # (computed once at submit; the worker derives the identical layout
    # from the same shapes); both None for owner-dispatch fan-out.
    # ``qpickle`` marks the request-fits/replies-don't case: the request
    # still rides the slab but the worker answers "pickleq" (qlayout None).
    qshapes: Optional[List[Tuple[int, int]]] = None
    qlayout: Optional[List[Tuple[int, int, int]]] = None
    qpickle: bool = False
    t0_ns: int = 0  # submit timestamp when tracing (0 = telemetry off)


def _reap(procs, conns, segs, reader_stop) -> None:
    """Module-level teardown shared by ``shutdown`` and the GC finalizer."""
    reader_stop.set()
    for conn in conns:
        try:
            conn.send(("shutdown", -1))
        except Exception:
            pass
    deadline = time.monotonic() + 5.0
    for proc in procs:
        proc.join(timeout=max(0.0, deadline - time.monotonic()))
    for proc in procs:
        if proc.is_alive():
            proc.terminate()
    for proc in procs:
        if proc.is_alive():
            proc.join(timeout=1.0)
        if proc.is_alive():
            proc.kill()
    for conn in conns:
        try:
            conn.close()
        except Exception:
            pass
    for seg in segs:
        try:
            seg.close()
        except Exception:
            pass
        try:
            seg.unlink()
        except Exception:
            pass  # already unlinked (double shutdown) or never created


class GraphClient:
    """Client for the shared-memory multi-process graph engine."""

    def __init__(
        self,
        graph,
        num_partitions: int = 4,
        num_workers: int = 2,
        client_part: int = 0,
        start_timeout: float = 60.0,
        request_timeout: float = 120.0,
        dispatch: str = "balanced",
        slab_slots: int = 8,
        slot_bytes: int = 4 << 20,
        pin_workers: bool = False,
        local_threshold: int = 0,
        telemetry=None,
    ):
        """``slab_slots`` x ``slot_bytes`` is each worker's slab geometry: a
        ring of slots that request/reply payloads land in. In-flight requests
        per worker are capped at the slot count (semaphore), so a slot is
        never overwritten before its gather; a caller that over-pipelines
        gets an EngineWorkerError after ``request_timeout`` instead of a
        deadlock, and a call too large for a slot transparently falls back
        to pipe-pickled payloads.

        ``dispatch`` picks how a query group maps onto workers:

        - "balanced" (default): the whole group goes to the worker with the
          fewest in-flight requests. Because every shard segment is mapped
          into every worker (shared pages cost no extra memory on one host),
          any worker can serve any partition; concurrent callers — e.g. the
          prefetch producer and a mid-training eval, or a pipelined driver —
          then spread across the fleet with one round-trip per call.
        - "owner": sub-requests go to the worker owning each partition (the
          paper's multi-machine layout, where adjacency cannot be shared);
          a single call fans out across workers and gathers their replies.

        Either way the per-(query, partition) seeding is identical, so
        sampling results are bitwise independent of the dispatch mode.

        ``local_threshold`` (0 = off) enables *hybrid serving*: a
        ``sample_many`` round whose total node count is at or below the
        threshold is answered in-process over zero-copy views of the
        client's own shard segments, using the exact worker sampling core
        (``sample_csr_rows`` + ``partition_rng``) — bitwise identical to a
        worker reply by construction. Small rounds (a walker step over a
        few hundred frontier nodes) are latency-bound, not throughput-bound:
        a pipe round-trip costs more than the sampling itself, and on hosts
        where workers share cores with the trainer the IPC is pure loss.
        Large rounds still go to the worker fleet.

        ``telemetry`` (a ``repro.obs.Telemetry``, default None = disabled)
        turns on request-round tracing and metrics: dispatch/wait/compose
        spans per round, round-latency histograms, slab-slot occupancy and
        pickle-fallback counters, and — because workers are spawned with
        ``trace=True`` — worker serve spans collected on the ``stats``
        control round, clock-offset-corrected into the client's timeline.
        Disabled costs one ``is None`` test per instrumented site.
        """
        if hasattr(graph, "graph"):  # accept a DistributedGraphEngine
            engine = graph
            graph = engine.graph
            num_partitions = engine.num_partitions
            client_part = engine.client_part
        self.graph = graph
        self.num_partitions = int(num_partitions)
        self.num_workers = max(1, min(int(num_workers), self.num_partitions))
        self.client_part = int(client_part)
        self.num_nodes = graph.num_nodes
        self.relation_names = graph.relation_names()
        self.stats = EngineStats()
        self.request_timeout = float(request_timeout)
        if dispatch not in ("balanced", "owner"):
            raise ValueError(f"unknown dispatch {dispatch!r}")
        self.dispatch = dispatch
        self.slab_slots = int(slab_slots)
        self.slot_bytes = int(slot_bytes)
        self.local_threshold = int(local_threshold)
        # served-side counters for the hybrid local path, folded into
        # aggregate_stats so the served == issued invariant keeps holding
        # when some rounds never reach a worker
        self._local_lock = threading.Lock()
        self._local_stats = {
            "neighbor_requests": 0, "sub_requests": 0, "batches": 0,
            "busy_ns": 0,
        }
        # telemetry (optional): tracer + metric handles resolved once so the
        # hot path pays one attribute load + is-None test when disabled
        self._tracer = telemetry.tracer if telemetry is not None else None
        if telemetry is not None:
            m = telemetry.metrics
            self._m_round_ns = m.histogram("client.round_latency_ns")
            self._m_rounds_worker = m.counter("client.rounds_worker")
            self._m_rounds_local = m.counter("client.rounds_local")
            self._m_pickle = m.counter("client.pickle_fallback")
            self._m_slab = m.gauge("client.slab_slots_inflight")
        else:
            self._m_round_ns = None
            self._m_rounds_worker = None
            self._m_rounds_local = None
            self._m_pickle = None
            self._m_slab = None
        # last stats snapshot seen per worker (control rounds + err payloads):
        # attached to EngineWorkerError when a worker dies or times out
        self._last_stats: Dict[int, Dict] = {}
        # heartbeat rounds a worker never answered: their late replies are
        # swept from the inbox on the next heartbeat instead of leaking
        self._stale_hb: set = set()

        # Everything allocated below (shm segments, worker processes) is
        # reaped if ANY construction step fails — a failed __init__ must not
        # leave graph-sized segments in /dev/shm or orphaned workers.
        self._segs = []
        self._procs = []
        self._conns = []
        self._reader_stop = threading.Event()
        try:
            # ---- build shards + per-worker reply slabs once, in shared memory
            manifests = []
            for p in range(self.num_partitions):
                seg, manifest = shm_lib.build_shard(graph, p, self.num_partitions)
                self._segs.append(seg)
                manifests.append(manifest)
            # zero-copy views over our own shard segments: the hybrid local
            # path serves small rounds from these (address space, not memory)
            self._local_views = [
                shm_lib.manifest_views(self._segs[p], manifests[p])
                for p in range(self.num_partitions)
            ]
            self._slabs = []
            for _ in range(self.num_workers):
                slab = shared_memory.SharedMemory(
                    create=True, size=self.slab_slots * self.slot_bytes
                )
                self._slabs.append(slab)
                self._segs.append(slab)

            # ---- spawn workers. Ownership (round-robin) steers "owner"
            # dispatch, but every worker maps every shard: attaching a
            # segment costs address space, not memory, and it is what lets
            # "balanced" dispatch hand any request round to any worker.
            self._worker_of = [
                p % self.num_workers for p in range(self.num_partitions)
            ]
            ctx = mp.get_context("spawn")
            for w in range(self.num_workers):
                parent_conn, child_conn = ctx.Pipe(duplex=True)
                proc = ctx.Process(
                    target=worker_main,
                    args=(w, manifests, child_conn, self._slabs[w].name,
                          self.slot_bytes, self._tracer is not None),
                    name=f"repro-graph-worker-{w}",
                    daemon=True,
                )
                proc.start()
                child_conn.close()  # child holds its own copy
                self._conns.append(parent_conn)
                self._procs.append(proc)
            if pin_workers and hasattr(os, "sched_setaffinity"):
                # spread workers across cores; cuts scheduler-migration
                # jitter on saturated hosts (benchmarking aid — leave off
                # when training compute shares the machine)
                ncpu = os.cpu_count() or 1
                for w, proc in enumerate(self._procs):
                    try:
                        os.sched_setaffinity(proc.pid, {w % ncpu})
                    except OSError:
                        break
            self._slot_sems = [
                threading.Semaphore(self.slab_slots)
                for _ in range(self.num_workers)
            ]
            # free-list (not a ring counter): out-of-order gathers return
            # slots in arbitrary order, and a reservation must never hand
            # out a slot a pending request still owns
            self._free_slots = [
                list(range(self.slab_slots)) for _ in range(self.num_workers)
            ]
            # guards _free_slots/_inflight/_rr (tiny critical sections,
            # taken from gather without the client-wide dispatch lock)
            self._state_lock = threading.Lock()
            self._inflight = [0] * self.num_workers
            self._rr = 0

            self._lock = threading.Lock()  # serializes rid alloc + pipe sends
            self._rid = 0
            self._cv = threading.Condition()
            self._inbox: Dict[Tuple[int, int], Tuple[str, object]] = {}
            self._dead: Dict[int, str] = {}  # worker -> reason
            self._closed = False
            self._handshake(start_timeout)
        except BaseException:
            _reap(self._procs, self._conns, self._segs, self._reader_stop)
            raise

        self._reader = threading.Thread(
            target=self._read_loop, name="repro-graph-client-reader", daemon=True
        )
        self._reader.start()
        self._finalizer = weakref.finalize(
            self, _reap, self._procs, self._conns, self._segs, self._reader_stop
        )

    # ------------------------------------------------------------- lifecycle
    def _handshake(self, timeout: float) -> None:
        deadline = time.monotonic() + timeout
        for w, conn in enumerate(self._conns):
            while not conn.poll(0.1):
                if not self._procs[w].is_alive():
                    raise EngineWorkerError(
                        f"graph worker {w} exited during startup "
                        f"(exitcode={self._procs[w].exitcode})"
                    )
                if time.monotonic() > deadline:
                    raise EngineWorkerError(f"graph worker {w} startup timed out")
            msg = conn.recv()
            if msg[0] != "ready":
                raise EngineWorkerError(f"graph worker {w} bad handshake: {msg!r}")

    def shutdown(self) -> None:
        """Stop workers and release shared memory. Safe to call repeatedly."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        # finalize() runs _reap exactly once and disarms the GC hook
        self._finalizer()

    close = shutdown  # alias

    def __enter__(self) -> "GraphClient":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def __del__(self):  # belt and braces; finalize also covers interpreter exit
        try:
            self.shutdown()
        except Exception:
            pass

    # ----------------------------------------------------------- reply inbox
    def _read_loop(self) -> None:
        """Eagerly drain every worker pipe into the inbox.

        Keeping the pipes drained is what makes deep pipelining safe: a
        worker's reply ``send`` always completes, so it is always back to
        reading requests, and a client ``send`` can never deadlock against
        an unread reply.
        """
        conn_of = {id(c): w for w, c in enumerate(self._conns)}
        live = list(self._conns)
        while not self._reader_stop.is_set():
            if not live:
                return
            try:
                ready = conn_wait(live, timeout=0.1)
            except OSError:
                return  # conns closed under us during shutdown
            notify: List[Tuple[Tuple[int, int], Tuple[str, object]]] = []
            for conn in ready:
                w = conn_of[id(conn)]
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    with self._cv:
                        self._dead.setdefault(w, "connection closed")
                        self._cv.notify_all()
                    live.remove(conn)
                    continue
                tag, rid = msg[0], msg[1]
                notify.append(((w, rid), (tag, msg[2] if len(msg) > 2 else None)))
            if notify:
                with self._cv:
                    self._inbox.update(notify)
                    self._cv.notify_all()
            # poll worker liveness even when idle: a SIGKILLed worker's pipe
            # stays half-open until the process is collected
            for w, proc in enumerate(self._procs):
                if not proc.is_alive() and w not in self._dead:
                    with self._cv:
                        self._dead[w] = f"process died (exitcode={proc.exitcode})"
                        self._cv.notify_all()

    def _wait_reply(self, w: int, rid: int):
        deadline = time.monotonic() + self.request_timeout
        with self._cv:
            while True:
                if (w, rid) in self._inbox:
                    tag, payload = self._inbox.pop((w, rid))
                    if tag == "err":
                        # the worker answered (and survives): slot reusable
                        if isinstance(payload, dict):
                            tb = payload.get("traceback")
                            snap = payload.get("stats")
                        else:  # plain-string payload (unknown-op reply)
                            tb, snap = payload, None
                        detail = f"\n{tb}"
                        if snap is not None:
                            self._last_stats[w] = snap
                            detail += f"\nworker {w} stats at failure: {snap}"
                        raise EngineWorkerError(
                            f"graph worker {w} failed serving request {rid}:"
                            + detail,
                            slot_safe=True,
                            worker_id=w, rid=rid, stats=snap,
                        )
                    return payload
                if w in self._dead:
                    raise EngineWorkerError(
                        f"graph worker {w} (pid {self._procs[w].pid}) "
                        f"{self._dead[w]} while request {rid} was in flight",
                        slot_safe=True,  # dead workers write nothing more
                        worker_id=w, rid=rid,
                        stats=self._last_stats.get(w),
                    )
                if self._closed:
                    raise EngineWorkerError(
                        "GraphClient was shut down", slot_safe=True,
                        worker_id=w, rid=rid,
                    )
                if time.monotonic() > deadline:
                    # worker may still be writing this slot: do NOT reuse it
                    raise EngineWorkerError(
                        f"graph worker {w} request {rid} timed out "
                        f"after {self.request_timeout:.0f}s",
                        worker_id=w, rid=rid,
                        stats=self._last_stats.get(w),
                    )
                self._cv.wait(timeout=0.1)

    # -------------------------------------------------------------- requests
    def _send(self, w: int, msg) -> None:
        try:
            self._conns[w].send(msg)
        except (BrokenPipeError, OSError) as e:
            raise EngineWorkerError(f"graph worker {w} unreachable: {e}") from e

    def _control(self, op: str):
        """Broadcast a control op to every worker; return per-worker replies."""
        if self._closed:
            raise RuntimeError("GraphClient is shut down")
        with self._lock:
            rid = self._rid = self._rid + 1
            for w in range(self.num_workers):
                self._send(w, (op, rid))
        return [self._wait_reply(w, rid) for w in range(self.num_workers)]

    def _control_one(self, w: int, op: str):
        """One control round against a single worker (serial — the stats
        round brackets it with timestamps for clock-offset estimation)."""
        if self._closed:
            raise RuntimeError("GraphClient is shut down")
        with self._lock:
            rid = self._rid = self._rid + 1
            self._send(w, (op, rid))
        return self._wait_reply(w, rid)

    def _route(self, nodes: np.ndarray):
        """Sort-based owner routing: one stable argsort instead of P boolean
        mask passes. Returns (order, sorted32, starts, cross) where
        nodes[order] is grouped by partition (``sorted32`` is that grouping
        as int32 — CSR ids fit), and partition p's segment is
        ``order[starts[p]:starts[p+1]]``."""
        owners = nodes % self.num_partitions
        order = np.argsort(owners, kind="stable")
        sorted32 = nodes[order].astype(np.int32, copy=False)
        counts = np.bincount(owners, minlength=self.num_partitions)
        starts = np.zeros(self.num_partitions + 1, dtype=np.int64)
        np.cumsum(counts, out=starts[1:])
        cross = len(nodes) - int(counts[self.client_part])
        return order, sorted32, starts, cross

    def submit(self, rng: np.random.Generator, queries: Sequence[Tuple]) -> PendingRequest:
        """Route + dispatch a query group; returns a handle for ``gather``.

        ``queries``: sequence of ``(nodes, relation, num_samples, pad_id)``.
        One seed per query is drawn from ``rng`` (in order — the same stream
        consumption as the in-process engine), so submission interleaving
        across threads never changes any caller's results. Queries that
        share one frontier array (an ego hop asks every relation about the
        same nodes) are routed once.
        """
        if self._closed:
            raise RuntimeError("GraphClient is shut down")
        t0_ns = time.perf_counter_ns() if self._tracer is not None else 0
        P = self.num_partitions
        outs: List[np.ndarray] = []
        qshapes: List[Tuple[int, int]] = []
        metas: List[Tuple] = []
        routed: List[Tuple] = []  # per query: (route, relation, k, pad, seed)
        # id(array) -> (array, routing): the kept reference makes the id a
        # valid key for the duration of this submit (no address reuse).
        # Routing, seed draws, and stats need no client lock: the rng belongs
        # to the caller and the stats mirror locks itself.
        routes: Dict[int, Tuple] = {}
        for nodes, relation, num_samples, pad_id in queries:
            nodes = np.asarray(nodes, dtype=np.int64)
            seed = int(rng.integers(0, SEED_BOUND))
            cached = routes.get(id(nodes))
            if cached is None or cached[0] is not nodes:
                route = self._route(nodes)
                routes[id(nodes)] = (nodes, route)
            else:
                route = cached[1]
            self.stats.add(len(nodes), route[3])
            outs.append(np.empty((len(nodes), num_samples), dtype=np.int64))
            qshapes.append((len(nodes), num_samples))
            metas.append(
                (relation, num_samples, pad_id, seed, len(nodes),
                 tuple(int(s) for s in route[2]))
            )
            routed.append((route, relation, num_samples, pad_id, seed))

        qlayout = qreq = None
        if self.dispatch == "balanced":
            qlayout = shm_lib.sampleq_layout(qshapes, self.slot_bytes)
            if qlayout is not None:
                qreq = [(a, b) for a, b, _ in qlayout]
            else:
                # replies overflow the slot but the request region fits:
                # keep the balanced whole-call exchange — the worker samples
                # in caller order and pickles the reply back ("pickleq") —
                # instead of degrading to owner fan-out
                qreq = shm_lib.sampleq_request_layout(qshapes, self.slot_bytes)
        if qreq is not None and any(n for n, _ in qshapes):
            with self._state_lock:
                # least-loaded worker, round-robin among ties so sequential
                # (sync) callers still exercise the whole fleet
                w = min(
                    range(self.num_workers),
                    key=lambda i: (
                        self._inflight[i], (i - self._rr) % self.num_workers
                    ),
                )
                self._rr = (w + 1) % self.num_workers
            slot = self._reserve_slot(w)
            try:
                # the slot is exclusively ours: slab writes need no lock
                for (route, *_), (n, _k), (a_off, b_off) in zip(
                    routed, qshapes, qreq
                ):
                    order, sorted32, _starts, _cross = route
                    np.copyto(
                        shm_lib.slot_view(
                            self._slabs[w], slot, self.slot_bytes, a_off, (n,)
                        ),
                        sorted32, casting="unsafe",
                    )
                    np.copyto(
                        shm_lib.slot_view(
                            self._slabs[w], slot, self.slot_bytes, b_off, (n,)
                        ),
                        order, casting="unsafe",
                    )
                with self._lock:
                    rid = self._rid = self._rid + 1
                    self._send(w, ("sampleq", rid, slot, metas))
            except BaseException:
                self._release_slot(w, slot)
                raise
            if self._tracer is not None:
                self._tracer.add_span(
                    "client.dispatch", "client", t0_ns,
                    time.perf_counter_ns() - t0_ns, {"rid": rid},
                )
            return PendingRequest(
                rid=rid, outs=outs, plan={w: []}, slots={w: slot},
                qshapes=qshapes, qlayout=qlayout, qpickle=qlayout is None,
                t0_ns=t0_ns,
            )

        # owner dispatch (or a call too large for a slab slot): fan the
        # per-partition sub-requests out to the partitions' owners
        per_worker: Dict[int, List[Tuple]] = {}
        plan: Dict[int, List[Tuple[int, np.ndarray, int]]] = {}
        for qi, (route, relation, num_samples, pad_id, seed) in enumerate(routed):
            order, sorted32, starts, _cross = route
            for p in range(P):
                lo, hi = int(starts[p]), int(starts[p + 1])
                if lo == hi:
                    continue
                w = self._worker_of[p]
                per_worker.setdefault(w, []).append(
                    (relation, p, sorted32[lo:hi] // P, num_samples, pad_id, seed)
                )
                plan.setdefault(w, []).append((qi, order[lo:hi], num_samples))
        slots: Dict[int, int] = {}
        try:
            for w in sorted(per_worker):
                slots[w] = self._reserve_slot(w)
            with self._lock:
                rid = self._rid = self._rid + 1
                for w, subs in per_worker.items():
                    self._send(w, ("sample", rid, slots[w], subs))
        except BaseException:
            for w, slot in slots.items():
                self._release_slot(w, slot)
            raise
        if self._tracer is not None:
            self._tracer.add_span(
                "client.dispatch", "client", t0_ns,
                time.perf_counter_ns() - t0_ns, {"rid": rid},
            )
        return PendingRequest(
            rid=rid, outs=outs, plan=plan, slots=slots, t0_ns=t0_ns
        )

    def _reserve_slot(self, w: int) -> int:
        """Claim a free slab slot on worker ``w`` (bounded wait, no client
        lock held — a saturated worker only stalls its own callers)."""
        if not self._slot_sems[w].acquire(timeout=self.request_timeout):
            raise EngineWorkerError(
                f"no reply slot free on worker {w} after "
                f"{self.request_timeout:.0f}s — more than "
                f"{self.slab_slots} requests pipelined without gather?"
            )
        with self._state_lock:
            self._inflight[w] += 1
            if self._m_slab is not None:
                self._m_slab.set(sum(self._inflight))
            return self._free_slots[w].pop()

    def _release_slot(self, w: int, slot: int) -> None:
        with self._state_lock:
            self._free_slots[w].append(slot)
            self._inflight[w] -= 1
            if self._m_slab is not None:
                self._m_slab.set(sum(self._inflight))
        self._slot_sems[w].release()

    def gather(self, pending: PendingRequest) -> List[np.ndarray]:
        """Collect a ``submit``'s replies and assemble per-query outputs.

        Balanced ("sampleq") calls come back already composed in caller
        order, so the client's whole per-sample cost is one contiguous
        int32 -> int64 copy per query. Owner fan-out replies are scattered
        row-wise straight out of each worker's slab slot — either way, no
        pickling and no intermediate copies.

        Every worker's slot is settled even when some fail: slots are
        recycled whenever the worker is provably done with them
        (``EngineWorkerError.slot_safe``), and the first error is re-raised
        after the remaining workers are drained.
        """
        tracer = self._tracer
        first_err: Optional[BaseException] = None
        for w, scatter in pending.plan.items():
            slot = pending.slots[w]
            release = True
            try:
                w0 = time.perf_counter_ns() if tracer is not None else 0
                kind, payload = self._wait_reply(w, pending.rid)
                if tracer is not None:
                    now = time.perf_counter_ns()
                    tracer.add_span(
                        "client.wait", "client", w0, now - w0,
                        {"rid": pending.rid, "worker": w},
                    )
                    c0 = now
                if kind in ("pickle", "pickleq") and self._m_pickle is not None:
                    self._m_pickle.inc()
                if pending.qshapes is not None:  # balanced whole-call reply
                    if pending.qlayout is not None:  # composed in the slab
                        for out, (n, k), (_, _, r_off) in zip(
                            pending.outs, pending.qshapes, pending.qlayout
                        ):
                            view = shm_lib.slot_view(
                                self._slabs[w], slot, self.slot_bytes,
                                r_off, (n, k),
                            )
                            np.copyto(out, view, casting="unsafe")
                    else:  # "pickleq": caller-order arrays over the pipe
                        for out, arr in zip(pending.outs, payload):
                            np.copyto(out, arr, casting="unsafe")
                elif kind == "shm":
                    shapes = [(len(idx), k) for _, idx, k in scatter]
                    offsets = shm_lib.reply_layout(shapes, self.slot_bytes)
                    for (qi, idx, k), off, shape in zip(scatter, offsets, shapes):
                        view = shm_lib.slot_view(
                            self._slabs[w], payload, self.slot_bytes, off, shape
                        )
                        pending.outs[qi][idx] = view
                else:  # pickle fallback (reply group exceeded a slab slot)
                    for (qi, idx, _), arr in zip(scatter, payload):
                        pending.outs[qi][idx] = arr
                if tracer is not None:
                    tracer.add_span(
                        "client.compose", "client", c0,
                        time.perf_counter_ns() - c0,
                        {"rid": pending.rid, "worker": w},
                    )
            except EngineWorkerError as e:
                release = e.slot_safe
                if first_err is None:
                    first_err = e
            finally:
                if release:
                    self._release_slot(w, slot)
        if first_err is not None:
            raise first_err
        if self._m_round_ns is not None and pending.t0_ns:
            self._m_round_ns.observe(time.perf_counter_ns() - pending.t0_ns)
            self._m_rounds_worker.inc()
        return pending.outs

    # ----------------------------------------------------------- engine API
    def _sample_local(
        self, rng: np.random.Generator, queries: Sequence[Tuple]
    ) -> List[np.ndarray]:
        """Serve one query group in-process over the client's shard views.

        Mirrors the worker exactly — one seed per query drawn in order from
        the caller's generator, owner routing via ``_route``, and
        ``sample_csr_rows(..., degs_all=...)`` under
        ``partition_rng(seed, p)`` per partition — so the reply is bitwise
        identical to what the worker fleet would have produced, and the
        caller's RNG stream advances identically either way.
        """
        if self._closed:
            raise RuntimeError("GraphClient is shut down")
        t0 = time.perf_counter_ns()
        P = self.num_partitions
        outs: List[np.ndarray] = []
        served = 0
        subs = 0
        # Mask routing (not submit's argsort): for a local reply there is no
        # wire payload to pack, and the engine-style per-partition masks are
        # cheaper. Draws are bitwise unchanged either way — a stable argsort
        # groups by owner preserving in-partition order, so the rows each
        # partition_rng(seed, p) sees are identical. Queries sharing one
        # frontier array (an ego hop asks every relation about the same
        # nodes) are routed once — masks and local rows are relation-free.
        routes: Dict[int, Tuple] = {}
        for nodes, relation, num_samples, pad_id in queries:
            nodes = np.asarray(nodes, dtype=np.int64)
            seed = int(rng.integers(0, SEED_BOUND))
            cached = routes.get(id(nodes))
            if cached is None or cached[0] is not nodes:
                owners = nodes % P
                cross = len(nodes) - int((owners == self.client_part).sum())
                parts = []
                for p in range(P):
                    mask = owners == p
                    if mask.any():
                        parts.append((p, mask, nodes[mask] // P))
                routes[id(nodes)] = (nodes, cross, parts)
            else:
                _, cross, parts = cached
            self.stats.add(len(nodes), cross)
            out = np.empty((len(nodes), num_samples), dtype=np.int64)
            for p, mask, local_rows in parts:
                views = self._local_views[p]
                out[mask] = sample_csr_rows(
                    views[f"{relation}/indptr"],
                    views[f"{relation}/indices"],
                    partition_rng(seed, p),
                    local_rows,
                    num_samples,
                    pad_id,
                    degs_all=views[f"{relation}/degs"],
                )
                subs += 1
            served += len(nodes)
            outs.append(out)
        dur = time.perf_counter_ns() - t0
        with self._local_lock:
            s = self._local_stats
            s["neighbor_requests"] += served
            s["sub_requests"] += subs
            s["batches"] += 1
            s["busy_ns"] += dur
        if self._tracer is not None:
            self._tracer.add_span(
                "client.local", "client", t0, dur, {"queries": len(queries)}
            )
            self._m_round_ns.observe(dur)
            self._m_rounds_local.inc()
        return outs

    def sample_many(
        self, rng: np.random.Generator, queries: Sequence[Tuple]
    ) -> List[np.ndarray]:
        if self.local_threshold > 0:
            total = 0
            for nodes, _rel, _k, _pad in queries:
                total += len(nodes)
            if total <= self.local_threshold:
                return self._sample_local(rng, queries)
        return self.gather(self.submit(rng, queries))

    def sample_neighbors(
        self,
        rng: np.random.Generator,
        nodes: np.ndarray,
        relation: str,
        num_samples: int,
        pad_id: int = -1,
    ) -> np.ndarray:
        return self.sample_many(rng, [(nodes, relation, num_samples, pad_id)])[0]

    def step(
        self, rng: np.random.Generator, nodes: np.ndarray, relation: str, pad_id: int = -1
    ) -> np.ndarray:
        return self.sample_neighbors(rng, nodes, relation, 1, pad_id)[:, 0]

    # ---------------------------------------------------------------- stats
    def worker_stats(self) -> List[Dict[str, int]]:
        """Per-worker counter dicts, fetched across the process boundary.

        Serial one-worker-at-a-time rounds, each bracketed with local
        ``perf_counter_ns`` timestamps: when tracing, the worker's reply
        piggybacks its drained serve-span ring plus its own clock reading,
        and the client estimates the clock offset as
        ``worker_clock - (t0 + t1) // 2`` (midpoint of the round trip)
        before ingesting the spans into the tracer's timeline. Each
        snapshot is also cached as the worker's last-known stats for
        ``EngineWorkerError`` context.
        """
        out: List[Dict[str, int]] = []
        for w in range(self.num_workers):
            t0 = time.perf_counter_ns()
            snap = self._control_one(w, "stats")
            t1 = time.perf_counter_ns()
            out.append(self._absorb_stats(w, snap, t0, t1))
        return out

    def _absorb_stats(self, w: int, snap: Dict, t0: int, t1: int) -> Dict:
        """Fold one stats reply in: strip the piggybacked trace payload
        (span ingest with the round-trip-midpoint clock offset) and cache
        the snapshot as the worker's last-known stats."""
        spans = snap.pop("spans", None)
        dropped = snap.pop("dropped_spans", 0)
        clock = snap.pop("clock_ns", None)
        self._last_stats[w] = dict(snap)
        if self._tracer is not None and spans:
            offset = (clock - (t0 + t1) // 2) if clock is not None else 0
            self._tracer.ingest(
                f"graph-worker-{w}", snap.get("pid", -(w + 1)),
                [
                    (name, "worker", s0, d, {"rid": r})
                    for name, r, s0, d in spans
                ],
                offset_ns=offset, dropped=dropped,
            )
        return snap

    def heartbeat(self, timeout: float = 5.0) -> Dict[int, bool]:
        """Bounded per-worker liveness probe on the ``stats`` control round.

        The health monitor's worker-liveness vehicle (no new IPC op):
        unlike :meth:`worker_stats`, a silent worker neither raises nor
        blocks for ``request_timeout`` — each worker gets ``timeout``
        seconds and a miss is reported as ``False``. The missed round's
        rid is remembered and its late reply (if the worker was merely
        slow) is swept from the inbox on the next heartbeat, so repeated
        probes never leak inbox entries. A responsive reply is absorbed
        exactly like a stats round (span ingest + last-stats cache), so
        heartbeats double as periodic trace drains.
        """
        if self._closed:
            return {}
        with self._cv:
            for key in [k for k in self._stale_hb if k in self._inbox]:
                self._inbox.pop(key)
                self._stale_hb.discard(key)
        alive: Dict[int, bool] = {}
        for w in range(self.num_workers):
            with self._cv:
                if w in self._dead:
                    alive[w] = False
                    continue
            t0 = time.perf_counter_ns()
            try:
                with self._lock:
                    rid = self._rid = self._rid + 1
                    self._send(w, ("stats", rid))
            except EngineWorkerError:
                alive[w] = False
                continue
            deadline = time.monotonic() + timeout
            reply = None
            with self._cv:
                while True:
                    if (w, rid) in self._inbox:
                        tag, payload = self._inbox.pop((w, rid))
                        if tag == "ok":
                            reply = payload
                        break
                    if (
                        w in self._dead
                        or self._closed
                        or time.monotonic() >= deadline
                    ):
                        break
                    self._cv.wait(timeout=0.1)
            t1 = time.perf_counter_ns()
            if reply is None:
                self._stale_hb.add((w, rid))
                alive[w] = False
            else:
                self._absorb_stats(w, reply, t0, t1)
                alive[w] = True
        return alive

    def drain_worker_spans(self) -> None:
        """Pull every worker's pending serve spans into the tracer.

        A convenience alias for a tracing-time ``worker_stats`` round —
        call once before export so spans since the last stats round are
        not lost. No-op when telemetry is off.
        """
        if self._tracer is not None:
            self.worker_stats()

    def aggregate_stats(self) -> Dict[str, float]:
        """Cross-partition totals summed over every worker process.

        ``neighbor_requests`` here counts queries as *served*; it must equal
        the client-side ``stats.neighbor_requests`` mirror (which counts
        queries as *issued*) — the invariant the service tests pin. Rounds
        answered by the hybrid local path (``local_threshold``) are folded
        in as served-side counts and also broken out under ``local_*`` keys.
        """
        per = self.worker_stats()
        with self._local_lock:
            local = dict(self._local_stats)
        agg: Dict[str, float] = {
            "neighbor_requests": (
                sum(s["neighbor_requests"] for s in per)
                + local["neighbor_requests"]
            ),
            "sub_requests": sum(s["sub_requests"] for s in per)
            + local["sub_requests"],
            "batches": sum(s["batches"] for s in per) + local["batches"],
            "busy_s": (sum(s["busy_ns"] for s in per) + local["busy_ns"]) / 1e9,
            "num_workers": len(per),
            "local_neighbor_requests": local["neighbor_requests"],
            "local_batches": local["batches"],
        }
        return agg

    def reset_stats(self) -> None:
        self.stats.reset()
        with self._local_lock:
            for key in self._local_stats:
                self._local_stats[key] = 0
        self._control("reset")
