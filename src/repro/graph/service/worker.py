"""Graph-service worker: one process serving neighbor queries for its shards.

A worker attaches the shared-memory CSR shards of the partitions it owns
(zero-copy — no adjacency is ever pickled to a worker) and loops on a duplex
pipe serving batched requests. The module imports only NumPy-side code so
spawned workers never pay a JAX import.

Protocol (one tuple per message, pickled over the pipe):

    ("sample", rid, slot, [(relation, part_id, local_rows, k, pad_id, seed), ...])
        -> ("ok", rid, ("shm", slot))        replies written as int32 arrays
                                             into the worker's reply-slab
                                             slot (offsets via reply_layout)
        -> ("ok", rid, ("pickle", [arrays])) fallback when a reply group is
                                             too large for a slab slot
    ("sampleq", rid, slot, [meta, ...])
        -> ("ok", rid, ("shmq", slot))       whole-call caller-order reply
                                             composed inside the slot
        -> ("ok", rid, ("pickleq", [arrays])) fallback when the reply region
                                             overflows the slot (the request
                                             region still rode in shm)
    ("stats", rid)    -> ("ok", rid, {counter dict})  when tracing, the dict
                         additionally carries the drained span ring
                         ("spans"/"dropped_spans"), "clock_ns" (this
                         process's perf_counter_ns, for client-side clock
                         offset correction) and always "pid"
    ("reset", rid)    -> ("ok", rid, None)
    ("shutdown", rid) -> worker replies ("ok", rid, None) and exits

Both serve ops count exactly one of ``shm_replies``/``pickle_replies`` per
request round, so ``shm_replies + pickle_replies == batches`` holds on
every path (the conservation invariant tests/test_obs.py pins).

Reply transport: only the tag crosses the pipe on the shm path — the sample
payload lands in shared memory (int32: CSR indices are int32, so nothing is
lost), so the client never pays pickle/copy costs proportional to
batch x num_samples and its reader thread stays off the hot path.

Any per-request failure is reported as ("err", rid, {"traceback": ...,
"stats": {...}}) — the client re-raises it as ``EngineWorkerError`` carrying
the worker id, request id, and the worker's stats snapshot at failure — so a
bad relation name in one query can never wedge the service, and the crash
report is actionable without re-running.

Tracing (``trace=True`` at spawn): each serve round appends
``(op_name, rid, t0_ns, dur_ns)`` to a bounded local ring (plain list +
counter — this module never imports repro.obs, workers stay numpy-only);
the "stats" round drains it. Timestamps are this process's
``perf_counter_ns``; the client corrects them into its own timebase.

Randomness: each sub-request derives ``partition_rng(seed, part_id)`` — the
same derivation the in-process engine uses — so replies are bitwise
independent of which process serves a partition.

Liveness: the loop wakes every ``_POLL_S`` to check its parent is still
alive (spawned workers are re-parented when the trainer dies) and exits on
orphaning, so a crashed trainer never strands graph servers.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
import traceback
from typing import Dict, List, Sequence

import numpy as np

from repro.graph.engine import partition_rng, sample_csr_rows
from repro.graph.service.shm import (
    ShardManifest, attach_segment, attach_shard, reply_layout, sampleq_layout,
    sampleq_request_layout, slot_view,
)

_POLL_S = 0.25
_SPAN_CAP = 8192  # bounded serve-span ring per worker (tracing only)


def _parent_alive() -> bool:
    parent = mp.parent_process()
    if parent is not None:
        return parent.is_alive()
    return os.getppid() != 1  # fork fallback: re-parented to init == orphaned


def worker_main(
    worker_id: int,
    manifests: Sequence[ShardManifest],
    conn,
    slab_name: str = "",
    slot_bytes: int = 0,
    trace: bool = False,
) -> None:
    """Entry point of one graph-service worker process."""
    segs = []
    slab = None
    stats: Dict[str, int] = {
        "worker_id": worker_id,
        "neighbor_requests": 0,
        "sub_requests": 0,
        "batches": 0,
        "busy_ns": 0,
        "shm_replies": 0,
        "pickle_replies": 0,
    }
    # serve-span ring: (op_name, rid, t0_ns, dur_ns), drained by "stats"
    spans: List[tuple] = [None] * _SPAN_CAP if trace else []
    span_n = 0
    try:
        shards: Dict[int, Dict[str, np.ndarray]] = {}
        for m in manifests:
            seg, views = attach_shard(m)
            segs.append(seg)
            shards[m.part_id] = views
        if slab_name:
            slab = attach_segment(slab_name)
            segs.append(slab)
        conn.send(("ready", worker_id, [m.part_id for m in manifests]))
        while True:
            if not conn.poll(_POLL_S):
                if not _parent_alive():
                    return
                continue
            try:
                msg = conn.recv()
            except EOFError:
                return  # client closed its end
            op, rid = msg[0], msg[1]
            if op == "shutdown":
                conn.send(("ok", rid, None))
                return
            try:
                if op == "sample":
                    t0 = time.perf_counter_ns()
                    slot, subs = msg[2], msg[3]
                    offsets = (
                        reply_layout(
                            [(len(rows), k) for _, _, rows, k, _, _ in subs],
                            slot_bytes,
                        )
                        if slab is not None
                        else None
                    )
                    replies: List[np.ndarray] = []
                    served = 0
                    for si, (relation, part_id, local_rows, k, pad_id, seed) in enumerate(subs):
                        views = shards[part_id]
                        out = (
                            slot_view(
                                slab, slot, slot_bytes, offsets[si],
                                (len(local_rows), k),
                            )
                            if offsets is not None
                            else None
                        )
                        sampled = sample_csr_rows(
                            views[f"{relation}/indptr"],
                            views[f"{relation}/indices"],
                            partition_rng(seed, part_id),
                            local_rows,
                            k,
                            pad_id,
                            degs_all=views[f"{relation}/degs"],
                            out=out,
                        )
                        if offsets is None:
                            replies.append(sampled)
                        served += len(local_rows)
                    stats["neighbor_requests"] += served
                    stats["sub_requests"] += len(subs)
                    stats["batches"] += 1
                    if offsets is not None:
                        stats["shm_replies"] += 1
                        payload = ("shm", slot)
                    else:
                        stats["pickle_replies"] += 1
                        payload = ("pickle", replies)
                    dur = time.perf_counter_ns() - t0
                    stats["busy_ns"] += dur
                    if trace:
                        spans[span_n % _SPAN_CAP] = (
                            "worker.sample", rid, t0, dur,
                        )
                        span_n += 1
                    conn.send(("ok", rid, payload))
                elif op == "sampleq":
                    # whole-call exchange (balanced dispatch): requests AND
                    # caller-order composition live in the slab slot, so the
                    # client's GIL never touches per-partition scatters
                    t0 = time.perf_counter_ns()
                    slot, metas = msg[2], msg[3]
                    shapes = [(m[4], m[1]) for m in metas]
                    offsets = sampleq_layout(shapes, slot_bytes)
                    if offsets is not None:
                        req_offs = [(a, b) for a, b, _ in offsets]
                    else:
                        # replies overflow the slot but the request region
                        # rode in shm: sample into fresh arrays and pickle
                        # the caller-order replies back ("pickleq")
                        req_offs = sampleq_request_layout(shapes, slot_bytes)
                    replies = []
                    served = 0
                    num_parts = manifests[0].num_parts
                    for qi, (relation, k, pad_id, seed, n, starts) in enumerate(
                        metas
                    ):
                        a_off, b_off = req_offs[qi]
                        nodes = slot_view(slab, slot, slot_bytes, a_off, (n,))
                        order = slot_view(slab, slot, slot_bytes, b_off, (n,))
                        if offsets is not None:
                            reply = slot_view(
                                slab, slot, slot_bytes, offsets[qi][2], (n, k)
                            )
                        else:
                            reply = np.empty((n, k), dtype=np.int32)
                            replies.append(reply)
                        for p in range(num_parts):
                            lo, hi = starts[p], starts[p + 1]
                            if lo == hi:
                                continue
                            views = shards[p]
                            sampled = sample_csr_rows(
                                views[f"{relation}/indptr"],
                                views[f"{relation}/indices"],
                                partition_rng(seed, p),
                                nodes[lo:hi] // num_parts,
                                k,
                                pad_id,
                                degs_all=views[f"{relation}/degs"],
                                out=np.empty((hi - lo, k), dtype=np.int32),
                            )
                            reply[order[lo:hi]] = sampled
                        served += n
                    stats["neighbor_requests"] += served
                    stats["sub_requests"] += len(metas)
                    stats["batches"] += 1
                    if offsets is not None:
                        stats["shm_replies"] += 1
                        payload = ("shmq", slot)
                    else:
                        stats["pickle_replies"] += 1
                        payload = ("pickleq", replies)
                    dur = time.perf_counter_ns() - t0
                    stats["busy_ns"] += dur
                    if trace:
                        spans[span_n % _SPAN_CAP] = (
                            "worker.sampleq", rid, t0, dur,
                        )
                        span_n += 1
                    conn.send(("ok", rid, payload))
                elif op == "stats":
                    snap = dict(stats)
                    snap["pid"] = os.getpid()
                    if trace:
                        if span_n <= _SPAN_CAP:
                            drained = spans[:span_n]
                        else:
                            i = span_n % _SPAN_CAP
                            drained = spans[i:] + spans[:i]
                        snap["spans"] = drained
                        snap["dropped_spans"] = max(0, span_n - _SPAN_CAP)
                        snap["clock_ns"] = time.perf_counter_ns()
                        spans = [None] * _SPAN_CAP
                        span_n = 0
                    conn.send(("ok", rid, snap))
                elif op == "reset":
                    for key in (
                        "neighbor_requests", "sub_requests", "batches",
                        "busy_ns", "shm_replies", "pickle_replies",
                    ):
                        stats[key] = 0
                    conn.send(("ok", rid, None))
                else:
                    conn.send(("err", rid, f"unknown op {op!r}"))
            except Exception:
                conn.send(("err", rid, {
                    "traceback": traceback.format_exc(),
                    "stats": dict(stats),
                }))
    except (EOFError, BrokenPipeError, KeyboardInterrupt):
        pass
    finally:
        for seg in segs:
            try:
                seg.close()
            except Exception:
                pass
        try:
            conn.close()
        except Exception:
            pass
