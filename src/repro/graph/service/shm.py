"""POSIX shared-memory CSR shards for the out-of-process graph engine.

The parent (trainer) process partitions the graph once — the same
``node_id % num_partitions`` ownership and vectorized CSR slice-gather the
in-process engine uses — and packs each partition's per-relation
``(indptr, indices)`` arrays into ONE ``multiprocessing.shared_memory``
segment. Workers attach by name and get zero-copy read-only NumPy views, so
partition adjacency is materialized exactly once no matter how many worker
processes serve it, and spawning a worker costs no graph serialization.

A ``ShardManifest`` (plain picklable dataclass) carries everything a worker
needs to reconstruct the views: segment name plus per-array offset / shape /
dtype. Segment lifetime is owned by the parent: workers only ``close()``
their mappings, the creator ``unlink()``s on shutdown.
"""
from __future__ import annotations

import dataclasses
import weakref
from multiprocessing import shared_memory
from typing import Dict, List, Optional, Tuple

import numpy as np

_ALIGN = 64  # cache-line align each array inside a segment


def _unlink_by_name(name: str) -> None:
    """Finalizer backstop: unlink a segment by name if it still exists.

    Keyed by name (not the SharedMemory object) so the finalizer holds no
    reference to the segment it guards; if the owner already unlinked on the
    explicit close path this is a no-op.
    """
    try:
        seg = attach_segment(name)
    except FileNotFoundError:
        return
    try:
        seg.unlink()
    finally:
        seg.close()


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Location of one NumPy array inside a shared-memory segment."""

    offset: int
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class ShardManifest:
    """Everything needed to attach one partition's CSR shard."""

    seg_name: str
    part_id: int
    num_parts: int
    num_nodes: int
    # "<relation>/indptr" and "<relation>/indices" -> location
    arrays: Dict[str, ArraySpec]


def _aligned(nbytes: int) -> int:
    return (nbytes + _ALIGN - 1) // _ALIGN * _ALIGN


def build_shard(
    graph, part_id: int, num_parts: int
) -> Tuple[shared_memory.SharedMemory, ShardManifest]:
    """Gather partition ``part_id``'s owned CSR rows into a shm segment.

    Row ownership and local re-indexing (local row = global // num_parts)
    match ``engine._Partition`` exactly, so a worker serving this shard is
    bitwise-interchangeable with the in-process partition.
    """
    from repro.graph.engine import _gather_rows

    owned = np.arange(part_id, graph.num_nodes, num_parts, dtype=np.int64)
    packed: List[Tuple[int, np.ndarray]] = []
    arrays: Dict[str, ArraySpec] = {}
    offset = 0
    for name, csr in graph.relations.items():
        indptr, indices = _gather_rows(csr.indptr, csr.indices, owned)
        # degrees are precomputed shard metadata: the worker's hot loop then
        # does one gather per query instead of two gathers + a subtraction
        degs = np.diff(indptr)
        for key, arr in (
            (f"{name}/indptr", indptr),
            (f"{name}/indices", indices),
            (f"{name}/degs", degs),
        ):
            arr = np.ascontiguousarray(arr)
            arrays[key] = ArraySpec(offset, tuple(arr.shape), str(arr.dtype))
            packed.append((offset, arr))
            offset += _aligned(arr.nbytes)
    seg = shared_memory.SharedMemory(create=True, size=max(offset, 1))
    # the explicit unlink path is GraphClient.close(); this finalizer is the
    # backstop that keeps /dev/shm clean if the creator dies before closing
    weakref.finalize(seg, _unlink_by_name, seg.name)
    for off, arr in packed:
        view = np.ndarray(arr.shape, dtype=arr.dtype, buffer=seg.buf, offset=off)
        view[...] = arr
    manifest = ShardManifest(
        seg_name=seg.name,
        part_id=part_id,
        num_parts=num_parts,
        num_nodes=int(graph.num_nodes),
        arrays=dict(arrays),
    )
    return seg, manifest


def attach_segment(name: str) -> shared_memory.SharedMemory:
    """Attach an existing segment without resource-tracker registration
    (the pre-3.13 equivalent of ``track=False``): attachers share the
    creator's tracker process, so letting an attach register — or worse,
    unregister — the segment corrupts the creator's accounting and spews
    KeyErrors or spurious leak warnings at teardown. The creator alone owns
    unlink."""
    try:  # tracker internals are stable across 3.8-3.12 but guard anyway
        from multiprocessing import resource_tracker

        orig_register = resource_tracker.register

        def _register_skip_shm(name_, rtype):
            if rtype != "shared_memory":
                orig_register(name_, rtype)

        resource_tracker.register = _register_skip_shm
    except Exception:
        orig_register = None
        resource_tracker = None
    try:
        return shared_memory.SharedMemory(name=name)
    finally:
        if resource_tracker is not None and orig_register is not None:
            resource_tracker.register = orig_register


# ------------------------------------------------------------- reply slabs
def reply_layout(
    shapes: List[Tuple[int, int]], slot_bytes: int, itemsize: int = 4
) -> Optional[List[int]]:
    """Byte offsets of each reply array inside one slab slot, or None if the
    replies do not fit (-> the worker falls back to pickling them).

    Computed identically by the worker (to write) and the client (to read),
    from the shapes the client already knows — so only a tiny tag crosses
    the pipe for a shared-memory reply.
    """
    offsets: List[int] = []
    offset = 0
    for n, k in shapes:
        offsets.append(offset)
        offset += _aligned(n * k * itemsize)
    if offset > slot_bytes:
        return None
    return offsets


def sampleq_layout(
    shapes: List[Tuple[int, int]], slot_bytes: int
) -> Optional[List[Tuple[int, int, int]]]:
    """Slot layout for a whole-call ("sampleq") exchange, one (nodes_offset,
    order_offset, reply_offset) triple per query.

    The client writes each query's owner-sorted global nodes and caller-order
    indices (both int32) into the slot; the worker samples every partition
    segment and scatters the replies into the reply region *in caller order*,
    so the client's entire per-sample cost is one contiguous int32 -> int64
    copy. Returns None when the call does not fit (-> owner-dispatch
    fallback).
    """
    offsets: List[Tuple[int, int]] = []
    offset = 0
    for n, _ in shapes:
        a = offset
        offset += _aligned(n * 4)
        b = offset
        offset += _aligned(n * 4)
        offsets.append((a, b))
    out: List[Tuple[int, int, int]] = []
    for (a, b), (n, k) in zip(offsets, shapes):
        out.append((a, b, offset))
        offset += _aligned(n * k * 4)
    if offset > slot_bytes:
        return None
    return out


def sampleq_request_layout(
    shapes: List[Tuple[int, int]], slot_bytes: int
) -> Optional[List[Tuple[int, int]]]:
    """Request-region offsets of a "sampleq" slot: one (nodes_offset,
    order_offset) pair per query — the prefix of :func:`sampleq_layout`
    without the reply region.

    Used when the *replies* overflow the slot but the request still fits:
    the client ships the request through the slab as usual and the worker
    answers with a pickled caller-order reply ("pickleq") instead of
    forcing the whole call down to owner-dispatch fan-out. Returns None
    when even the request region does not fit. Computed identically on
    both sides from the shapes the client already knows, like the other
    layouts.
    """
    offsets: List[Tuple[int, int]] = []
    offset = 0
    for n, _ in shapes:
        a = offset
        offset += _aligned(n * 4)
        b = offset
        offset += _aligned(n * 4)
        offsets.append((a, b))
    if offset > slot_bytes:
        return None
    return offsets


def slot_view(
    seg: shared_memory.SharedMemory,
    slot: int,
    slot_bytes: int,
    offset: int,
    shape: Tuple[int, int],
) -> np.ndarray:
    """An int32 (n, k) view into slab ``slot`` at ``offset``."""
    return np.ndarray(
        shape, dtype=np.int32, buffer=seg.buf, offset=slot * slot_bytes + offset
    )


def attach_shard(
    manifest: ShardManifest, writeable: bool = False
) -> Tuple[shared_memory.SharedMemory, Dict[str, np.ndarray]]:
    """Attach a shard by manifest: zero-copy views over the live segment.

    The attach is deliberately hidden from ``resource_tracker`` (the
    pre-3.13 equivalent of ``track=False``): workers share the creator's
    tracker process, so letting an attach register — or worse, unregister —
    the segment corrupts the creator's accounting and spews KeyErrors or
    spurious leak warnings at teardown. The creator alone owns unlink.
    """
    seg = attach_segment(manifest.seg_name)
    return seg, manifest_views(seg, manifest, writeable)


def manifest_views(
    seg: shared_memory.SharedMemory,
    manifest: ShardManifest,
    writeable: bool = False,
) -> Dict[str, np.ndarray]:
    """Zero-copy array views over an already-held segment (either the
    creator's own handle or one returned by :func:`attach_shard`)."""
    views: Dict[str, np.ndarray] = {}
    for key, spec in manifest.arrays.items():
        arr = np.ndarray(
            spec.shape, dtype=np.dtype(spec.dtype), buffer=seg.buf, offset=spec.offset
        )
        arr.flags.writeable = writeable
        views[key] = arr
    return views
