"""Multi-process shared-memory graph engine (Graph4Rec §3.1 at host scale).

The paper's distributed graph engine stores partitioned adjacency on
dedicated servers so samplers never contend with training for cores. This
package is that subsystem for a single host:

- ``shm``     — partition CSR shards packed into POSIX shared memory by the
                parent, attached zero-copy by workers.
- ``worker``  — the per-process partition server loop (NumPy-only imports).
- ``client``  — ``GraphClient``: the async, pipelined, API-compatible face
                the walker / ego sampler / pipeline / trainer consume.

Select it with ``TrainerConfig(engine_backend="mp", num_engine_workers=N)``
or construct ``GraphClient`` directly (it is a context manager). With a
fixed seed both backends produce bitwise-identical walks, ego graphs, and
training losses (see ``graph/engine.py`` for the randomness contract).
"""
from repro.graph.service.client import EngineWorkerError, GraphClient, PendingRequest
from repro.graph.service.shm import ArraySpec, ShardManifest, attach_shard, build_shard
from repro.graph.service.worker import worker_main

__all__ = [
    "ArraySpec",
    "EngineWorkerError",
    "GraphClient",
    "PendingRequest",
    "ShardManifest",
    "attach_shard",
    "build_shard",
    "worker_main",
]
