"""Unified decoder-only LM substrate for the assigned architectures.

One config describes every family: each layer is a (mixer, ffn) block where
mixer ∈ {attn, mamba} and ffn ∈ {dense, moe, none}. Dense GQA archs are
(attn, dense) everywhere; Mixtral/OLMoE are (attn, moe); Mamba2 is
(mamba, none); Jamba interleaves (mamba|attn, dense|moe) in its 1:7 pattern.

Layers are executed with ``lax.scan`` over the *repeating period* of the
block pattern (params stacked per offset), which keeps HLO size and compile
time flat in depth — 62-layer DeepSeek compiles the same program as a
2-layer smoke model, just with bigger leading dims. ``remat`` wraps the
scanned body for training memory.

The vocab embedding is the paper's PS-sharded table: rows on the ``model``
axis, pulled via masked-gather+psum (embedding/table.ps_lookup semantics;
under pjit we express it as a plain gather + sharding constraints and let
XLA lower the collective). The LM head is vocab-sharded likewise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models.sharding import constrain

Params = Dict[str, Any]

BlockSpec = Tuple[str, str]  # (mixer, ffn)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv: int
    d_ff: int
    head_dim: int = 128
    blocks: Tuple[BlockSpec, ...] = ()  # len == n_layers; default all (attn, dense)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, ...]] = None
    sliding_window: Optional[int] = None
    mlp_kind: str = "swiglu"
    norm: str = "rms"
    moe: Optional[MOE.MoEConfig] = None
    mamba: Optional[M.Mamba2Config] = None
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    use_flash: bool = False  # Pallas path (TPU); jnp path lowers for dry-run
    aux_loss_weight: float = 0.01
    # scan over layer repetitions (compact HLO, fast compile) vs python-loop
    # unroll. XLA's HloCostAnalysis counts a while-loop body ONCE, so the
    # dry-run unrolls to get true FLOP/byte counts (launch/dryrun.py).
    scan_layers: bool = True
    # ---- perf knobs (EXPERIMENTS.md §Perf levers; defaults = paper-faithful
    # baseline) ----
    block_q: int = 256  # chunked-attention query block (KV re-read ∝ S/block_q)
    # "full": recompute everything in bwd; "dots": save matmul outputs
    # (less recompute, more residency)
    remat_policy: str = "full"
    # reshard the LM head so logits come from a WEIGHT all-gather instead of
    # an ACTIVATION all-reduce (wins when B·S·V >> d·V, i.e. always at train)
    gather_head: bool = False
    # decode: shard the KV-cache SEQUENCE axis over the model axis
    # (context-parallel decode) — kv-head counts (2/3/4/8) can't shard over
    # 16, so without this the per-step attention re-gathers the cache
    shard_cache_seq: bool = False
    # pad q heads to a 16 multiple -> head-parallel attention (see AttnConfig)
    pad_heads: bool = False

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows, padded to a 256 multiple so the vocab axis
        divides the 16-way model mesh (51865, 50280 don't). Logits beyond
        ``vocab`` are masked in the loss / decode head."""
        return -(-self.vocab // 256) * 256

    def block_list(self) -> Tuple[BlockSpec, ...]:
        return self.blocks if self.blocks else tuple(
            [("attn", "dense")] * self.n_layers
        )

    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv=self.n_kv,
            head_dim=self.head_dim,
            qkv_bias=self.qkv_bias,
            causal=True,
            sliding_window=self.sliding_window,
            rope_theta=self.rope_theta,
            mrope_sections=self.mrope_sections,
            chunk_unroll=not self.scan_layers,
            block_q=self.block_q,
            shard_cache_seq=self.shard_cache_seq,
            pad_heads=self.pad_heads,
        )

    def mamba_cfg(self) -> M.Mamba2Config:
        return dataclasses.replace(self.mamba, chunk_unroll=not self.scan_layers)

    def period(self) -> int:
        """Smallest repeating period of the block pattern."""
        blocks = self.block_list()
        n = len(blocks)
        for p in range(1, n + 1):
            if n % p == 0 and all(blocks[i] == blocks[i % p] for i in range(n)):
                return p
        return n


# ---------------------------------------------------------------- parameters
def _init_block(key: jax.Array, cfg: LMConfig, spec: BlockSpec, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": L.init_norm(cfg.norm, cfg.d_model, dtype)}
    mixer, ffn = spec
    if mixer == "attn":
        p["attn"] = L.init_attn(k1, cfg.attn_cfg(), dtype)
    else:
        p["mamba"] = M.init_mamba2(k1, cfg.mamba, dtype)
    if ffn != "none":
        p["norm2"] = L.init_norm(cfg.norm, cfg.d_model, dtype)
    if ffn == "dense":
        p["mlp"] = L.init_mlp(k2, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["moe"] = MOE.init_moe(k3, cfg.moe, dtype)
    return p


def init_lm_params(key: jax.Array, cfg: LMConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    blocks = cfg.block_list()
    p = cfg.period()
    R = len(blocks) // p
    keys = jax.random.split(key, len(blocks) + 3)
    # stack layer params per offset: leaf leading dim = R (scan axis)
    stacked: List[Params] = []
    for off in range(p):
        per_rep = [
            _init_block(keys[rep * p + off], cfg, blocks[off], dtype)
            for rep in range(R)
        ]
        stacked.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_rep))
    scale = 1.0 / np.sqrt(cfg.d_model)
    params: Params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_padded, cfg.d_model)) * scale).astype(dtype),
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[-2], (cfg.d_model, cfg.vocab_padded)) * scale
        ).astype(dtype)
    return params


def abstract_params(cfg: LMConfig) -> Params:
    return jax.eval_shape(lambda k: init_lm_params(k, cfg), jax.random.PRNGKey(0))


# --------------------------------------------------------------- param specs
def param_pspecs(cfg: LMConfig) -> Params:
    """PartitionSpec pytree matching init_lm_params, under current rules."""
    from repro.models.sharding import spec as S

    def attn_specs(qkv_bias):
        d = {
            "wq": S("fsdp", "heads"), "wk": S("fsdp", "kv_heads"),
            "wv": S("fsdp", "kv_heads"), "wo": S("heads", "fsdp"),
        }
        if qkv_bias:
            d.update({"bq": S("heads"), "bk": S("kv_heads"), "bv": S("kv_heads")})
        return d

    def norm_specs(kind):
        return {"scale": S(None)} if kind == "rms" else {"scale": S(None), "bias": S(None)}

    def mlp_specs(kind):
        if kind == "swiglu":
            return {"wg": S("fsdp", "ffn"), "wu": S("fsdp", "ffn"), "wd": S("ffn", "fsdp")}
        return {"wu": S("fsdp", "ffn"), "bu": S("ffn"), "wd": S("ffn", "fsdp"), "bd": S(None)}

    def moe_specs(moecfg):
        if moecfg.shard == "ep":  # experts over model, dims over fsdp
            d = {
                "router": S(None, None),
                "wu": S("experts", "fsdp", None), "wd": S("experts", None, "fsdp"),
            }
            if moecfg.mlp_kind == "swiglu":
                d["wg"] = S("experts", "fsdp", None)
        else:  # tp: per-expert ffn dim over model
            d = {
                "router": S(None, None),
                "wu": S(None, "fsdp", "ffn"), "wd": S(None, "ffn", "fsdp"),
            }
            if moecfg.mlp_kind == "swiglu":
                d["wg"] = S(None, "fsdp", "ffn")
        return d

    def mamba_specs():
        return {
            "wz": S("fsdp", "mamba_heads"), "wx": S("fsdp", "mamba_heads"),
            "wB": S("fsdp", None), "wC": S("fsdp", None), "wdt": S("fsdp", None),
            "wo": S("mamba_heads", "fsdp"), "conv": S(None, None),
            "A_log": S(None), "D": S(None), "dt_bias": S(None),
            "norm_scale": S(None),
        }

    def block_specs(spec_: BlockSpec):
        mixer, ffn = spec_
        d: Params = {"norm1": norm_specs(cfg.norm)}
        if mixer == "attn":
            d["attn"] = attn_specs(cfg.qkv_bias)
        else:
            d["mamba"] = mamba_specs()
        if ffn != "none":
            d["norm2"] = norm_specs(cfg.norm)
        if ffn == "dense":
            d["mlp"] = mlp_specs(cfg.mlp_kind)
        elif ffn == "moe":
            d["moe"] = moe_specs(cfg.moe)
        # stacked leading (scan) dim -> prepend None to every spec
        return jax.tree_util.tree_map(
            lambda s: jax.sharding.PartitionSpec(None, *s), d,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )

    blocks = cfg.block_list()
    p = cfg.period()
    out: Params = {
        "embed": S("vocab", "fsdp"),
        "final_norm": norm_specs(cfg.norm),
        "layers": [block_specs(blocks[off]) for off in range(p)],
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = S("fsdp", "vocab")
    return out


# ------------------------------------------------------------------- forward
def embed_tokens(params: Params, cfg: LMConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """PS pull: gather from the vocab-sharded table (paper §3.6)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if tokens.shape[1] > 1:  # decode steps keep S=1 replicated
        return constrain(x, "batch", "seq", None)
    return constrain(x, "batch", None, None)


def _block_apply(
    cfg: LMConfig,
    spec_: BlockSpec,
    bp: Params,
    x: jnp.ndarray,
    positions: Optional[jnp.ndarray],
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mixer, ffn = spec_
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg.norm, bp["norm1"], x)
    if mixer == "attn":
        x = x + L.attn_forward(bp["attn"], cfg.attn_cfg(), h, positions, cfg.use_flash)
    else:
        x = x + M.mamba2_forward(bp["mamba"], cfg.mamba_cfg(), h)
    if ffn == "dense":
        x = x + L.mlp_forward(bp["mlp"], cfg.mlp_kind, L.apply_norm(cfg.norm, bp["norm2"], x))
    elif ffn == "moe":
        y, aux = MOE.moe_forward(bp["moe"], cfg.moe, L.apply_norm(cfg.norm, bp["norm2"], x))
        x = x + y
    # sequence-parallel residual stream: seq sharded over the model axis
    return constrain(x, "batch", "seq", None), aux


def forward(
    params: Params,
    cfg: LMConfig,
    tokens: Optional[jnp.ndarray] = None,
    inputs_embeds: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward -> (logits (B,S,V), aux_loss)."""
    x = inputs_embeds if inputs_embeds is not None else embed_tokens(params, cfg, tokens)
    blocks = cfg.block_list()
    p = cfg.period()

    def rep_body(x, rep_params):
        aux_sum = jnp.zeros((), jnp.float32)
        for off in range(p):
            x, aux = _block_apply(cfg, blocks[off], rep_params[off], x, positions)
            aux_sum = aux_sum + aux
        return x, aux_sum

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(rep_body, policy=policy)
    else:
        body = rep_body
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(lambda c, xs: body(c, xs), x, params["layers"])
        aux_total = auxs.sum()
    else:
        R = len(blocks) // p
        aux_total = jnp.zeros((), jnp.float32)
        for rep in range(R):
            rep_params = jax.tree_util.tree_map(lambda l: l[rep], params["layers"])
            x, aux = body(x, rep_params)
            aux_total = aux_total + aux
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if cfg.gather_head:
        # pull the head to (d replicated, vocab on model) BEFORE the matmul:
        # one weight all-gather (d·V/16 bytes) replaces the logits
        # all-reduce over the fsdp-sharded contraction (B·S·V/16 bytes).
        head = constrain(head, None, "vocab")
    logits = x @ head
    logits = _mask_padded_vocab(cfg, logits)
    logits = constrain(logits, "batch", None, "vocab")
    return logits, aux_total


def _mask_padded_vocab(cfg: LMConfig, logits: jnp.ndarray) -> jnp.ndarray:
    if cfg.vocab_padded == cfg.vocab:
        return logits
    v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(v_iota < cfg.vocab, logits, -1e30)


def gold_logit(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Label-logit extraction that stays sharded on the vocab axis.

    take_along_axis would force an all-gather of the vocab-sharded logits
    (~16x the logits bytes per device); the iota-compare-select-reduce form
    keeps every operand sharded and fuses to a masked row reduction.
    """
    v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    hit = v_iota == labels[..., None]
    return jnp.where(hit, logits, 0.0).sum(axis=-1)


def lm_loss(
    params: Params,
    cfg: LMConfig,
    tokens: jnp.ndarray,
    labels: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    inputs_embeds: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    logits, aux = forward(params, cfg, tokens, inputs_embeds, positions)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = gold_logit(logits, labels)
    mask = (labels >= 0).astype(jnp.float32)
    ce = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + cfg.aux_loss_weight * aux


# -------------------------------------------------------------------- decode
def init_cache(cfg: LMConfig, batch: int, cache_len: int) -> Params:
    """Per-offset stacked caches (scan layout). cache_len = full context for
    dense archs, sliding window for SWA archs (ring)."""
    dtype = jnp.dtype(cfg.dtype)
    blocks = cfg.block_list()
    p = cfg.period()
    R = len(blocks) // p
    caches: List[Params] = []
    for off in range(p):
        mixer, _ = blocks[off]
        if mixer == "attn":
            s_max = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
            one = L.init_kv_cache(
                L.KVCacheSpec(batch, s_max, cfg.n_kv, cfg.head_dim,
                              ring=cfg.sliding_window is not None), dtype
            )
        else:
            one = M.init_mamba_cache(cfg.mamba, batch, dtype)
        caches.append(
            jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a, (R,) + a.shape), one)
        )
    return {"layers": caches, "t": jnp.zeros((), jnp.int32)}


def cache_pspecs(cfg: LMConfig) -> Params:
    from repro.models.sharding import spec as S

    blocks = cfg.block_list()
    p = cfg.period()
    out: List[Params] = []
    for off in range(p):
        mixer, _ = blocks[off]
        if mixer == "attn":
            # flattened (R, B, S, n_kv*head_dim) layout — see KVCacheSpec
            seq_ax = "cache_seq" if cfg.shard_cache_seq else None
            kv_ax = None if cfg.shard_cache_seq else "kv_heads"
            out.append({
                "k": S(None, "batch", seq_ax, kv_ax),
                "v": S(None, "batch", seq_ax, kv_ax),
            })
        else:
            out.append({
                "ssm": S(None, "batch", "mamba_heads", None, None),
                # conv channels mix x/B/C — keep replicated on the channel dim
                "conv": S(None, "batch", None, None),
            })
    return {"layers": out, "t": jax.sharding.PartitionSpec()}


def decode_step(
    params: Params,
    cfg: LMConfig,
    cache: Params,
    token: jnp.ndarray,  # (B, 1) int32
) -> Tuple[jnp.ndarray, Params]:
    """One-token serve step -> (logits (B, V), new cache)."""
    x = embed_tokens(params, cfg, token)
    blocks = cfg.block_list()
    p = cfg.period()
    t = cache["t"]

    def rep_body(x, xs):
        rep_params, rep_cache = xs
        new_cache = []
        for off in range(p):
            mixer, ffn = blocks[off]
            bp = rep_params[off]
            c = rep_cache[off]
            h = L.apply_norm(cfg.norm, bp["norm1"], x)
            if mixer == "attn":
                y, c = L.attn_decode_step(bp["attn"], cfg.attn_cfg(), c, h, t)
            else:
                y, c = M.mamba2_decode_step(bp["mamba"], cfg.mamba, c, h)
            x = x + y
            if ffn == "dense":
                x = x + L.mlp_forward(bp["mlp"], cfg.mlp_kind,
                                      L.apply_norm(cfg.norm, bp["norm2"], x))
            elif ffn == "moe":
                ymoe, _ = MOE.moe_forward(bp["moe"], cfg.moe,
                                          L.apply_norm(cfg.norm, bp["norm2"], x))
                x = x + ymoe
            new_cache.append(c)
        return x, new_cache

    if cfg.scan_layers:
        x, new_layer_caches = jax.lax.scan(
            rep_body, x, (params["layers"], cache["layers"])
        )
    else:
        blocks_n = len(blocks)
        R = blocks_n // p
        outs = []
        for rep in range(R):
            rp = jax.tree_util.tree_map(lambda l: l[rep], params["layers"])
            rc = jax.tree_util.tree_map(lambda l: l[rep], cache["layers"])
            x, nc = rep_body(x, (rp, rc))
            outs.append(nc)
        new_layer_caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs
        )
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if cfg.gather_head:
        head = constrain(head, None, "vocab")
    logits = _mask_padded_vocab(cfg, (x @ head))[:, 0, :]
    logits = constrain(logits, "batch", "vocab")
    return logits, {"layers": new_layer_caches, "t": t + 1}
