"""Qwen2-VL language backbone (arXiv:2409.12191): M-RoPE + dynamic resolution.

The vision encoder (ViT + merger) is a STUB per the brief: ``input_specs``
supplies precomputed patch embeddings (B, n_patches, d_model). This module
implements what remains the LM's job:

- merging patch embeddings into the token stream at the image placeholder
  span (here: a fixed span right after BOS — dynamic position is a data
  question, not a model one);
- computing the 3-D M-RoPE position ids: text tokens get (t, t, t); vision
  tokens share one temporal index and spread (h, w) over the patch grid,
  matching the paper's multimodal rotary scheme.

Everything else (GQA attention, SwiGLU, sharding) is the shared
transformer.py stack with ``mrope_sections`` set.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.sharding import constrain


def merge_vision_embeds(
    params: Dict,
    cfg: T.LMConfig,
    tokens: jnp.ndarray,  # (B, S)
    patch_embeds: jnp.ndarray,  # (B, Np, d) — stub ViT output
    image_start: int = 1,  # patches occupy [image_start, image_start + Np)
) -> jnp.ndarray:
    """Token embeddings with the image span overwritten by patch embeds."""
    x = T.embed_tokens(params, cfg, tokens)
    Np = patch_embeds.shape[1]
    x = jax.lax.dynamic_update_slice(
        x, patch_embeds.astype(x.dtype), (0, image_start, 0)
    )
    return constrain(x, "batch", None, None)


def mrope_positions(
    batch: int,
    seq_len: int,
    n_patches: int,
    grid_hw: Tuple[int, int],
    image_start: int = 1,
) -> jnp.ndarray:
    """(B, S, 3) position ids: (temporal, height, width).

    Text: (i, i, i). Vision span: temporal frozen at image_start; height/width
    walk the patch grid. Text after the image resumes at
    image_start + max(grid) + 1 (paper's continuity rule).
    """
    H, W = grid_hw
    assert H * W >= n_patches, (grid_hw, n_patches)
    i = jnp.arange(seq_len)
    in_img = (i >= image_start) & (i < image_start + n_patches)
    after = i >= image_start + n_patches
    pi = i - image_start  # patch index within span
    ph = pi // W
    pw = pi % W
    resume = image_start + max(H, W)  # temporal id where post-image text resumes
    shift = resume - (image_start + n_patches)  # applied to trailing text
    t_pos = jnp.where(in_img, image_start, jnp.where(after, i + shift, i))
    h_pos = jnp.where(in_img, image_start + ph, t_pos)
    w_pos = jnp.where(in_img, image_start + pw, t_pos)
    pos = jnp.stack([t_pos, h_pos, w_pos], axis=-1)
    return jnp.broadcast_to(pos[None], (batch, seq_len, 3)).astype(jnp.int32)


def vlm_loss(
    params: Dict,
    cfg: T.LMConfig,
    tokens: jnp.ndarray,
    labels: jnp.ndarray,
    patch_embeds: jnp.ndarray,
    grid_hw: Tuple[int, int],
) -> jnp.ndarray:
    B, S = tokens.shape
    Np = patch_embeds.shape[1]
    x = merge_vision_embeds(params, cfg, tokens, patch_embeds)
    pos = mrope_positions(B, S, Np, grid_hw)
    return T.lm_loss(params, cfg, tokens, labels, positions=pos, inputs_embeds=x)
