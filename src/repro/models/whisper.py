"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the brief, the mel-spectrogram + conv frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, n_frames, d_model). We implement
the transformer that consumes them: a bidirectional encoder with sinusoidal
positions and a causal decoder with learned positions and cross-attention.

Decode shapes lower ``decode_step``: one new token against a self-attn KV
cache plus the precomputed cross-attention K/V of the encoded audio.
Whisper's trained context is 448 tokens; the 32k-decode dry-run exercises
sharding/lowering beyond that, as noted in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.sharding import constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int  # encoder AND decoder layer count (tiny: 4/4)
    n_heads: int
    n_kv: int
    d_ff: int
    n_audio_frames: int = 1500  # post-conv frames (30 s)
    max_target_positions: int = 448

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 256) * 256
    norm: str = "ln"
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True  # see transformer.LMConfig.scan_layers

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self, causal: bool) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads, n_kv=self.n_kv,
            head_dim=self.head_dim, qkv_bias=True, causal=causal,
            use_rope=False, chunk_unroll=not self.scan_layers,
        )


def _scan_or_unroll(cfg: "WhisperConfig", body, x, xs):
    """lax.scan (compact HLO) or python unroll (true cost analysis)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda l: l[i], xs)
        x, y = body(x, xi)
        ys.append(y)
    if any(y is None for y in ys):
        return x, None
    return x, jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)


def _sinusoids(length: int, channels: int) -> np.ndarray:
    t = np.arange(length)[:, None]
    inv = np.exp(-np.log(10000.0) * np.arange(channels // 2) / (channels // 2 - 1))
    ang = t * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def init_whisper_params(key: jax.Array, cfg: WhisperConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    n = cfg.n_layers
    keys = jax.random.split(key, 6 * n + 4)
    ki = iter(range(len(keys)))

    def enc_layer():
        return {
            "norm1": L.init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": L.init_attn(keys[next(ki)], cfg.attn_cfg(False), dtype),
            "norm2": L.init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": L.init_mlp(keys[next(ki)], "gelu", cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_layer():
        return {
            "norm1": L.init_norm(cfg.norm, cfg.d_model, dtype),
            "self_attn": L.init_attn(keys[next(ki)], cfg.attn_cfg(True), dtype),
            "norm_x": L.init_norm(cfg.norm, cfg.d_model, dtype),
            "cross_attn": L.init_cross_attn(keys[next(ki)], cfg.attn_cfg(False), dtype),
            "norm2": L.init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": L.init_mlp(keys[next(ki)], "gelu", cfg.d_model, cfg.d_ff, dtype),
        }

    enc = [enc_layer() for _ in range(n)]
    dec = [dec_layer() for _ in range(n)]
    stack = lambda ls: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ls)
    scale = 1.0 / np.sqrt(cfg.d_model)
    return {
        "enc_pos": jnp.asarray(_sinusoids(cfg.n_audio_frames, cfg.d_model), dtype),
        "dec_pos": (jax.random.normal(keys[next(ki)],
                    (cfg.max_target_positions, cfg.d_model)) * 0.01).astype(dtype),
        "embed": (jax.random.normal(keys[next(ki)], (cfg.vocab_padded, cfg.d_model)) * scale).astype(dtype),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "dec_norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
    }


def abstract_params(cfg: WhisperConfig) -> Params:
    return jax.eval_shape(lambda k: init_whisper_params(k, cfg), jax.random.PRNGKey(0))


def param_pspecs(cfg: WhisperConfig) -> Params:
    from repro.models.sharding import spec as S

    def attn_s():
        return {
            "wq": S(None, "fsdp", "heads"), "wk": S(None, "fsdp", "kv_heads"),
            "wv": S(None, "fsdp", "kv_heads"), "wo": S(None, "heads", "fsdp"),
            "bq": S(None, "heads"), "bk": S(None, "kv_heads"), "bv": S(None, "kv_heads"),
        }

    def norm_s():
        return {"scale": S(None, None), "bias": S(None, None)}

    def mlp_s():
        return {"wu": S(None, "fsdp", "ffn"), "bu": S(None, "ffn"),
                "wd": S(None, "ffn", "fsdp"), "bd": S(None, None)}

    enc = {"norm1": norm_s(), "attn": attn_s(), "norm2": norm_s(), "mlp": mlp_s()}
    dec = {
        "norm1": norm_s(), "self_attn": attn_s(), "norm_x": norm_s(),
        "cross_attn": attn_s(), "norm2": norm_s(), "mlp": mlp_s(),
    }
    return {
        "enc_pos": S(None, None),
        "dec_pos": S(None, None),
        "embed": S("vocab", "fsdp"),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": {"scale": S(None), "bias": S(None)},
        "dec_norm": {"scale": S(None), "bias": S(None)},
    }


# ------------------------------------------------------------------- encode
def encode(params: Params, cfg: WhisperConfig, audio_embeds: jnp.ndarray) -> jnp.ndarray:
    """audio_embeds: (B, n_frames, d) stub frontend output -> encoder states."""
    x = audio_embeds + params["enc_pos"][None, : audio_embeds.shape[1]]
    x = constrain(x, "batch", None, None)
    acfg = cfg.attn_cfg(False)

    def body(x, lp):
        h = L.apply_norm(cfg.norm, lp["norm1"], x)
        x = x + L.attn_forward(lp["attn"], acfg, h)
        x = x + L.mlp_forward(lp["mlp"], "gelu", L.apply_norm(cfg.norm, lp["norm2"], x))
        return constrain(x, "batch", None, None), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = _scan_or_unroll(cfg, body, x, params["enc_layers"])
    return L.apply_norm(cfg.norm, params["enc_norm"], x)


def decode_train(
    params: Params, cfg: WhisperConfig, enc: jnp.ndarray, tokens: jnp.ndarray
) -> jnp.ndarray:
    B, S = tokens.shape
    pos = jnp.minimum(jnp.arange(S), cfg.max_target_positions - 1)
    x = jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][pos][None]
    x = constrain(x, "batch", None, None)
    acfg_self = cfg.attn_cfg(True)
    acfg_x = cfg.attn_cfg(False)

    def body(x, lp):
        h = L.apply_norm(cfg.norm, lp["norm1"], x)
        x = x + L.attn_forward(lp["self_attn"], acfg_self, h)
        kv = L.encode_cross_kv(lp["cross_attn"], acfg_x, enc)
        h = L.apply_norm(cfg.norm, lp["norm_x"], x)
        x = x + L.cross_attn_forward(lp["cross_attn"], acfg_x, h, kv)
        x = x + L.mlp_forward(lp["mlp"], "gelu", L.apply_norm(cfg.norm, lp["norm2"], x))
        return constrain(x, "batch", None, None), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = _scan_or_unroll(cfg, body, x, params["dec_layers"])
    x = L.apply_norm(cfg.norm, params["dec_norm"], x)
    logits = x @ params["embed"].T  # tied head
    if cfg.vocab_padded != cfg.vocab:
        v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(v_iota < cfg.vocab, logits, -1e30)
    return logits


def loss(
    params: Params, cfg: WhisperConfig,
    audio_embeds: jnp.ndarray, tokens: jnp.ndarray, labels: jnp.ndarray,
) -> jnp.ndarray:
    enc = encode(params, cfg, audio_embeds)
    logits = decode_train(params, cfg, enc, tokens).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    logz = jax.nn.logsumexp(logits, axis=-1)
    from repro.models.transformer import gold_logit
    gold = gold_logit(logits, labels)
    mask = (labels >= 0).astype(jnp.float32)
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# -------------------------------------------------------------------- decode
def init_cache(
    params: Params, cfg: WhisperConfig, audio_embeds: jnp.ndarray, cache_len: int
) -> Params:
    """Prefill: encode audio once, precompute per-layer cross K/V, allocate
    the self-attn cache."""
    enc = encode(params, cfg, audio_embeds)
    B = audio_embeds.shape[0]
    dtype = jnp.dtype(cfg.dtype)
    acfg_x = cfg.attn_cfg(False)

    # per-layer cross K/V via vmap over the stacked decoder layer params
    k, v = jax.vmap(
        lambda lp: L.encode_cross_kv(lp["cross_attn"], acfg_x, enc)
    )(params["dec_layers"])
    self_cache = L.init_kv_cache(
        L.KVCacheSpec(B, cache_len, cfg.n_kv, cfg.head_dim, ring=False), dtype
    )
    self_cache = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), self_cache
    )
    return {"self": self_cache, "cross_k": k, "cross_v": v, "t": jnp.zeros((), jnp.int32)}


def cache_pspecs(cfg: WhisperConfig) -> Params:
    from repro.models.sharding import spec as S

    return {
        # self cache is flattened (L, B, S, n_kv*head_dim)
        "self": {"k": S(None, "batch", None, "kv_heads"),
                 "v": S(None, "batch", None, "kv_heads")},
        # cross K/V keep head layout (small: n_frames per layer); heads
        # replicated — 6 kv heads don't divide the 16-way model axis
        "cross_k": S(None, "batch", None, None, None),
        "cross_v": S(None, "batch", None, None, None),
        "t": jax.sharding.PartitionSpec(),
    }


def decode_step(
    params: Params, cfg: WhisperConfig, cache: Params, token: jnp.ndarray
) -> Tuple[jnp.ndarray, Params]:
    B = token.shape[0]
    t = cache["t"]
    pos = jnp.minimum(t, cfg.max_target_positions - 1)
    x = jnp.take(params["embed"], token, axis=0) + params["dec_pos"][pos][None, None]
    acfg_self = cfg.attn_cfg(True)
    acfg_x = cfg.attn_cfg(False)

    def body(x, xs):
        lp, sc, ck, cv = xs
        h = L.apply_norm(cfg.norm, lp["norm1"], x)
        y, sc = L.attn_decode_step(lp["self_attn"], acfg_self, sc, h, t)
        x = x + y
        h = L.apply_norm(cfg.norm, lp["norm_x"], x)
        x = x + L.cross_attn_forward(lp["cross_attn"], acfg_x, h, (ck, cv))
        x = x + L.mlp_forward(lp["mlp"], "gelu", L.apply_norm(cfg.norm, lp["norm2"], x))
        return x, sc

    x, new_self = _scan_or_unroll(
        cfg, body, x,
        (params["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"]),
    )
    x = L.apply_norm(cfg.norm, params["dec_norm"], x)
    logits = (x @ params["embed"].T)[:, 0, :]
    if cfg.vocab_padded != cfg.vocab:
        v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(v_iota < cfg.vocab, logits, -1e30)
    logits = constrain(logits, "batch", "vocab")
    return logits, {"self": new_self, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "t": t + 1}
