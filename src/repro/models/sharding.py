"""Logical-axis sharding rules for the model substrate.

Model code annotates tensors with *logical* axis names; the launcher
installs rules mapping them to physical mesh axes. This keeps every model
definition mesh-agnostic: the same forward works on a single CPU device
(empty rules), the 16x16 single-pod mesh, and the 2x16x16 multi-pod mesh.

    batch   -> ("pod", "data") on multi-pod, ("data",) on single pod, () on CPU
    heads / kv_heads / ffn / experts / vocab / mamba_heads -> "model"
    seq / d_model / head_dim / state -> replicated

Usage:
    with use_rules(POD_RULES):            # launcher
        ...jit(train_step).lower(...)
    x = constrain(x, "batch", None, "heads", None)   # model code
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()

CPU_RULES: Dict[str, Axis] = {}  # everything replicated

SINGLE_POD_RULES: Dict[str, Axis] = {
    "batch": ("data",),
    "fsdp": ("data",),  # weight/optimizer-state sharding over the data axis
    # Megatron-style sequence parallelism: inter-layer activations shard the
    # sequence dim over the model axis (16x smaller activation residency /
    # remat saves); attention/mamba gather the sequence on entry.
    "seq": "model",
    "cache_seq": "model",  # decode KV-cache sequence axis (context-parallel)
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "vocab": "model",
    "mamba_heads": "model",
    "expert_group": ("data",),  # token groups for MoE all-to-all
}

MULTI_POD_RULES: Dict[str, Axis] = {
    **SINGLE_POD_RULES,
    "batch": ("pod", "data"),
    "expert_group": ("pod", "data"),
    # weights replicated across pods (pure DP on the pod axis): "fsdp" stays data
}

def decode_rules(base: Dict[str, Axis]) -> Dict[str, Axis]:
    """Rules for tiny-batch decode (long_500k, batch=1): batch replicated,
    state sharded on heads only."""
    r = dict(base)
    r["batch"] = None
    r["expert_group"] = None
    r["seq"] = None  # decode steps have S=1 (cache_seq stays sharded)
    return r


def current_rules() -> Dict[str, Axis]:
    return getattr(_STATE, "rules", CPU_RULES)


@contextlib.contextmanager
def use_rules(rules: Dict[str, Axis]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def spec(*logical: Optional[str]) -> P:
    """Build a PartitionSpec from logical axis names under current rules."""
    rules = current_rules()
    return P(*[rules.get(name) if name else None for name in logical])


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the current rules (no-op on CPU rules)."""
    rules = current_rules()
    if not rules:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical))
