"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer layer.

Training/prefill uses the *chunked SSD algorithm*: the sequence is split
into chunks of Q tokens; within a chunk the recurrence is computed in its
quadratic "attention-like" dual form (MXU-friendly matmuls), and a short
scan over chunk summaries carries the (H, P, N) state across chunks. This is
the TPU-native adaptation: instead of the CUDA selective-scan kernel we keep
all large contractions as matmuls over hardware-aligned tiles and reduce the
sequential dependency to L/Q scan steps.

Decode keeps a constant-size state h (B, H, P, N) and a depthwise-conv ring
buffer — O(1) per token, which is what makes long_500k feasible.

Shapes: H heads (model-sharded), P headdim, N d_state, G=1 B/C groups.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    # python-unroll the chunk recurrence (dry-run probes: XLA counts scan
    # bodies once; see transformer.LMConfig.scan_layers)
    chunk_unroll: bool = False

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim


def init_mamba2(key: jax.Array, cfg: Mamba2Config, dtype) -> Params:
    ks = jax.random.split(key, 8)
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    sc = 1.0 / np.sqrt(d)
    # dt bias spread log-uniform in [dt_min, dt_max] (mamba init)
    u = jax.random.uniform(ks[6], (H,))
    dt_init = jnp.exp(
        u * (np.log(cfg.dt_max) - np.log(cfg.dt_min)) + np.log(cfg.dt_min)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "wz": (jax.random.normal(ks[0], (d, di)) * sc).astype(dtype),
        "wx": (jax.random.normal(ks[1], (d, di)) * sc).astype(dtype),
        "wB": (jax.random.normal(ks[2], (d, N)) * sc).astype(dtype),
        "wC": (jax.random.normal(ks[3], (d, N)) * sc).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (d, H)) * sc).astype(dtype),
        "wo": (jax.random.normal(ks[5], (di, d)) * (1.0 / np.sqrt(di))).astype(dtype),
        # depthwise causal conv over the x/B/C channels
        "conv": (jax.random.normal(ks[7], (cfg.conv_width, di + 2 * N)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32) + jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
    }


def _proj_xbcdt(p: Params, cfg: Mamba2Config, u: jnp.ndarray):
    """u (B,S,d) -> z, xbc (pre-conv), dt_raw."""
    z = u @ p["wz"]  # (B,S,di)
    xbc = jnp.concatenate([u @ p["wx"], u @ p["wB"], u @ p["wC"]], axis=-1)
    dt_raw = (u @ p["wdt"]).astype(jnp.float32)  # (B,S,H)
    return z, xbc, dt_raw


def _causal_depthwise_conv(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """w (W, Ch), x (B, S, Ch) -> (B, S, Ch) causal depthwise conv + silu."""
    W = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pads[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out)


def _split_xbc(cfg: Mamba2Config, xbc: jnp.ndarray):
    di, N = cfg.d_inner, cfg.d_state
    x = xbc[..., :di]
    Bm = xbc[..., di : di + N]
    Cm = xbc[..., di + N :]
    return x, Bm, Cm


def _gated_norm(p: Params, y: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    # RMSNorm(y) * silu(z), mamba2's norm-then-gate
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    yn = (y * jax.lax.rsqrt(var + 1e-6).astype(y.dtype)) * p["norm_scale"]
    return yn * jax.nn.silu(z)


def mamba2_forward(p: Params, cfg: Mamba2Config, u: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence chunked SSD. u: (B, S, d_model) -> (B, S, d_model)."""
    B, S, _ = u.shape
    H, P, N, Q = cfg.n_heads, cfg.headdim, cfg.d_state, min(cfg.chunk, S)
    assert S % Q == 0, (S, Q)
    Nc = S // Q

    z, xbc, dt_raw = _proj_xbcdt(p, cfg, u)
    xbc = _causal_depthwise_conv(p["conv"], xbc)
    x, Bm, Cm = _split_xbc(cfg, xbc)
    x = constrain(x.reshape(B, S, H, P), "batch", None, "mamba_heads", None)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B,S,H) f32
    A = -jnp.exp(p["A_log"])  # (H,) negative
    # per-chunk views, chunk axis first for the scan
    dA = jnp.moveaxis((dt * A).reshape(B, Nc, Q, H), 1, 0)  # (Nc,B,Q,H)
    dtc = jnp.moveaxis(dt.reshape(B, Nc, Q, H), 1, 0)
    xc = jnp.moveaxis(x.reshape(B, Nc, Q, H, P), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(B, Nc, Q, N).astype(jnp.float32), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(B, Nc, Q, N).astype(jnp.float32), 1, 0)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(h_prev, inp):
        """One SSD chunk: dual quadratic form inside, recurrence across.

        Only (B,Q,Q,H)-sized temporaries are live (one chunk), instead of the
        (B,Nc,Q,Q,H) full-sequence tensor — the TPU-native VMEM-sized tiling
        of the SSD algorithm, expressed at the XLA level."""
        da, dt_q, xq, bq_, cq = inp  # (B,Q,H), (B,Q,H), (B,Q,H,P), (B,Q,N)x2
        lcum = jnp.cumsum(da, axis=1)  # (B,Q,H)
        # intra-chunk: y_diag[t] = Σ_{s<=t} C_t·B_s exp(l_t-l_s) dt_s x_s
        diff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,Q,Q,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bqn,bsn->bqs", cq, bq_)  # (B,Q,Q)
        w = scores[..., None] * decay * dt_q[:, None, :, :]  # (B,Q,Q,H)
        y_diag = jnp.einsum("bqsh,bshp->bqhp", w.astype(xq.dtype), xq)
        # inter-chunk: y_off[t] = exp(l_t)·C_t·h_prev
        y_off = jnp.einsum(
            "bqn,bhpn->bqhp", cq.astype(xq.dtype), h_prev
        ) * jnp.exp(lcum)[..., None].astype(xq.dtype)
        # state update: h = exp(l_Q)·h_prev + Σ_s exp(l_Q-l_s) dt_s B_s⊗x_s
        decay_to_end = jnp.exp(lcum[:, -1:, :] - lcum)  # (B,Q,H)
        wB = (decay_to_end * dt_q)[..., None] * bq_[:, :, None, :]  # (B,Q,H,N)
        s_chunk = jnp.einsum("bqhn,bqhp->bhpn", wB.astype(xq.dtype), xq)
        h = h_prev * jnp.exp(lcum[:, -1, :])[..., None, None].astype(xq.dtype) + s_chunk
        return h, y_diag + y_off

    chunk_step = jax.checkpoint(chunk_step)
    h0 = jnp.zeros((B, H, P, N), x.dtype)
    if cfg.chunk_unroll:
        ys = []
        h = h0
        for c in range(Nc):
            h, y_c = chunk_step(h, (dA[c], dtc[c], xc[c], Bc[c], Cc[c]))
            ys.append(y_c)
        y = jnp.stack(ys)  # (Nc,B,Q,H,P)
    else:
        _, y = jax.lax.scan(chunk_step, h0, (dA, dtc, xc, Bc, Cc))

    y = jnp.moveaxis(y, 0, 1).reshape(B, S, H, P)
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, cfg.d_inner)
    return _gated_norm(p, y, z) @ p["wo"]


# ------------------------------------------------------------------- decode
def init_mamba_cache(cfg: Mamba2Config, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.headdim, cfg.d_state), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.d_state), dtype),
    }


def mamba2_decode_step(
    p: Params, cfg: Mamba2Config, cache: Dict[str, jnp.ndarray], u: jnp.ndarray
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token state update. u: (B, 1, d_model)."""
    B = u.shape[0]
    H, P, N = cfg.n_heads, cfg.headdim, cfg.d_state
    z, xbc, dt_raw = _proj_xbcdt(p, cfg, u)  # (B,1,·)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, W, Ch)
    conv_out = jax.nn.silu((hist * p["conv"][None]).sum(axis=1, keepdims=True))
    new_conv = hist[:, 1:, :]
    x, Bm, Cm = _split_xbc(cfg, conv_out)
    x = x.reshape(B, H, P)
    dt = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))  # (B,H)
    dBx = jnp.einsum(
        "bh,bn,bhp->bhpn", dt.astype(x.dtype), Bm[:, 0], x
    )
    h = cache["ssm"] * a[..., None, None].astype(x.dtype) + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h) + x * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(B, 1, cfg.d_inner)
    out = _gated_norm(p, y, z) @ p["wo"]
    return out, {"ssm": h, "conv": new_conv}
