"""Shared transformer building blocks: norms, RoPE/M-RoPE, GQA attention
(with full / sliding-window KV caches), MLPs.

Pure-functional: params are plain dicts; every init has a matching apply.
Weights are initialized in ``param_dtype`` (bf16 for the production configs)
and activations computed in ``dtype``. Logical sharding annotations use
models/sharding.py so the same code lowers on CPU and on the pod meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain

Params = Dict[str, jnp.ndarray]

NEG_INF = -1e30


# -------------------------------------------------------------------- norms
def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"]


def init_layernorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * p["scale"] + p["bias"]


def apply_norm(kind: str, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return rmsnorm(p, x) if kind == "rms" else layernorm(p, x)


def init_norm(kind: str, dim: int, dtype) -> Params:
    return init_rmsnorm(dim, dtype) if kind == "rms" else init_layernorm(dim, dtype)


# --------------------------------------------------------------------- RoPE
def rope_cos_sin(
    positions: jnp.ndarray,  # (B, S) int — or (B, S, 3) for M-RoPE
    head_dim: int,
    theta: float = 10000.0,
    mrope_sections: Optional[Sequence[int]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rotary angle tables (B, S, head_dim/2).

    M-RoPE (Qwen2-VL, arXiv:2409.12191): the head_dim/2 frequency channels
    are split into sections (temporal, height, width); each section takes its
    angle from the corresponding coordinate of the 3-D position id. Text
    tokens carry identical coordinates in all three channels, which makes
    M-RoPE degenerate to standard RoPE for pure text.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    inv_freq = jnp.asarray(inv_freq)
    if mrope_sections is None:
        assert positions.ndim == 2, positions.shape
        ang = positions[..., None].astype(jnp.float32) * inv_freq  # (B,S,half)
    else:
        assert positions.ndim == 3 and positions.shape[-1] == len(mrope_sections)
        assert sum(mrope_sections) == half, (mrope_sections, half)
        chunks = []
        lo = 0
        for si, sec in enumerate(mrope_sections):
            chunks.append(
                positions[..., si, None].astype(jnp.float32) * inv_freq[lo : lo + sec]
            )
            lo += sec
        ang = jnp.concatenate(chunks, axis=-1)  # (B,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate-half convention; x: (B, S, H, head_dim), cos/sin: (B, S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    causal: bool = True
    sliding_window: Optional[int] = None  # None = full attention
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, ...]] = None  # Qwen2-VL
    use_rope: bool = True  # whisper uses learned/sinusoidal positions instead
    block_q: int = 256  # chunked-attention query block
    # python-unroll the chunk loop (dry-run cost analysis: XLA counts scan
    # bodies once; see LMConfig.scan_layers)
    chunk_unroll: bool = False
    # decode: keep the KV cache sequence-sharded over `model` (context-
    # parallel decode) instead of flat-head-sharded
    shard_cache_seq: bool = False
    # pad query heads up to a multiple of 16 and shard attention by heads:
    # removes the context-parallel AV all-reduce and q gather at the cost of
    # (Hp-H)/H padded compute. Requires Hp % n_kv == 0. Beyond-paper knob.
    pad_heads: bool = False

    @property
    def n_heads_padded(self) -> int:
        if not self.pad_heads:
            return self.n_heads
        hp = -(-self.n_heads // 16) * 16
        assert hp % self.n_kv == 0, (hp, self.n_kv)
        return hp


def init_attn(key: jax.Array, cfg: AttnConfig, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, H, K, hd = cfg.d_model, cfg.n_heads_padded, cfg.n_kv, cfg.head_dim
    sc = 1.0 / np.sqrt(d)
    wq = jax.random.normal(kq, (d, H * hd)) * sc
    wo = jax.random.normal(ko, (H * hd, d)) * (1.0 / np.sqrt(H * hd))
    if H != cfg.n_heads:
        # padded heads: zero their output rows so they never contribute
        mask = (np.arange(H) < cfg.n_heads).repeat(hd)
        wo = wo * mask[:, None]
    p: Params = {
        "wq": wq.astype(dtype),
        "wk": (jax.random.normal(kk, (d, K * hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(kv, (d, K * hd)) * sc).astype(dtype),
        "wo": wo.astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _proj_qkv(p: Params, cfg: AttnConfig, x: jnp.ndarray):
    B, S, _ = x.shape
    q = x @ p["wq"] + (p.get("bq", 0.0))
    k = x @ p["wk"] + (p.get("bk", 0.0))
    v = x @ p["wv"] + (p.get("bv", 0.0))
    q = q.reshape(B, S, cfg.n_heads_padded, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv, cfg.head_dim)
    return q, k, v


def gqa_scores_mask(
    S_q: int, S_kv: int, causal: bool, window: Optional[int], q_offset: int = 0
) -> jnp.ndarray:
    """(S_q, S_kv) additive mask: causal and/or sliding-window band."""
    qi = jnp.arange(S_q)[:, None] + q_offset
    ki = jnp.arange(S_kv)[None, :]
    ok = jnp.ones((S_q, S_kv), bool)
    if causal:
        ok &= ki <= qi
    if window is not None:
        ok &= ki > qi - window
    return jnp.where(ok, 0.0, NEG_INF)


def gqa_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, K, hd)
    v: jnp.ndarray,  # (B, Skv, K, hd)
    mask: Optional[jnp.ndarray],  # broadcastable to (B, 1, Sq, Skv) additive
) -> jnp.ndarray:
    """Grouped-query attention, naive jnp path (materializes Sq×Skv logits).

    Fine for decode (Sq=1) and small smoke shapes; full-sequence training /
    prefill uses chunked_gqa_attention (O(bq·Skv) live logits) or the Pallas
    flash kernel on TPU."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / np.sqrt(hd)
    if mask is not None:
        logits = logits + mask[:, :, None, :, :] if mask.ndim == 4 else logits + mask
    att = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", att, v)
    return out.reshape(B, Sq, H, hd)


def chunked_gqa_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, K, hd)
    v: jnp.ndarray,  # (B, Skv, K, hd)
    causal: bool,
    window: Optional[int],
    block_q: int = 256,
    q_offset: int = 0,
    unroll: bool = False,
) -> jnp.ndarray:
    """Memory-efficient attention: lax.scan over query blocks.

    The XLA analogue of flash attention — at most (B, H, bq, Skv) logits are
    live per step instead of (B, H, Sq, Skv). This is the production default
    for train/prefill shapes (the naive path would need S²-sized HBM temps —
    230+ GB/device at train_4k scale)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    bq = min(block_q, Sq)
    assert Sq % bq == 0, (Sq, bq)
    nq = Sq // bq
    qg = q.reshape(B, nq, bq, K, G, hd)
    kpos = jnp.arange(k.shape[1])[None, :]

    def step(_, inp):
        qi, qblk = inp  # scalar block idx, (B, bq, K, G, hd)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, k) / np.sqrt(hd)
        qpos = (qi * bq + jnp.arange(bq))[:, None] + q_offset
        ok = jnp.ones((bq, k.shape[1]), bool)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        logits = jnp.where(ok[None, None, None], logits.astype(jnp.float32), NEG_INF)
        att = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", att, v)  # (B, bq, K, G, hd)
        return None, out

    # remat each block: backward recomputes the (bq, Skv) logits instead of
    # storing all nq of them (the flash-attention memory contract)
    step = jax.checkpoint(step)
    if unroll:
        outs = jnp.stack(
            [step(None, (jnp.asarray(i), qg[:, i]))[1] for i in range(nq)]
        )
    else:
        _, outs = jax.lax.scan(
            step, None, (jnp.arange(nq), jnp.moveaxis(qg, 1, 0))
        )  # (nq, B, bq, K, G, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)


def attn_forward(
    p: Params,
    cfg: AttnConfig,
    x: jnp.ndarray,  # (B, S, d)
    positions: Optional[jnp.ndarray] = None,  # (B,S) or (B,S,3)
    use_flash: bool = False,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill)."""
    B, S, _ = x.shape
    q, k, v = _proj_qkv(p, cfg, x)
    if cfg.use_rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        cos, sin = rope_cos_sin(
            positions, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
        )
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # Attention parallelism. Default: CONTEXT parallelism — head counts
    # (9, 14, 28…) rarely divide the 16-way model axis, so K/V shard the
    # kv-sequence dim over `model`; each chunk computes partial
    # (bq × S/16) logits and GSPMD reduces softmax stats + the AV
    # contraction with all-reduces. With pad_heads, q-heads are padded to a
    # 16 multiple and attention shards by HEADS instead: K/V replicate
    # (one small gather) and the AV all-reduce disappears. Pinning here
    # (not inside the loop) hoists resharding out of the chunk scan/remat.
    if cfg.pad_heads:
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    else:
        q = constrain(q, "batch", None, None, None)
        k = constrain(k, "batch", "seq", None, None)
        v = constrain(v, "batch", "seq", None, None)
    if use_flash:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.sliding_window
        )
    elif S > cfg.block_q and S % cfg.block_q == 0:
        out = chunked_gqa_attention(
            q, k, v, cfg.causal, cfg.sliding_window,
            block_q=cfg.block_q, unroll=cfg.chunk_unroll,
        )
    else:
        mask = gqa_scores_mask(S, S, cfg.causal, cfg.sliding_window)
        out = gqa_attention(q, k, v, mask)
    out = constrain(out, "batch", None, None, None)
    return out.reshape(B, S, -1) @ p["wo"]


# ------------------------------------------------------------------ caches
@dataclasses.dataclass
class KVCacheSpec:
    """Full cache keeps S_max slots; sliding-window cache keeps a ring of
    ``window`` slots (this is what makes long_500k decode feasible).

    Layout is FLATTENED on the head axis — (B, S, n_kv*head_dim) — so the
    last dim divides the 16-way model axis for every assigned arch (raw
    n_kv of 2/3/4/8 would not), keeping the cache shardable as a jit input."""

    batch: int
    s_max: int  # cache capacity: seq_len (full) or window (SWA ring)
    n_kv: int
    head_dim: int
    ring: bool  # True -> ring buffer indexed modulo s_max


def init_kv_cache(spec: KVCacheSpec, dtype) -> Dict[str, jnp.ndarray]:
    shape = (spec.batch, spec.s_max, spec.n_kv * spec.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attn_decode_step(
    p: Params,
    cfg: AttnConfig,
    cache: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (B, 1, d)
    t: jnp.ndarray,  # scalar int32 — absolute decode position
    use_flash: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode against the KV cache (full or ring)."""
    B = x.shape[0]
    S_max = cache["k"].shape[1]
    ring = cfg.sliding_window is not None and S_max == cfg.sliding_window
    q, k_new, v_new = _proj_qkv(p, cfg, x)
    if cfg.use_rope:
        pos = jnp.broadcast_to(t[None, None], (B, 1))
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(
                t[None, None, None], (B, 1, len(cfg.mrope_sections))
            )
        cos, sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    slot = jnp.where(ring, t % S_max, jnp.minimum(t, S_max - 1))
    kv_flat = cfg.n_kv * cfg.head_dim
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.reshape(B, 1, kv_flat), (0, slot, 0)
    )
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.reshape(B, 1, kv_flat), (0, slot, 0)
    )
    if cfg.shard_cache_seq:
        # context-parallel decode: keep S sharded; softmax stats + the AV
        # partial output all-reduce instead of gathering the cache
        k = constrain(k, "batch", "cache_seq", None)
        v = constrain(v, "batch", "cache_seq", None)
    else:
        k = constrain(k, "batch", None, "kv_heads")
        v = constrain(v, "batch", None, "kv_heads")
    k_heads = k.reshape(B, S_max, cfg.n_kv, cfg.head_dim)
    v_heads = v.reshape(B, S_max, cfg.n_kv, cfg.head_dim)
    # validity: slot s holds absolute position (ring: t - ((t - s) mod S_max))
    s_idx = jnp.arange(S_max)
    if ring:
        age = (slot - s_idx) % S_max  # 0 = newest
        valid = (age <= jnp.minimum(t, S_max - 1))
    else:
        valid = s_idx <= t
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]  # (1,1,1,S)
    out = gqa_attention(q, k_heads, v_heads, mask)  # (B,1,H,hd)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": k, "v": v}


# ----------------------------------------------------------- cross-attention
def init_cross_attn(key: jax.Array, cfg: AttnConfig, dtype) -> Params:
    return init_attn(key, cfg, dtype)


def cross_attn_forward(
    p: Params,
    cfg: AttnConfig,
    x: jnp.ndarray,  # (B, Sq, d) decoder states
    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],  # precomputed (B, Se, K, hd) k, v
) -> jnp.ndarray:
    B, Sq, _ = x.shape
    q = (x @ p["wq"] + p.get("bq", 0.0)).reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    k, v = enc_kv
    out = gqa_attention(q, k, v, None)
    return out.reshape(B, Sq, -1) @ p["wo"]


def encode_cross_kv(p: Params, cfg: AttnConfig, enc: jnp.ndarray):
    B, Se, _ = enc.shape
    k = (enc @ p["wk"] + p.get("bk", 0.0)).reshape(B, Se, cfg.n_kv, cfg.head_dim)
    v = (enc @ p["wv"] + p.get("bv", 0.0)).reshape(B, Se, cfg.n_kv, cfg.head_dim)
    return k, v


# --------------------------------------------------------------------- MLPs
def init_mlp(key: jax.Array, kind: str, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    sc_in = 1.0 / np.sqrt(d_model)
    sc_out = 1.0 / np.sqrt(d_ff)
    if kind == "swiglu":
        return {
            "wg": (jax.random.normal(k1, (d_model, d_ff)) * sc_in).astype(dtype),
            "wu": (jax.random.normal(k2, (d_model, d_ff)) * sc_in).astype(dtype),
            "wd": (jax.random.normal(k3, (d_ff, d_model)) * sc_out).astype(dtype),
        }
    if kind == "gelu":
        return {
            "wu": (jax.random.normal(k1, (d_model, d_ff)) * sc_in).astype(dtype),
            "bu": jnp.zeros((d_ff,), dtype),
            "wd": (jax.random.normal(k2, (d_ff, d_model)) * sc_out).astype(dtype),
            "bd": jnp.zeros((d_model,), dtype),
        }
    raise ValueError(kind)


def mlp_forward(p: Params, kind: str, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
        h = constrain(h, "batch", None, "ffn")
        return h @ p["wd"]
    h = jax.nn.gelu(x @ p["wu"] + p["bu"])
    h = constrain(h, "batch", None, "ffn")
    return h @ p["wd"] + p["bd"]
