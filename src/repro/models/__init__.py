from repro.models import layers, transformer, moe, mamba2, whisper, qwen2_vl, sharding
