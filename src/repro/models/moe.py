"""Mixture-of-Experts layer: top-k router + capacity-based expert dispatch.

TPU-native (GShard/Switch style): token->expert routing is expressed as two
dense einsums against a (group, token, expert, capacity) one-hot dispatch
tensor, so the layer is fully static-shape. Under the pod mesh the expert
axis is sharded on ``model`` and token groups on ``data`` — XLA lowers the
dispatch/combine einsums to all-to-alls, the same communication pattern as
the paper's relation-wise aggregation (tokens->experts ≈ nodes->relations).

Capacity C = ceil(tokens_per_group * top_k / num_experts * capacity_factor);
overflow tokens are dropped (standard GShard semantics) and their residual
path carries them. ``group_size`` bounds the dispatch einsum's quadratic
term — groups are split off the sequence axis.

An auxiliary load-balance loss (Switch-style f·P) is returned for training.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int  # per-expert hidden
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 1024  # tokens per routing group (bounds dispatch cost)
    mlp_kind: str = "swiglu"
    router_jitter: float = 0.0
    # "ep": expert-parallel (experts sharded on model axis; requires
    #       num_experts % model_size == 0 — OLMoE 64, Jamba 16).
    # "tp": tensor-parallel within each expert (per-expert ffn dim sharded;
    #       Mixtral's 8 experts on a 16-way model axis).
    shard: str = "ep"


def init_moe(key: jax.Array, cfg: MoEConfig, dtype) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    sc_in = 1.0 / np.sqrt(d)
    sc_out = 1.0 / np.sqrt(f)
    p: Params = {
        # router kept in f32 — routing decisions are precision-sensitive
        "router": jax.random.normal(kr, (d, E)).astype(jnp.float32) * sc_in,
        "wu": (jax.random.normal(ku, (E, d, f)) * sc_in).astype(dtype),
        "wd": (jax.random.normal(kd, (E, f, d)) * sc_out).astype(dtype),
    }
    if cfg.mlp_kind == "swiglu":
        p["wg"] = (jax.random.normal(kg, (E, d, f)) * sc_in).astype(dtype)
    return p


def _capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    c = int(np.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.num_experts))
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def moe_forward(
    p: Params, cfg: MoEConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    g = min(cfg.group_size, S)
    assert S % g == 0, (S, g)
    G = B * (S // g)
    xt = x.reshape(G, g, d)
    xt = constrain(xt, "expert_group", None, None)
    E, K = cfg.num_experts, cfg.top_k
    C = _capacity(cfg, g)

    logits = (xt.astype(jnp.float32) @ p["router"])  # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, K)  # (G, g, K)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # --- build dispatch/combine tensors with per-expert position counters
    dispatch = jnp.zeros((G, g, E, C), dtype=x.dtype)
    combine = jnp.zeros((G, g, E, C), dtype=x.dtype)
    counts = jnp.zeros((G, E), dtype=jnp.int32)
    for kk in range(K):
        m = jax.nn.one_hot(top_idx[..., kk], E, dtype=jnp.int32)  # (G, g, E)
        pos = jnp.cumsum(m, axis=1) - m + counts[:, None, :]  # (G, g, E)
        keep = (m > 0) & (pos < C)
        counts = counts + (m * keep).sum(axis=1)
        oh = jax.nn.one_hot(jnp.clip(pos, 0, C - 1), C, dtype=x.dtype)  # (G,g,E,C)
        oh = oh * keep[..., None].astype(x.dtype)
        dispatch = dispatch + oh
        # keep combine in x.dtype — an f32 combine would upcast the MoE
        # output and contaminate the whole residual stream with f32 copies
        combine = combine + oh * top_vals[..., kk, None, None].astype(x.dtype)
        combine = combine.astype(x.dtype)

    # --- expert compute (expert axis model-sharded -> all-to-all at the einsums)
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xt)  # (E,G,C,d)
    if cfg.shard == "ep":
        # shard experts on `model` AND token groups on `data`: the all-to-all
        # moves tokens to their experts; every expert-side tensor stays
        # (E/16, G/16, C, ·) so no bwd resharding can materialize a full
        # (E·G·C, d_ff) block on one device.
        xe = constrain(xe, "experts", "expert_group", None, None)
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["wg"])) * jnp.einsum(
            "egcd,edf->egcf", xe, p["wu"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", xe, p["wu"]))
    if cfg.shard == "ep":
        h = constrain(h, "experts", "expert_group", None, None)
    else:  # tp: per-expert hidden dim sharded on the model axis
        h = constrain(h, None, "expert_group", None, "ffn")
    ye = jnp.einsum("egcf,efd->egcd", h, p["wd"])  # (E,G,C,d)
    if cfg.shard == "ep":
        ye = constrain(ye, "experts", "expert_group", None, None)
    else:
        ye = constrain(ye, None, "expert_group", None, None)
    y = jnp.einsum("gsec,egcd->gsd", combine, ye)  # (G,g,d)
    y = constrain(y, "expert_group", None, None)

    # --- Switch aux loss: E * Σ_e f_e · P_e
    f_e = (dispatch.sum(axis=-1) > 0).astype(jnp.float32).mean(axis=1)  # (G,E)
    P_e = probs.mean(axis=1)  # (G, E)
    aux = (E * (f_e * P_e).sum(axis=-1)).mean()
    return y.reshape(B, S, d), aux
