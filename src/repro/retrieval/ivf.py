"""IVF (inverted-file) ANN for million-item recall tables, device-resident.

Exact streaming top-k (retrieval/topk.py) is O(I) compute per query batch.
For million-item corpora the standard serving trick is a coarse quantizer:
cluster the item table into ``nlist`` cells (spherical k-means — the items
are scored by inner product on normalized embeddings, so centroids live on
the same sphere), store each cell's members as an inverted list, and at
query time score only the ``nprobe`` nearest cells' lists.

The index is built around the hardware, not around numpy:

- **Packed CSR lists.** Items are sorted by cell; ``offsets`` (nlist+1)
  delimits each cell's contiguous row range and ``order`` maps packed row
  -> original item id. No dense (nlist, max_len) pad is ever gathered —
  the per-probe slice width is ``lpad`` (the max list length, bounded by
  ``balance_factor`` via hot-cell spilling), and slots past a list's true
  length are masked, not materialized.
- **int8 scalar quantization, asymmetric distance.** Packed rows are
  stored as per-row absmax-scaled int8 codes scored against the f32 query
  (``score = (codes . q) * scale``) — a 10M x 32 table is ~320 MB of codes
  instead of 1.3 GB of f32, so it fits device memory next to the exact
  table (or without it: ``keep_exact_device=False`` re-ranks on host).
- **Device residency.** Centroids, codes, scales, CSR arrays, and (by
  default) the exact table are uploaded once at build via
  ``jax.device_put`` and reused by every ``search()``; the only per-call
  transfers are the queries/exclusion lists in and the (Q, k) results out
  (tested under ``jax.transfer_guard``).
- **Gather-then-score kernel.** The shortlist stage runs the Pallas kernel
  (kernels/ivf.py: scalar-prefetched list offsets driving HBM->VMEM DMAs)
  on TPU, or its jitted XLA oracle (``kernels.ref.ivf_list_topk_ref``) on
  CPU — one contract, conformance-tested.
- **Exact re-rank.** The top ``shortlist`` approximate candidates are
  re-scored with exact f32 dots and re-sorted by ascending item id before
  the final top-k, so the shared lower-id-wins tie-break contract of
  retrieval/topk.py holds end to end. ``nprobe == nlist`` sizes the
  shortlist to the full candidate budget, so exhaustive probing returns
  exactly the oracle's ids (quantization only reorders the shortlist,
  never the exact re-rank; tested).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import ivf_list_topk_ref
from repro.lint.sanitizer import host_array
from repro.retrieval.topk import _deterministic_topk_rows

_INT32_MAX = np.iinfo(np.int32).max
# auto assignment mode switches to hierarchical above this many
# item x centroid score pairs (the full-table assignment GEMM cost)
_HIER_AUTO_THRESHOLD = 2_000_000_000
# truncated spill preference depth: a full (n_spill, nlist) stable argsort
# is tens of GB at the 10M arm; 32 next-best cells place everything in
# practice, with a full-ranking fallback for the rare leftovers
_SPILL_PREF_RANKS = 32


@dataclasses.dataclass(frozen=True)
class IVFConfig:
    nlist: int = 64  # coarse cells
    nprobe: int = 8  # cells scored per query
    kmeans_iters: int = 8
    # k-means training subsample (0 = fit on every item). Million-item
    # tables fit centroids on a sample, then assign the full table once.
    train_size: int = 0
    # Cap each inverted list at this multiple of the mean cell size by
    # spilling a hot cell's weakest members to their next-best centroid.
    # ``lpad`` (the fixed per-probe gather width) — and with it the
    # per-query candidate budget O(nprobe * lpad) — is then bounded even
    # when k-means lands a skewed clustering; every item still lives in
    # exactly one list, so nprobe == nlist stays exhaustive. 0 disables.
    balance_factor: float = 4.0
    # Row-chunk width of the full-table assignment pass (memory bound:
    # O(assign_chunk x nlist) scores live at once).
    assign_chunk: int = 65536
    seed: int = 0
    # Exact-dot re-rank depth: how many approximate-score survivors are
    # re-scored exactly per query. 0 -> auto (max(4k, 128)); the effective
    # shortlist adds the exclusion width and clamps to the probe budget.
    rerank: int = 0
    # Keep the exact f32 table device-resident for the re-rank gather.
    # False re-ranks on host from the builder's numpy table — the 10M-item
    # mode where only the int8 codes fit device memory.
    keep_exact_device: bool = True
    # Full-table assignment pass: "exact" scores all nlist centroids per
    # item; "hier" routes each item through ~sqrt(nlist) centroid groups
    # first (a build-time approximation — cheaper by ~nlist/sqrt(nlist),
    # conformance-tested); "auto" picks hier only when I*nlist is large
    # enough for the exact GEMM to dominate the build.
    assign_mode: str = "auto"
    # Shortlist stage: "pallas" = the gather-then-score kernel,
    # "ref" = its jitted XLA oracle, "auto" = pallas on TPU else ref.
    backend: str = "auto"

    def validate(self) -> None:
        if self.nlist <= 0:
            raise ValueError(f"nlist must be positive, got {self.nlist}")
        if self.nprobe <= 0:
            raise ValueError(f"nprobe must be positive, got {self.nprobe}")
        if self.assign_chunk <= 0:
            raise ValueError(
                f"assign_chunk must be positive, got {self.assign_chunk} "
                "(a non-positive chunk width would silently assign nothing)"
            )
        if self.rerank < 0:
            raise ValueError(f"rerank must be >= 0, got {self.rerank}")
        if self.assign_mode not in ("auto", "exact", "hier"):
            raise ValueError(
                f"assign_mode must be auto|exact|hier, got {self.assign_mode!r}"
            )
        if self.backend not in ("auto", "ref", "pallas"):
            raise ValueError(
                f"backend must be auto|ref|pallas, got {self.backend!r}"
            )


# ------------------------------------------------------------- build helpers
def _quantize_rows(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row absmax int8 quantization -> (codes int8, scales (R, 1) f32)."""
    scales = np.maximum(
        np.abs(x).max(axis=1, keepdims=True) / 127.0, 1e-12
    ).astype(np.float32)
    codes = np.rint(x / scales).astype(np.int8)
    return codes, scales


def _assign_exact(norm: np.ndarray, cent: np.ndarray, chunk: int) -> np.ndarray:
    """Chunked full-table argmax assignment (O(chunk x nlist) live scores)."""
    out = np.empty(norm.shape[0], dtype=np.int64)
    for lo in range(0, norm.shape[0], chunk):
        out[lo : lo + chunk] = np.argmax(norm[lo : lo + chunk] @ cent.T, axis=1)
    return out


def _assign_hier(
    norm: np.ndarray,
    cent: np.ndarray,
    chunk: int,
    rng: np.random.Generator,
    probe_groups: int = 4,
) -> np.ndarray:
    """Two-level assignment: route items through centroid groups.

    The centroids themselves are clustered into ~sqrt(nlist) groups (a tiny
    exact k-means over the centroid set); each item scores the group
    centers, then only the member centroids of its ``probe_groups`` best
    groups — O(sqrt(nlist) + probe_groups * nlist/sqrt(nlist)) scores per
    item instead of O(nlist). A deliberate build-time approximation: an
    item whose true argmax centroid lives outside its probed groups lands
    on its best *probed* centroid instead. Deterministic for a fixed seed;
    search-time contracts (one cell per item, exhaustive-probe exactness)
    are assignment-agnostic.
    """
    nlist, d = cent.shape
    G = max(1, int(round(np.sqrt(nlist))))
    if G < 2:
        return _assign_exact(norm, cent, chunk)
    gc = cent[rng.choice(nlist, size=G, replace=False)].copy()
    for _ in range(4):
        ga = np.argmax(cent @ gc.T, axis=1)
        sums = np.zeros((G, d), np.float32)
        np.add.at(sums, ga, cent)
        nrm = np.linalg.norm(sums, axis=1, keepdims=True)
        ok = nrm[:, 0] > 1e-12
        gc[ok] = (sums / np.maximum(nrm, 1e-12))[ok]
    ga = np.argmax(cent @ gc.T, axis=1)
    members = [np.flatnonzero(ga == g) for g in range(G)]
    gcount = np.bincount(ga, minlength=G)
    pg = min(probe_groups, G)
    assign = np.zeros(norm.shape[0], dtype=np.int64)
    for lo in range(0, norm.shape[0], chunk):
        blk = norm[lo : lo + chunk]
        gs = blk @ gc.T  # (c, G)
        gs[:, gcount == 0] = -np.inf  # a memberless group buys nothing
        topg = np.argpartition(-gs, pg - 1, axis=1)[:, :pg]
        best = np.full(len(blk), -np.inf, dtype=np.float32)
        aa = np.zeros(len(blk), dtype=np.int64)
        # ascending group order + strict > keeps the update deterministic
        for g in range(G):
            mem = members[g]
            if not len(mem):
                continue
            sel = np.flatnonzero((topg == g).any(axis=1))
            if not len(sel):
                continue
            sc = blk[sel] @ cent[mem].T  # (n_sel, |mem|)
            am = sc.argmax(axis=1)
            mx = sc[np.arange(len(sel)), am]
            upd = mx > best[sel]
            hit = sel[upd]
            best[hit] = mx[upd]
            aa[hit] = mem[am[upd]]
        assign[lo : lo + chunk] = aa
    return assign


def _place_rank_rounds(
    spill: np.ndarray,
    prefs: np.ndarray,
    assign: np.ndarray,
    counts: np.ndarray,
    cap: int,
) -> np.ndarray:
    """One admission round per preference rank: round r places every
    still-unplaced spilled item whose r-th-preference cell has room,
    admitting by ascending item id when a cell can't take all claimants.
    Mutates ``assign``/``counts``; returns the placed mask."""
    nlist = len(counts)
    placed = np.zeros(len(spill), dtype=bool)
    for r in range(prefs.shape[1]):
        active = np.flatnonzero(~placed)
        if not len(active):
            break
        tgt = prefs[active, r]
        room = np.maximum(cap - counts, 0)
        # group claimants by target cell, id ascending; admit the first
        # ``room[cell]`` of each group
        lex = np.lexsort((spill[active], tgt))
        tg = tgt[lex]
        grp_start = np.flatnonzero(np.r_[True, np.diff(tg) > 0])
        within = np.arange(len(tg)) - np.repeat(
            grp_start, np.diff(np.r_[grp_start, len(tg)])
        )
        ok = within < room[tg]
        sel = active[lex[ok]]
        assign[spill[sel]] = tg[ok]
        counts += np.bincount(tg[ok], minlength=nlist)
        placed[sel] = True
    return placed


def _spill_hot_cells(
    norm: np.ndarray, cent: np.ndarray, assign: np.ndarray, cap: int
) -> np.ndarray:
    """Move the weakest members of over-``cap`` cells to their next-best
    centroid with room. Every item keeps exactly one cell (exhaustive
    probing stays exact); cap * nlist >= num_items whenever the cap is at
    least the mean cell size, so a slot always exists.

    Vectorized rank rounds (the seed implementation walked spilled items
    one at a time with an O(nlist) inner scan — the loop that dominated
    the 1M-item build): round r places every still-unplaced item whose
    r-th-preference cell has room. Preference lists are truncated to the
    top ``_SPILL_PREF_RANKS`` cells per item, computed chunked — the full
    (n_spill, nlist) argsort is O(10s of GB) at the 10M arm — and the
    rare items whose whole truncated list is full fall back to their full
    ranking. Deterministic for fixed inputs; a deliberate
    conformance-tested change from the sequential greedy order (same cap
    bound, same one-cell-per-item permutation guarantee).
    """
    assign = assign.copy()
    nlist = len(cent)
    counts = np.bincount(assign, minlength=nlist)
    hot = np.flatnonzero(counts > cap)
    if not len(hot):
        return assign
    # weakest members per hot cell, via one cell-sorted pass (a per-cell
    # ``assign == c`` scan is O(n_hot * I) — minutes at the 10M arm)
    by_cell = np.argsort(assign, kind="stable")
    offs = np.zeros(nlist + 1, np.int64)
    offs[1:] = np.cumsum(counts)
    own_aff = np.einsum("ij,ij->i", norm, cent[assign])
    spill_parts = []
    for c in hot:
        members = by_cell[offs[c] : offs[c + 1]]
        weakest = np.argsort(own_aff[members], kind="stable")[
            : counts[c] - cap
        ]
        spill_parts.append(members[weakest])
    spill = np.concatenate(spill_parts)
    counts[hot] = cap  # spilled members vacate their source cells
    R = int(min(nlist, _SPILL_PREF_RANKS))
    prefs = np.empty((len(spill), R), np.int64)
    for lo in range(0, len(spill), 65536):
        sc = norm[spill[lo : lo + 65536]] @ cent.T
        part = np.argpartition(-sc, R - 1, axis=1)[:, :R]
        row = np.arange(len(part))[:, None]
        ordr = np.argsort(-sc[row, part], axis=1, kind="stable")
        prefs[lo : lo + 65536] = part[row, ordr]
    placed = _place_rank_rounds(spill, prefs, assign, counts, cap)
    left = np.flatnonzero(~placed)
    if len(left):  # truncated list exhausted: full ranking for the few
        sp = spill[left]
        full = np.argsort(-(norm[sp] @ cent.T), axis=1, kind="stable")
        _place_rank_rounds(sp, full, assign, counts, cap)
    return assign


# ------------------------------------------------------------ search program
@functools.partial(
    jax.jit, static_argnames=("nprobe", "shortlist", "lpad", "backend")
)
def _ivf_shortlist(
    q, ex, centroids, codes, scales, order, offsets,
    *, nprobe, shortlist, lpad, backend,
):
    """Probe + gather-then-score + exclusion -> (Q, S) approximate shortlist.

    Returns (approx scores, item ids, total candidates scored). Excluded
    and empty slots come back (-inf, -1); candidates are unique per query
    (cells are disjoint and ``top_k`` probes distinct cells), which the
    exact re-rank relies on.
    """
    qf = q.astype(jnp.float32)
    cscores = qf @ centroids.astype(jnp.float32).T  # (Q, nlist)
    _, probes = jax.lax.top_k(cscores, nprobe)  # (Q, nprobe)
    starts = offsets[probes]
    lens = offsets[probes + 1] - starts
    if backend == "pallas":
        s, rows = ops.ivf_list_topk(
            qf, codes, scales, starts, lens, lpad=lpad, shortlist=shortlist
        )
    else:
        s, rows = ivf_list_topk_ref(
            qf, codes, scales, starts, lens, lpad=lpad, shortlist=shortlist
        )
    ids = jnp.where(rows >= 0, order[jnp.maximum(rows, 0)], -1)
    hit = (ex[:, :, None] == ids[:, None, :]).any(axis=1)
    masked = hit | (ids < 0)
    s = jnp.where(masked, -jnp.inf, s)
    ids = jnp.where(masked, -1, ids)
    return s, ids, jnp.sum(jnp.minimum(lens, lpad))


@functools.partial(jax.jit, static_argnames=("k",))
def _rerank_exact_device(q, s, ids, table, *, k):
    """Exact-dot re-rank of the shortlist under the lower-id tie-break.

    Survivors are re-scored against the exact f32 table and re-sorted by
    ascending item id before ``top_k`` (first occurrence of a tied value
    wins), so equal exact scores resolve to the lower id — the contract
    shared with retrieval/topk.py regardless of probe or shortlist order.
    """
    qf = q.astype(jnp.float32)
    masked = jnp.isneginf(s) | (ids < 0)
    vecs = table[jnp.maximum(ids, 0)].astype(jnp.float32)  # (Q, S, d)
    es = jnp.einsum("qd,qsd->qs", qf, vecs)
    es = jnp.where(masked, -jnp.inf, es)
    by_id = jnp.argsort(jnp.where(ids >= 0, ids, _INT32_MAX), axis=1)
    ids2 = jnp.take_along_axis(ids, by_id, axis=1)
    es2 = jnp.take_along_axis(es, by_id, axis=1)
    best, pos = jax.lax.top_k(es2, k)
    bi = jnp.take_along_axis(ids2, pos, axis=1)
    return best, jnp.where(jnp.isneginf(best), -1, bi)


def _rerank_exact_host(q, s, ids, table, k):
    """Host twin of ``_rerank_exact_device`` for ``keep_exact_device=False``:
    the exact table never leaves host memory; only the (Q, S) shortlist is
    pulled back. Same id-ascending pre-sort + tie-stable top-k, so the
    results match the device re-rank (conformance-tested)."""
    masked = np.isneginf(s) | (ids < 0)
    vecs = table[np.maximum(ids, 0)]  # (Q, S, d)
    es = np.einsum("qd,qsd->qs", q, vecs).astype(np.float32)
    es = np.where(masked, -np.inf, es).astype(np.float32)
    by_id = np.argsort(np.where(ids >= 0, ids, _INT32_MAX), axis=1, kind="stable")
    ids2 = np.take_along_axis(ids, by_id, axis=1)
    es2 = np.take_along_axis(es, by_id, axis=1)
    pos = _deterministic_topk_rows(es2, k)  # ascending index == ascending id
    best = np.take_along_axis(es2, pos, axis=1)
    bi = np.take_along_axis(ids2, pos, axis=1)
    return best, np.where(np.isneginf(best), -1, bi)


@dataclasses.dataclass
class IVFIndex:
    """Built coarse index over one item table (ids are row indices).

    Device residency contract: ``build()`` (and any direct construction —
    ``__post_init__``) uploads centroids, codes, scales, and the CSR
    arrays once via ``jax.device_put``; ``search()`` only ever transfers
    queries in and results out.
    """

    config: IVFConfig
    centroids: np.ndarray  # (nlist, d) float32
    order: np.ndarray  # (I,) int32 — packed row -> original item id
    offsets: np.ndarray  # (nlist + 1,) int32 CSR bounds into packed rows
    codes: np.ndarray  # (I + lpad, d) int8 cell-sorted rows (+ DMA pad)
    scales: np.ndarray  # (I + lpad, 1) float32 per-row dequant scales
    items: np.ndarray  # (I, d) float32 — the exact table (host copy)
    lpad: int = 1  # max list length: fixed per-probe gather width
    # items moved off their argmax cell by hot-cell balancing at build
    # time: the recall-vs-balance price the BENCH_recall ANN-rebuild item
    # needs to see (each spilled item is findable only via its second-best
    # cell, exactly the population nprobe misses first)
    spilled_items: int = 0

    def __post_init__(self):
        # accurate per-search telemetry, read by core.recall's counters
        self.last_cells_probed = 0
        self.last_candidates_scored = 0
        self._upload()

    def _upload(self) -> None:
        """One-time host->device residency (the only table-sized H2D)."""
        dp = jax.device_put
        self._dev = {
            "centroids": dp(self.centroids),
            "codes": dp(self.codes),
            "scales": dp(self.scales),
            "order": dp(self.order),
            "offsets": dp(self.offsets),
        }
        if self.config.keep_exact_device:
            self._dev["items"] = dp(self.items)

    @classmethod
    def build(cls, items: np.ndarray, config: IVFConfig = IVFConfig()) -> "IVFIndex":
        config.validate()
        it = host_array(items, dtype=np.float32)
        I, d = it.shape
        nlist = min(config.nlist, I)
        rng = np.random.default_rng(config.seed)
        norm = it / np.maximum(np.linalg.norm(it, axis=1, keepdims=True), 1e-12)
        train = norm
        if config.train_size and config.train_size < I:
            train = norm[
                rng.choice(I, size=max(config.train_size, nlist), replace=False)
            ]
        cent = train[rng.choice(len(train), size=nlist, replace=False)].copy()
        for _ in range(max(1, config.kmeans_iters)):
            t_assign = _assign_exact(train, cent, config.assign_chunk)
            sums = np.zeros((nlist, d), np.float32)
            np.add.at(sums, t_assign, train)
            counts = np.bincount(t_assign, minlength=nlist)
            nrm = np.linalg.norm(sums, axis=1, keepdims=True)
            ok = (counts > 0) & (nrm[:, 0] > 1e-12)
            cent[ok] = (sums / np.maximum(nrm, 1e-12))[ok]
            dead = np.flatnonzero(counts == 0)
            if len(dead):  # re-seed empty cells so every list stays non-trivial
                cent[dead] = train[rng.integers(0, len(train), size=len(dead))]
        mode = config.assign_mode
        if mode == "auto":
            mode = "hier" if I * nlist > _HIER_AUTO_THRESHOLD else "exact"
        if mode == "hier":
            assign = _assign_hier(norm, cent, config.assign_chunk, rng)
        else:
            assign = _assign_exact(norm, cent, config.assign_chunk)
        spilled = 0
        if config.balance_factor:
            cap = max(1, int(np.ceil(config.balance_factor * I / nlist)))
            before = assign
            assign = _spill_hot_cells(norm, cent, assign, cap)
            spilled = int((assign != before).sum())
        order = np.argsort(assign, kind="stable").astype(np.int32)
        counts = np.bincount(assign, minlength=nlist)
        offsets = np.zeros(nlist + 1, np.int32)
        offsets[1:] = np.cumsum(counts).astype(np.int32)
        lpad = max(1, int(counts.max()))
        codes, scales = _quantize_rows(it[order])
        # lpad rows of zero padding so the kernel's fixed-width DMA slice
        # (pl.ds(start, lpad)) never reads past the table
        codes = np.concatenate([codes, np.zeros((lpad, d), np.int8)])
        scales = np.concatenate([scales, np.zeros((lpad, 1), np.float32)])
        return cls(
            config=dataclasses.replace(config, nlist=nlist),
            centroids=cent.astype(np.float32), order=order, offsets=offsets,
            codes=codes, scales=scales, items=it, lpad=lpad,
            spilled_items=spilled,
        )

    # ------------------------------------------------------------- derived
    @property
    def lists(self) -> np.ndarray:
        """Back-compat dense (nlist, lpad) view of the CSR lists, -1 padded.

        Purely derived for inspection/tests — nothing at search time ever
        materializes or gathers this matrix.
        """
        lens = np.diff(self.offsets)
        out = np.full((len(lens), self.lpad), -1, np.int32)
        out[np.arange(self.lpad)[None, :] < lens[:, None]] = self.order
        return out

    @property
    def candidates_per_query(self) -> int:
        """Upper bound on candidates scored per query (probe budget)."""
        return min(self.config.nprobe, self.config.nlist) * self.lpad

    # -------------------------------------------------------------- search
    def search(
        self,
        queries: np.ndarray,
        k: int,
        exclude: Optional[np.ndarray] = None,
        nprobe: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """((Q, k) f32 scores, (Q, k) i32 ids); unfilled slots are (-inf, -1).

        ``k`` may exceed the probed candidate count only up to the table
        size; slots beyond the candidates surface as id -1. Scores are
        exact dots (the quantized scores only pick the shortlist); with
        ``nprobe == nlist`` the shortlist covers every candidate and the
        result equals the exhaustive oracle.
        """
        if nprobe is not None and nprobe <= 0:
            raise ValueError(f"nprobe must be positive, got {nprobe}")
        nprobe = min(
            self.config.nlist, self.config.nprobe if nprobe is None else nprobe
        )
        I = self.items.shape[0]
        if not 0 < k <= I:
            raise ValueError(f"k={k} must be in [1, {I}]")
        q = host_array(queries, dtype=np.float32)
        Q = q.shape[0]
        if exclude is None:
            ex = np.full((Q, 1), -1, np.int32)
        else:
            ex = host_array(exclude, dtype=np.int32)
        budget = nprobe * self.lpad
        if nprobe >= self.config.nlist:
            shortlist = budget  # exhaustive: every candidate survives
        else:
            want = self.config.rerank or max(4 * k, 128)
            shortlist = min(max(want, k) + ex.shape[1], budget)
        backend = self.config.backend
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "ref"
        dev = self._dev
        dq = jax.device_put(q)
        s, ids, n_scored = _ivf_shortlist(
            dq, jax.device_put(ex), dev["centroids"], dev["codes"],
            dev["scales"], dev["order"], dev["offsets"],
            nprobe=nprobe, shortlist=shortlist, lpad=self.lpad,
            backend=backend,
        )
        kk = min(k, shortlist)
        if self.config.keep_exact_device:
            bs, bi = _rerank_exact_device(dq, s, ids, dev["items"], k=kk)
            bs, bi = host_array(bs), host_array(bi)
        else:
            bs, bi = _rerank_exact_host(
                q, host_array(s), host_array(ids), self.items, kk
            )
        self.last_cells_probed = Q * nprobe
        self.last_candidates_scored = int(host_array(n_scored))
        if kk < k:
            bs = np.pad(bs, ((0, 0), (0, k - kk)), constant_values=-np.inf)
            bi = np.pad(bi, ((0, 0), (0, k - kk)), constant_values=-1)
        return bs, bi
