"""IVF (inverted-file) coarse partitioning for million-item recall tables.

Exact streaming top-k (retrieval/topk.py) is O(I) compute per query batch.
For million-item corpora the standard serving trick is a coarse quantizer:
cluster the item table into ``nlist`` cells (spherical k-means — the items
are scored by inner product on normalized embeddings, so centroids live on
the same sphere), store each cell's item ids as an inverted list, and at
query time score only the ``nprobe`` nearest cells' lists. Compute and
memory per query drop to O(nprobe · I / nlist) at a bounded recall cost;
``nprobe == nlist`` degenerates to exhaustive search and returns exactly
the oracle's ids (scores agree to float tolerance — candidates are scored
by a gathered per-candidate dot rather than the dense matmul; tested).

The inverted lists are stored as one padded (nlist, max_len) id matrix so
the whole search — centroid scores, probe selection, candidate gather,
scoring, exclusion masking, final top-k — is a single jitted program with
static shapes. The same tie-break contract as retrieval/topk.py applies
(equal scores -> lower item id wins).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class IVFConfig:
    nlist: int = 64  # coarse cells
    nprobe: int = 8  # cells scored per query
    kmeans_iters: int = 8
    # k-means training subsample (0 = fit on every item). Million-item
    # tables fit centroids on a sample, then assign the full table once.
    train_size: int = 0
    # Cap each inverted list at this multiple of the mean cell size by
    # spilling a hot cell's weakest members to their next-best centroid.
    # The padded (nlist, max_len) list matrix — and with it the per-query
    # candidate gather, O(nprobe * max_len) — is then bounded even when
    # k-means lands a skewed clustering; every item still lives in exactly
    # one list, so nprobe == nlist stays exhaustive. 0 disables the cap.
    balance_factor: float = 4.0
    # Row-chunk width of the full-table assignment pass (memory bound:
    # O(assign_chunk x nlist) scores live at once).
    assign_chunk: int = 65536
    seed: int = 0

    def validate(self) -> None:
        if self.nlist <= 0:
            raise ValueError(f"nlist must be positive, got {self.nlist}")
        if self.nprobe <= 0:
            raise ValueError(f"nprobe must be positive, got {self.nprobe}")
        if self.assign_chunk <= 0:
            raise ValueError(
                f"assign_chunk must be positive, got {self.assign_chunk} "
                "(a non-positive chunk width would silently assign nothing)"
            )


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def _ivf_search(queries, centroids, lists, items, exclude, *, k, nprobe):
    q = queries.astype(jnp.float32)  # (Q, d)
    cscores = q @ centroids.astype(jnp.float32).T  # (Q, nlist)
    _, probes = jax.lax.top_k(cscores, nprobe)  # (Q, nprobe)
    cand = lists[probes].reshape(q.shape[0], -1)  # (Q, nprobe * max_len)
    vecs = items[jnp.maximum(cand, 0)].astype(jnp.float32)  # (Q, C, d)
    scores = jnp.einsum("qd,qcd->qc", q, vecs)
    scores = jnp.where(cand >= 0, scores, -jnp.inf)
    hit = (exclude[:, :, None] == cand[:, None, :]).any(axis=1)
    scores = jnp.where(hit, -jnp.inf, scores)
    # order candidates by ascending item id before top_k so the shared
    # lower-id-wins tie-break holds regardless of probe order; -inf pads
    # sort to the end and can never displace a real candidate
    order = jnp.argsort(jnp.where(cand >= 0, cand, jnp.iinfo(jnp.int32).max))
    cand = jnp.take_along_axis(cand, order, axis=1)
    scores = jnp.take_along_axis(scores, order, axis=1)
    best_s, pos = jax.lax.top_k(scores, k)
    return best_s, jnp.take_along_axis(cand, pos, axis=1)


def _spill_hot_cells(
    norm: np.ndarray, cent: np.ndarray, assign: np.ndarray, cap: int
) -> np.ndarray:
    """Move the weakest members of over-``cap`` cells to their next-best
    centroid with room. Every item keeps exactly one cell (exhaustive
    probing stays exact); cap * nlist >= num_items whenever the cap is at
    least the mean cell size, so a slot always exists."""
    assign = assign.copy()
    counts = np.bincount(assign, minlength=len(cent))
    for c in np.flatnonzero(counts > cap):
        members = np.flatnonzero(assign == c)
        affinity = norm[members] @ cent[c]
        spill = members[np.argsort(affinity)[: len(members) - cap]]
        prefs = np.argsort(-(norm[spill] @ cent.T), axis=1)
        for item, pref in zip(spill, prefs):
            for cand in pref:
                if counts[cand] < cap:
                    assign[item] = cand
                    counts[cand] += 1
                    counts[c] -= 1
                    break
    return assign


@dataclasses.dataclass
class IVFIndex:
    """Built coarse index over one item table (ids are row indices)."""

    config: IVFConfig
    centroids: np.ndarray  # (nlist, d) float32
    lists: np.ndarray  # (nlist, max_len) int32, -1 padded
    items: np.ndarray  # (I, d) float32 — the indexed table
    # items moved off their argmax cell by hot-cell balancing at build
    # time: the recall-vs-balance price the BENCH_recall ANN-rebuild item
    # needs to see (each spilled item is findable only via its second-best
    # cell, exactly the population nprobe misses first)
    spilled_items: int = 0

    @classmethod
    def build(cls, items: np.ndarray, config: IVFConfig = IVFConfig()) -> "IVFIndex":
        config.validate()
        it = np.asarray(items, dtype=np.float32)
        I, d = it.shape
        nlist = min(config.nlist, I)
        rng = np.random.default_rng(config.seed)
        norm = it / np.maximum(np.linalg.norm(it, axis=1, keepdims=True), 1e-12)
        train = norm
        if config.train_size and config.train_size < I:
            train = norm[
                rng.choice(I, size=max(config.train_size, nlist), replace=False)
            ]
        cent = train[rng.choice(len(train), size=nlist, replace=False)]
        for _ in range(max(1, config.kmeans_iters)):
            t_assign = np.argmax(train @ cent.T, axis=1)
            for c in range(nlist):
                members = train[t_assign == c]
                if len(members):
                    m = members.sum(axis=0)
                    cent[c] = m / max(np.linalg.norm(m), 1e-12)
                else:  # re-seed empty cells so every list stays non-trivial
                    cent[c] = train[rng.integers(0, len(train))]
        # one full-table assignment pass (chunked: O(chunk x nlist) memory)
        step = config.assign_chunk
        assign = np.empty(I, dtype=np.int64)
        for lo in range(0, I, step):
            assign[lo : lo + step] = np.argmax(norm[lo : lo + step] @ cent.T, axis=1)
        spilled = 0
        if config.balance_factor:
            cap = max(1, int(np.ceil(config.balance_factor * I / nlist)))
            before = assign
            assign = _spill_hot_cells(norm, cent, assign, cap)
            spilled = int((assign != before).sum())
        counts = np.bincount(assign, minlength=nlist)
        max_len = max(1, int(counts.max()))
        lists = np.full((nlist, max_len), -1, dtype=np.int32)
        for c in range(nlist):
            members = np.flatnonzero(assign == c)
            lists[c, : len(members)] = members
        return cls(
            config=dataclasses.replace(config, nlist=nlist),
            centroids=cent, lists=lists, items=it, spilled_items=spilled,
        )

    @property
    def candidates_per_query(self) -> int:
        return min(self.config.nprobe, self.config.nlist) * self.lists.shape[1]

    def search(
        self,
        queries: np.ndarray,
        k: int,
        exclude: Optional[np.ndarray] = None,
        nprobe: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """((Q, k) f32 scores, (Q, k) i32 ids); unfilled slots are (-inf, -1).

        ``k`` may exceed the probed candidate count only up to the table
        size; slots beyond the candidates surface as id -1.
        """
        q = np.asarray(queries, dtype=np.float32)
        if nprobe is not None and nprobe <= 0:
            raise ValueError(f"nprobe must be positive, got {nprobe}")
        nprobe = min(
            self.config.nlist, self.config.nprobe if nprobe is None else nprobe
        )
        if not 0 < k <= self.items.shape[0]:
            raise ValueError(f"k={k} must be in [1, {self.items.shape[0]}]")
        kk = min(k, nprobe * self.lists.shape[1])
        ex = (
            jnp.full((q.shape[0], 1), -1, jnp.int32)
            if exclude is None
            else jnp.asarray(np.asarray(exclude, dtype=np.int32))
        )
        s, i = _ivf_search(
            jnp.asarray(q), jnp.asarray(self.centroids), jnp.asarray(self.lists),
            jnp.asarray(self.items), ex, k=kk, nprobe=nprobe,
        )
        s, i = np.asarray(s), np.asarray(i)
        # shared filler contract: a -inf slot never carries a real id
        i = np.where(np.isneginf(s), -1, i)
        if kk < k:
            s = np.pad(s, ((0, 0), (0, k - kk)), constant_values=-np.inf)
            i = np.pad(i, ((0, 0), (0, k - kk)), constant_values=-1)
        return s, i
