"""Device-side retrieval: the serving half of Graph4Rec's recall story.

Training (PRs 1-3) produces embedding tables; this package turns them into
served recommendations at scale:

- ``topk``: exact maximum-inner-product search — a numpy brute-force oracle
  plus chunked/streaming device paths (jitted ``lax.scan`` and a Pallas
  kernel) whose memory is O(chunk), not O(items).
- ``ivf``: inverted-file coarse partitioning for million-item tables —
  spherical k-means cells, ``nprobe``-bounded search, recall traded for an
  O(nlist / nprobe) compute reduction.

``repro.core.recall`` builds the paper's ICF/UCF/U2I recall strategies on
top of these primitives; ``benchmarks/bench_recall.py`` measures them.
"""
from repro.retrieval.topk import (
    brute_force_topk, chunked_topk, pad_id_rows,
)
from repro.retrieval.ivf import IVFConfig, IVFIndex
