"""Exact top-k retrieval: numpy oracle + device chunked/streaming paths.

Three implementations of the same maximum-inner-product search, all under
one tie-break contract (higher score first; on equal scores the lower item
id wins) so they are interchangeable and testable against each other:

- ``brute_force_topk``: numpy reference — materializes the (Q, I) score
  block. O(Q·I) memory; retained as the test oracle and the seed-equivalent
  baseline arm of ``benchmarks/bench_recall.py``.
- ``chunked_topk``: jitted ``lax.scan`` over item chunks with a running
  (Q, k) best state — O(Q·(k + chunk)) device memory regardless of the item
  count, which is what lets recall evaluation scale to million-item tables.
- ``backend="pallas"``: the fused Pallas kernel (kernels/topk.py), same
  streaming structure with the chunk sweep as the inner grid axis.

``exclude`` is a (Q, E) padded id matrix (-1 = empty slot): per query, the
listed item ids score -inf — how a user's training history is dropped
during recall without a host-side post-filter.
"""
from __future__ import annotations

import functools
import weakref
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.lint.sanitizer import host_array

NEG_INF = np.float32(-np.inf)

# Device-resident copies of recently-searched item tables, keyed by the
# host array's identity (+ shape/layout knobs). Retrieval callers reuse one
# corpus across thousands of query batches; before this cache every call
# re-shipped the full table host->device (the BENCH_recall "IVF loses to
# brute force" bug had the same root). Entries are evicted when the host
# array is garbage-collected (weakref) and the table is bounded FIFO.
_DEVICE_TABLE_CACHE: dict = {}
_DEVICE_TABLE_CACHE_MAX = 8


def _cached_device_table(arr: np.ndarray, tag, make):
    """jax.device_put(make(arr)) memoized on the host array's identity."""
    key = (id(arr), arr.shape, arr.dtype.str, tag)
    hit = _DEVICE_TABLE_CACHE.get(key)
    if hit is not None:
        return hit[1]
    dev = jax.device_put(make(arr))
    ref = weakref.ref(arr, lambda _, k=key: _DEVICE_TABLE_CACHE.pop(k, None))
    _DEVICE_TABLE_CACHE[key] = (ref, dev)
    while len(_DEVICE_TABLE_CACHE) > _DEVICE_TABLE_CACHE_MAX:
        _DEVICE_TABLE_CACHE.pop(next(iter(_DEVICE_TABLE_CACHE)))
    return dev


def pad_id_rows(rows, width: int = 0, pad: int = -1) -> np.ndarray:
    """Ragged id lists -> (len(rows), width) padded int32 matrix."""
    width = max(width, 1, *(len(r) for r in rows)) if rows else max(width, 1)
    out = np.full((len(rows), width), pad, dtype=np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


def _deterministic_topk_rows(scores: np.ndarray, k: int) -> np.ndarray:
    """Row-wise top-k positions, ties broken by ascending index."""
    n = scores.shape[-1]
    k = min(k, n)
    # argsort of -score is not tie-stable; lexsort on (index, -score) is.
    idx = np.lexsort(
        (np.broadcast_to(np.arange(n), scores.shape), -scores), axis=-1
    )
    return idx[..., :k]


def brute_force_topk(
    queries: np.ndarray,
    items: np.ndarray,
    k: int,
    exclude: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy oracle: full (Q, I) scores, then deterministic row top-k.

    Returns ((Q, k) float32 scores, (Q, k) int32 ids). Shared filler
    contract with every device path: slots with no surviving item (k
    exceeds the non-excluded count) come back as (-inf, -1) — a -inf score
    never carries a real id, so consumers can filter on ``ids >= 0``.
    """
    q = host_array(queries, dtype=np.float32)
    it = host_array(items, dtype=np.float32)
    if not 0 < k <= it.shape[0]:
        raise ValueError(f"k={k} must be in [1, num_items={it.shape[0]}]")
    scores = q @ it.T
    if exclude is not None:
        ex = host_array(exclude)
        rows = np.repeat(np.arange(ex.shape[0]), ex.shape[1])
        cols = ex.reshape(-1)
        valid = cols >= 0
        scores[rows[valid], cols[valid]] = NEG_INF
    ids = _deterministic_topk_rows(scores, k)
    top = np.take_along_axis(scores, ids, axis=-1)
    return top, np.where(np.isneginf(top), -1, ids).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("k", "chunk", "num_items"))
def _chunked_topk_scan(queries, items3, exclude, *, k, chunk, num_items):
    """(Q, d) x (nchunks, chunk, d) -> streaming exact top-k (lax.scan).

    Per chunk: score the block, drop padded columns, -inf the excluded ids
    this chunk owns (a scatter — O(Q·E), not the O(Q·E·chunk) broadcast
    compare), reduce the chunk to its local top-k (top_k straight on the
    score block: no concat/gather on the wide axis), then fold it into the
    running (Q, k) best via a top-k over 2k candidates. Live memory is
    O(Q·(chunk + k)) — independent of the item count.

    Tie-break: ``lax.top_k`` keeps the first occurrence of a tied value, so
    in-chunk ties resolve to the lower id, and putting the running state
    first in the 2k merge makes earlier chunks (smaller ids) win globally —
    the same lower-id-wins contract as the numpy oracle.
    """
    Q = queries.shape[0]
    q32 = queries.astype(jnp.float32)
    rows = jnp.arange(Q, dtype=jnp.int32)[:, None]
    init = (
        jnp.full((Q, k), -jnp.inf, jnp.float32),
        jnp.full((Q, k), -1, jnp.int32),
    )

    def body(carry, inp):
        ci, chunk_items = inp
        best_s, best_i = carry
        base = ci * chunk
        scores = q32 @ chunk_items.astype(jnp.float32).T  # (Q, chunk)
        gid = base + jnp.arange(chunk, dtype=jnp.int32)
        scores = jnp.where(gid[None, :] < num_items, scores, -jnp.inf)
        # excluded ids owned by this chunk -> -inf via a dropped scatter
        col = jnp.where(
            (exclude >= base) & (exclude < base + chunk), exclude - base, chunk
        )
        scores = scores.at[rows, col].set(-jnp.inf, mode="drop")
        c_s, pos = jax.lax.top_k(scores, k)  # chunk-local top-k
        all_s = jnp.concatenate([best_s, c_s], axis=1)  # (Q, 2k)
        all_i = jnp.concatenate([best_i, base + pos.astype(jnp.int32)], axis=1)
        best_s, mpos = jax.lax.top_k(all_s, k)
        return (best_s, jnp.take_along_axis(all_i, mpos, axis=1)), None

    n = items3.shape[0]
    (best_s, best_i), _ = jax.lax.scan(
        body, init, (jnp.arange(n, dtype=jnp.int32), items3)
    )
    return best_s, best_i


def chunked_topk(
    queries,
    items,
    k: int,
    exclude: Optional[np.ndarray] = None,
    item_chunk: int = 8192,
    query_chunk: int = 0,
    backend: str = "ref",
) -> Tuple[np.ndarray, np.ndarray]:
    """Device streaming top-k; bitwise-matching drop-in for the oracle.

    ``backend="ref"`` is the jitted ``lax.scan`` path; ``"pallas"`` routes
    through the fused kernel (interpret mode off-TPU). ``query_chunk`` > 0
    additionally sweeps queries in fixed-shape host-side blocks so one call
    never holds more than (query_chunk, k + item_chunk) scores — the shape
    the jit caches, padded on the last block.
    """
    q = host_array(queries, dtype=np.float32)
    it = host_array(items, dtype=np.float32)
    Q, I = q.shape[0], it.shape[0]
    if not 0 < k <= I:
        raise ValueError(f"k={k} must be in [1, num_items={I}]")
    if item_chunk <= 0:
        raise ValueError(f"item_chunk must be positive, got {item_chunk}")
    if query_chunk < 0:
        raise ValueError(
            f"query_chunk must be >= 0 (0 disables query chunking), "
            f"got {query_chunk}"
        )
    if exclude is not None:
        exclude = host_array(exclude, dtype=np.int32)

    if query_chunk and Q > query_chunk:
        out_s = np.empty((Q, k), np.float32)
        out_i = np.empty((Q, k), np.int32)
        for lo in range(0, Q, query_chunk):
            hi = min(lo + query_chunk, Q)
            qb = q[lo:hi]
            exb = exclude[lo:hi] if exclude is not None else None
            if hi - lo < query_chunk:  # pad to the cached jit shape
                pad = query_chunk - (hi - lo)
                qb = np.pad(qb, ((0, pad), (0, 0)))
                if exb is not None:
                    exb = np.pad(exb, ((0, pad), (0, 0)), constant_values=-1)
            s, i = chunked_topk(
                qb, it, k, exclude=exb, item_chunk=item_chunk, backend=backend
            )
            out_s[lo:hi], out_i[lo:hi] = s[: hi - lo], i[: hi - lo]
        return out_s, out_i

    if backend == "pallas":
        from repro.kernels import ops

        ex = None if exclude is None else jax.device_put(exclude)
        dit = _cached_device_table(it, "flat", lambda a: a)
        s, i = ops.streaming_topk(
            jax.device_put(q), dit, k, exclude=ex, item_chunk=item_chunk
        )
        s, i = host_array(s), host_array(i)
        return s, np.where(np.isneginf(s), -1, i)
    if backend != "ref":
        raise ValueError(f"unknown topk backend {backend!r}")

    chunk = max(min(item_chunk, I), k)  # phase-1 keeps k per chunk
    Ip = -(-I // chunk) * chunk

    def _blocks(a: np.ndarray) -> np.ndarray:
        if Ip != I:
            a = np.pad(a, ((0, Ip - I), (0, 0)))
        return a.reshape(Ip // chunk, chunk, -1)

    items3 = _cached_device_table(it, ("scan", chunk), _blocks)
    ex = (
        jnp.full((Q, 1), -1, jnp.int32)
        if exclude is None
        else jax.device_put(exclude)
    )
    s, i = _chunked_topk_scan(
        jax.device_put(q), items3, ex, k=k, chunk=chunk, num_items=I
    )
    s, i = host_array(s), host_array(i)
    return s, np.where(np.isneginf(s), -1, i)
