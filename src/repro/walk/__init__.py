from repro.walk.metapath import (
    WalkConfig, MetapathWalker, parse_metapath, jax_walk, jax_walk_multi,
)
