"""Multi-metapath random walk generation (Graph4Rec §3.2).

A metapath is a sequence of relation names assembled head-to-tail with a
hyphen, e.g. ``"u2click2i - i2click2u"``; walks repeat the metapath until the
requested walk length is reached (metapath2vec semantics). Multiple metapaths
may be given ("multi-metapaths random walk"): each walk draws one of them.
A homogeneous random walk (DeepWalk) is the degenerate metapath ``"u2u - u2u"``.

Two implementations:

- ``MetapathWalker`` — NumPy, runs against ``HeteroGraph`` *or* the
  ``DistributedGraphEngine`` (the production data-pipeline path; the paper's
  walker also runs host-side on the graph servers).
- ``jax_walk`` / ``jax_walk_multi`` — pure ``jax.lax.scan`` over padded
  adjacency, fully jittable. ``jax_walk_multi`` runs walks of SEVERAL
  metapaths together (each walk carries its own per-step relation schedule)
  and is the walk stage of the fused on-device sampler
  (``sampling/fused.py``); ``jax_walk`` is its single-relation special case.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.graph.engine import engine_sample_many
from repro.graph.hetero_graph import HeteroGraph, Relation

PAD = -1


def parse_metapath(mp: str) -> List[str]:
    """``"u2click2i - i2click2u"`` -> ["u2click2i", "i2click2u"]; validates chaining."""
    rels = [p.strip() for p in mp.split("-") if p.strip()]
    if not rels:
        raise ValueError(f"empty metapath {mp!r}")
    parsed = [Relation.parse(r) for r in rels]
    for a, b in zip(parsed, parsed[1:]):
        if a.dst_type != b.src_type:
            raise ValueError(
                f"metapath {mp!r}: {a.name} ends at type {a.dst_type!r} but "
                f"{b.name} starts at {b.src_type!r}"
            )
    return [p.name for p in parsed]


@dataclasses.dataclass
class WalkConfig:
    metapaths: Sequence[str]  # e.g. ("u2click2i - i2click2u", "u2buy2i - i2buy2u")
    walk_len: int = 8  # number of nodes per walk (path length)
    walks_per_node: int = 1


class MetapathWalker:
    """Host-side multi-metapath walker (paper-faithful data pipeline stage)."""

    def __init__(self, graph_or_engine, config: WalkConfig):
        self.g = graph_or_engine
        self.config = config
        self.paths = [parse_metapath(mp) for mp in config.metapaths]
        if not self.paths:
            raise ValueError("need at least one metapath")
        # construction-time state only, so build the per-step relation
        # schedule once instead of on every sampling round
        self._rel_names, self._rel_sched = self._relation_schedule()

    def start_nodes(self, rng: np.random.Generator, path_idx: int, n: int) -> np.ndarray:
        """Uniform start nodes of the metapath's source type."""
        first = Relation.parse(self.paths[path_idx][0])
        graph = self.g.graph if hasattr(self.g, "graph") else self.g
        start, count = graph.node_type_ranges[first.src_type]
        return rng.integers(start, start + count, size=n).astype(np.int64)

    def walk(
        self, rng: np.random.Generator, starts: np.ndarray, path_idx: int = 0
    ) -> np.ndarray:
        """Walk from ``starts``: (B,) -> (B, walk_len), PAD after a dead end."""
        path_of = np.full(len(starts), path_idx, dtype=np.int64)
        return self._walk_batched(rng, np.asarray(starts, dtype=np.int64), path_of)

    def _relation_schedule(self) -> Tuple[List[str], np.ndarray]:
        """(relation names, (num_paths, walk_len-1) relation-id schedule)."""
        rel_names = sorted({r for p in self.paths for r in p})
        rel_id = {r: i for i, r in enumerate(rel_names)}
        L = self.config.walk_len
        sched = np.empty((len(self.paths), max(L - 1, 1)), dtype=np.int64)
        for pi, rels in enumerate(self.paths):
            for s in range(max(L - 1, 1)):
                sched[pi, s] = rel_id[rels[s % len(rels)]]
        return rel_names, sched

    def _walk_batched(
        self, rng: np.random.Generator, starts: np.ndarray, path_of: np.ndarray
    ) -> np.ndarray:
        """Advance walks of ALL metapaths together: per step, the frontier is
        grouped by relation and ALL relation groups are issued as one
        ``sample_many`` query group — a single engine round per step (one
        pipelined request round-trip per worker on the mp backend) instead of
        one call per metapath."""
        L = self.config.walk_len
        B = len(starts)
        out = np.full((B, L), PAD, dtype=np.int64)
        out[:, 0] = starts
        cur = starts.copy()
        alive = np.ones(B, dtype=bool)
        rel_names, sched = self._rel_names, self._rel_sched
        for step in range(1, L):
            if not alive.any():
                break
            step_rel = sched[path_of, step - 1]
            nxt = np.full(B, PAD, dtype=np.int64)
            step_rids = np.unique(step_rel[alive])
            sels = [alive & (step_rel == ri) for ri in step_rids]
            queries = [
                (cur[sel], rel_names[int(ri)], 1, PAD)
                for ri, sel in zip(step_rids, sels)
            ]
            for sel, sampled in zip(sels, engine_sample_many(self.g, rng, queries)):
                nxt[sel] = sampled[:, 0]
            alive = alive & (nxt != PAD)
            out[alive, step] = nxt[alive]
            cur = np.where(alive, nxt, cur)
        return out

    def generate(self, rng: np.random.Generator, num_walks: int) -> np.ndarray:
        """Round-robin over metapaths; returns (num_walks, walk_len).

        All metapaths advance in ONE batched walk (see ``_walk_batched``);
        rows stay grouped by metapath index, matching the chunked layout of
        the per-metapath implementation.
        """
        per = max(1, num_walks // len(self.paths))
        counts = []
        for pi in range(len(self.paths)):
            n = per if pi < len(self.paths) - 1 else num_walks - per * (len(self.paths) - 1)
            counts.append(max(0, n))
        starts = [
            self.start_nodes(rng, pi, n) for pi, n in enumerate(counts) if n > 0
        ]
        path_of = np.repeat(
            np.arange(len(self.paths), dtype=np.int64), np.asarray(counts, dtype=np.int64)
        )
        return self._walk_batched(rng, np.concatenate(starts), path_of)


# --------------------------------------------------------------------- JAX
def jax_walk_multi(
    key: jax.Array,
    adj: jnp.ndarray,  # (R, num_nodes, max_degree) padded adjacency per relation
    degree: jnp.ndarray,  # (R, num_nodes)
    starts: jnp.ndarray,  # (B,)
    sched: jnp.ndarray,  # (num_paths, walk_len - 1) relation id per step
    path_of: jnp.ndarray,  # (B,) metapath index of each walk
    walk_len: int,
) -> jnp.ndarray:
    """Jittable multi-metapath random walk via lax.scan -> (B, walk_len).

    Each walk ``b`` follows its own metapath ``path_of[b]``: at step ``t`` it
    samples a neighbor under relation ``sched[path_of[b], t - 1]`` from the
    stacked padded adjacency. Dead ends self-loop and are masked to PAD in
    the output — PAD is suffix-only, matching ``MetapathWalker``. A PAD (or
    degree-0) start emits PAD from step 1 on.
    """
    B = starts.shape[0]
    step_rels = sched[path_of].T  # (walk_len - 1, B)
    # ONE random-bits draw for the whole walk: per-step randint calls cost
    # a full threefry invocation each, which dominates small-batch walks on
    # CPU. Offsets come from bits % degree — the modulo bias is
    # O(max_degree / 2^32), far below anything a distribution test can see.
    bits = jax.random.bits(key, (max(walk_len - 1, 1), B), jnp.uint32)

    def step(carry, inp):
        bits_t, rel_t = inp
        cur, alive = carry
        deg = degree[rel_t, cur]
        off = (bits_t % jnp.maximum(deg, 1).astype(jnp.uint32)).astype(deg.dtype)
        nxt = adj[rel_t, cur, off]
        ok = alive & (deg > 0)
        nxt = jnp.where(ok, nxt, cur)
        return (nxt, ok), jnp.where(ok, nxt, PAD)

    safe_starts = jnp.maximum(starts, 0)
    # walk_len is small and static: unrolling removes the per-iteration
    # scan overhead (measurable on CPU, free on TPU)
    (_, _), rest = jax.lax.scan(
        step,
        (safe_starts, starts >= 0),
        (bits[: walk_len - 1], step_rels),
        unroll=True,
    )
    return jnp.concatenate([starts[:, None], rest.T], axis=1)


def jax_walk(
    key: jax.Array,
    adj: jnp.ndarray,  # (num_nodes, max_degree) padded adjacency for ONE relation chain
    degree: jnp.ndarray,  # (num_nodes,)
    starts: jnp.ndarray,  # (B,)
    walk_len: int,
) -> jnp.ndarray:
    """Jittable homogeneous/collapsed-metapath random walk via lax.scan.

    For heterogeneous metapaths, pass the *relation-collapsed* adjacency (the
    composition graph of one metapath period) — or use ``jax_walk_multi``,
    which this is the single-relation case of. Dead ends self-loop and are
    masked to PAD in the output, matching the NumPy walker's semantics.
    """
    B = starts.shape[0]
    sched = jnp.zeros((1, max(walk_len - 1, 1)), dtype=jnp.int32)
    path_of = jnp.zeros((B,), dtype=jnp.int32)
    return jax_walk_multi(
        key, adj[None], degree[None], starts, sched, path_of, walk_len
    )
