"""Render the dry-run JSON into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report \
        benchmarks/results/dryrun.json benchmarks/results/dryrun_multi.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def fmt_b(x: float) -> str:
    if x >= 1e12:
        return f"{x / 1e12:.2f}TB"
    if x >= 1e9:
        return f"{x / 1e9:.2f}GB"
    if x >= 1e6:
        return f"{x / 1e6:.1f}MB"
    return f"{x / 1e3:.0f}KB"


def roofline_table(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | mem/dev | compute | memory | collective | dominant"
        " | MODEL_FLOPS | useful | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|"[:-4] + "|",
    ]
    hints = {
        ("compute",): "larger per-chip batch or fused kernels (MXU util)",
        ("memory",): "flash/fused attention (cut S² HBM traffic), bf16 end-to-end",
        ("collective",): "overlap weight-gathers with compute; reduce "
                         "context-parallel AR via ring attention",
    }
    for r in sorted(rows, key=lambda r: (r["arch"], ORDER.index(r["shape"]))):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped |"
                       f" — | — | {r['reason'][:48]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR {r['error'][:60]} |")
            continue
        hint = hints[(r["dominant"],)]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['memory']['total_gb']:.2f}GB "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['useful_ratio']:.2f} | {hint} |"
        )
    return "\n".join(out)


def dryrun_table(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | mesh | status | compile | bytes/dev | FLOPs/dev |"
        " collective bytes/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], ORDER.index(r["shape"]))):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped "
                       f"({r['reason'][:40]}…) | — | — | — | — |")
        elif r["status"] == "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                f"| {r['compile_s']:.1f}s | {fmt_b(r['memory']['argument_bytes'] + r['memory']['temp_bytes'])} "
                f"| {r.get('flops_per_device', 0):.2e} "
                f"| {fmt_b(r.get('collective_bytes_per_device', 0))} |"
            )
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR |"
                       f" {r['error'][:70]} | | | |")
    return "\n".join(out)


def main() -> None:
    single = json.load(open(sys.argv[1]))
    multi = json.load(open(sys.argv[2])) if len(sys.argv) > 2 else []
    print("## Roofline (single pod, 16x16 = 256 chips)\n")
    print(roofline_table(single))
    print("\n## Dry-run matrix\n")
    print(dryrun_table(single + multi))


if __name__ == "__main__":
    main()
