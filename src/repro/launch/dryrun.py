import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) and emit
roofline terms.

512 placeholder CPU devices stand in for 2 pods × 256 TPU v5e chips. The
XLA_FLAGS line above MUST run before any other import — jax locks the device
count at first init (do NOT set this globally; smoke tests want 1 device).

Per (arch, shape, mesh) the dry-run performs THREE compiles:

1. **full** — the production program (lax.scan over layer periods). This is
   the lowering/sharding proof and the source of memory_analysis().
2. **probe@1, probe@2** — the same program at 1 and 2 repeating periods of
   depth, with layers and attention chunk-loops python-unrolled. XLA's
   HloCostAnalysis counts a while-loop body ONCE regardless of trip count,
   so per-layer FLOPs/bytes/collective-bytes are recovered exactly by linear
   extrapolation:  total = P1 + (reps-1)·(P2-P1)   (layers repeat per
   period, so depth-linearity is exact by construction).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out benchmarks/results/dryrun.json
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_arch  # noqa: E402
from repro.configs.registry import ARCH_IDS  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.sharding import (  # noqa: E402
    MULTI_POD_RULES, SINGLE_POD_RULES, decode_rules, use_rules,
)
from repro.train import optimizer as opt_lib  # noqa: E402
from repro.utils import get_logger  # noqa: E402

log = get_logger("repro.dryrun")


# ----------------------------------------------------------------- counting
def _count(tree) -> float:
    return float(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))


def _count_active(spec, aparams) -> float:
    """Active params for MoE archs: expert weights scaled by top_k/E."""
    total = _count(aparams)
    if spec.kind == "whisper" or spec.lm is None or spec.lm.moe is None:
        return total
    moe = spec.lm.moe
    inactive_frac = 1.0 - moe.top_k / moe.num_experts
    moe_params = 0.0
    for off_block in aparams["layers"]:
        if "moe" in off_block:
            for name, leaf in off_block["moe"].items():
                if name != "router":
                    moe_params += float(np.prod(leaf.shape))
    return total - moe_params * inactive_frac


# ----------------------------------------------------------------- sharding
def _shardify(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _opt_pspecs(param_specs):
    return opt_lib.AdamState(
        step=P(),
        mu=param_specs,
        nu=jax.tree_util.tree_map(
            lambda p: p, param_specs, is_leaf=lambda x: isinstance(x, P)
        ),
    )


# ------------------------------------------------------------------ lowering
def compile_spec(spec, shape, mesh, rules):
    """Lower + compile one ArchSpec variant. Returns compiled executable."""
    from repro.configs.base import resolve_shape

    s = resolve_shape(shape)
    shape = s
    with jax.set_mesh(mesh), use_rules(rules):
        aparams = spec.abstract_params()
        pspecs = spec.param_pspecs()
        batch = spec.input_specs(shape)
        bspecs = spec.input_pspecs(shape)
        if s.kind == "train":
            opt = opt_lib.adam(1e-3, weight_decay=0.01)
            aopt = jax.eval_shape(opt.init, aparams)
            ospecs = _opt_pspecs(pspecs)
            fn = spec.make_train_step(opt)
            lowered = jax.jit(
                fn,
                in_shardings=(_shardify(mesh, pspecs), _shardify(mesh, ospecs),
                              _shardify(mesh, bspecs)),
                donate_argnums=(0, 1),
            ).lower(aparams, aopt, batch)
        elif s.kind == "prefill":
            fn = spec.make_prefill()
            lowered = jax.jit(
                fn,
                in_shardings=(_shardify(mesh, pspecs), _shardify(mesh, bspecs)),
            ).lower(aparams, batch)
        else:  # decode
            acache = spec.abstract_cache(shape)
            cspecs = spec.cache_pspecs()
            fn = spec.make_serve_step()
            lowered = jax.jit(
                fn,
                in_shardings=(_shardify(mesh, pspecs), _shardify(mesh, cspecs),
                              _shardify(mesh, bspecs)),
                donate_argnums=(1,),
            ).lower(aparams, acache, batch)
        return lowered.compile()


def _costs(compiled, chips: int) -> Tuple[float, float, float, Dict[str, float]]:
    ca = compiled.cost_analysis() or {}
    cb, breakdown = RL.collective_bytes(compiled.as_text(), default_group=chips)
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            cb, breakdown)


def run_one(arch_id: str, shape: str, multi_pod: bool, reduced: bool = False,
            probes: bool = True, spec=None) -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch_id, "shape": shape, "mesh": mesh_name}
    try:
        spec = spec if spec is not None else get_arch(arch_id, reduced=reduced)
        ok, reason = spec.supports(shape)
        if not ok:
            return {**base, "status": "skipped", "reason": reason}
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(np.prod(mesh.devices.shape))
        rules = MULTI_POD_RULES if multi_pod else SINGLE_POD_RULES
        if shape == "long_500k":
            rules = decode_rules(rules)

        # ---- 1. full production compile (scan layers): lowering proof + memory
        t0 = time.perf_counter()
        compiled = compile_spec(spec, shape, mesh, rules)
        compile_s = time.perf_counter() - t0
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "total_gb": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                         + ma.output_size_in_bytes) / 1e9,
        }
        f_raw, b_raw, c_raw, _ = _costs(compiled, chips)

        # ---- 2. probes (unrolled): trip-count-corrected costs.
        # XLA counts scan bodies once, so probe modules are python-unrolled.
        # Decode steps have no chunk loops -> two depth probes at full cache
        # size suffice (cost is depth-linear). Train/prefill probes would be
        # enormous unrolled at S=32k, so we exploit that per-layer cost is
        # EXACTLY a + b·S + c·S² (attention is quadratic, everything else
        # linear/constant): probe at S ∈ {1k, 2k, 4k} × depth {1p, 2p},
        # solve the polynomial per layer and for the base, and evaluate at
        # the target S.
        if probes:
            p = spec.period_layers
            reps = spec.depth_reps
            s_full = SHAPES[shape]
            # probes run mb=1: microbatching is FLOP/byte-neutral (k grad
            # steps at B/k each) but multiplies unrolled HLO size by k; the
            # only production delta is k× per-step weight re-gathers, noted
            # in EXPERIMENTS.md §Dry-run caveats.
            spec = dataclasses.replace(spec, microbatches=1)
            if s_full.kind == "decode":
                probe1 = compile_spec(spec.with_layers(p).unrolled(), shape, mesh, rules)
                probe2 = compile_spec(spec.with_layers(2 * p).unrolled(), shape, mesh, rules)
                c1s = _costs(probe1, chips)
                c2s = _costs(probe2, chips)
                flops, bytes_acc, cbytes = (
                    a + (reps - 1) * (b - a)
                    for a, b in zip(c1s[:3], c2s[:3])
                )
                bd1, bd2 = c1s[3], c2s[3]
                breakdown = {
                    k: bd1.get(k, 0.0)
                    + (reps - 1) * (bd2.get(k, 0.0) - bd1.get(k, 0.0))
                    for k in set(bd1) | set(bd2)
                }
            else:
                s_probe = [1024, 2048, 4096]
                per_depth = []  # [depth][s_idx] -> (flops, bytes, coll)
                for depth in (p, 2 * p):
                    row = []
                    for sp in s_probe:
                        shp = dataclasses.replace(s_full, seq_len=sp)
                        comp = compile_spec(
                            spec.with_layers(depth).unrolled(), shp, mesh, rules
                        )
                        row.append(_costs(comp, chips)[:3])
                    per_depth.append(row)

                def _fit_eval(vals3, s_target):
                    """Exact quadratic through 3 (S, val) points."""
                    coef = np.polyfit(np.array(s_probe, float), np.array(vals3), 2)
                    return float(np.polyval(coef, s_target))

                out3 = []
                for j in range(3):  # flops, bytes, coll
                    layer = [
                        per_depth[1][i][j] - per_depth[0][i][j] for i in range(3)
                    ]
                    nonlayer = [per_depth[0][i][j] - layer[i] for i in range(3)]
                    out3.append(
                        _fit_eval(nonlayer, s_full.seq_len)
                        + reps * _fit_eval(layer, s_full.seq_len)
                    )
                flops, bytes_acc, cbytes = (max(v, 0.0) for v in out3)
                breakdown = {}
        else:
            flops, bytes_acc, cbytes = f_raw, b_raw, c_raw
            breakdown = {}

        # ---- 3. roofline terms
        aparams = spec.abstract_params()
        n_params = _count(aparams)
        n_active = _count_active(spec, aparams)
        s = SHAPES[shape]
        tokens = s.global_batch * (s.seq_len if s.kind != "decode" else 1)
        model_flops = RL.model_flops_estimate(
            n_params, n_active, tokens, "train" if s.kind == "train" else "fwd"
        )
        compute_s = flops / RL.PEAK_FLOPS
        memory_s = bytes_acc / RL.HBM_BW
        collective_s = cbytes / RL.LINK_BW
        dominant = max((("compute", compute_s), ("memory", memory_s),
                        ("collective", collective_s)), key=lambda kv: kv[1])[0]
        out = {
            **base, "status": "ok", "chips": chips, "kind": s.kind,
            "compile_s": compile_s, "memory": mem,
            "n_params": n_params, "n_params_active": n_active,
            "flops_per_device": flops, "bytes_per_device": bytes_acc,
            "collective_bytes_per_device": cbytes,
            "collective_breakdown": breakdown,
            "raw_scan_counts": {"flops": f_raw, "bytes": b_raw, "coll": c_raw},
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant,
            "model_flops": model_flops,
            "useful_ratio": model_flops / (flops * chips) if flops else 0.0,
        }
        log.info(
            "OK %-20s %-12s %-8s compile=%5.1fs mem=%7.2fGB "
            "comp=%.2es mem=%.2es coll=%.2es dom=%-10s useful=%.2f",
            arch_id, shape, mesh_name, compile_s, mem["total_gb"],
            compute_s, memory_s, collective_s, dominant, out["useful_ratio"],
        )
        return out
    except Exception as e:
        log.error("FAIL %s %s %s: %s", arch_id, shape, mesh_name, e)
        return {**base, "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the depth-probe compiles (lowering proof only)")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                results.append(run_one(arch, shape, multi, reduced=args.reduced,
                                       probes=not args.no_probes))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    existing = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                existing[(r["arch"], r["shape"], r["mesh"])] = r
    for r in results:
        existing[(r["arch"], r["shape"], r["mesh"])] = r
    with open(args.out, "w") as f:
        json.dump(list(existing.values()), f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")
    if n_err:
        for r in results:
            if r["status"] == "error":
                print(f"  ERROR {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
