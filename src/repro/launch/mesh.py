"""Production mesh construction (TPU v5e pods; CPU placeholder devices for
the dry-run).

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """Version-tolerant ``jax.make_mesh``: newer JAX accepts ``axis_types``
    (and ``jax.sharding.AxisType``); older releases have neither, and the
    default (auto) behavior is what we want anyway — so fall back to plain
    ``make_mesh`` when the kwarg or the enum is unavailable."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests (all axes size 1)."""
    return _make_mesh((1, 1), ("data", "model"))
