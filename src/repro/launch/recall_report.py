"""Render eval_recsys JSON results as the paper-style comparison table.

Graph4Rec's experimental story (§4.2, Tables 2-4) is a systematic model ×
dataset × recall-strategy comparison. ``examples/eval_recsys.py`` writes one
JSON record per scenario; this module turns that list into a markdown
report: one table per dataset, one row per model, Recall/Hit/NDCG columns
per strategy, plus a serving-throughput appendix (embed + retrieval time).

    PYTHONPATH=src python -m repro.launch.recall_report results.json > REPORT.md
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

METRICS = ("", "_hit", "_ndcg")
METRIC_NAMES = ("R", "Hit", "NDCG")


def _fmt(x: float) -> str:
    return f"{x:.4f}"


def render_recall_report(results: List[Dict]) -> str:
    """``results``: records with keys dataset, model, method, top_k,
    metrics (flat strategy dict), num_users, num_items, embed_s, eval_s."""
    out: List[str] = ["# Recall evaluation report", ""]
    datasets = sorted({r["dataset"] for r in results})
    for ds_name in datasets:
        rows = [r for r in results if r["dataset"] == ds_name]
        strategies = sorted(
            {k for r in rows for k in r["metrics"] if "_" not in k}
        )
        r0 = rows[0]
        out.append(
            f"## {ds_name} ({r0['num_users']} users, {r0['num_items']} items, "
            f"@K={r0['top_k']})"
        )
        out.append("")
        header = ["model", "method"]
        for s in strategies:
            header += [f"{s} {m}" for m in METRIC_NAMES]
        header += ["embed s", "eval s"]
        out.append("| " + " | ".join(header) + " |")
        out.append("|" + "---|" * len(header))
        for r in sorted(rows, key=lambda r: (r["model"], r["method"])):
            cells = [r["model"], r["method"]]
            for s in strategies:
                cells += [_fmt(r["metrics"].get(s + m, 0.0)) for m in METRICS]
            cells += [f"{r['embed_s']:.2f}", f"{r['eval_s']:.2f}"]
            out.append("| " + " | ".join(cells) + " |")
        out.append("")
    return "\n".join(out)


def main(argv: List[str]) -> None:
    with open(argv[0]) as f:
        payload = json.load(f)
    print(render_recall_report(payload["results"]))


if __name__ == "__main__":
    main(sys.argv[1:])
