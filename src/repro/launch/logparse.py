"""Reconstruct dryrun JSON rows from sweep log lines (crash/kill recovery).

The dry-run only writes its JSON at the end; if a sweep is interrupted the
per-run log lines still carry every roofline field we print. This parser
rebuilds result rows from them (memory breakdown reduced to total_gb).
"""
from __future__ import annotations

import json
import re
import sys
from typing import Dict, List

LINE = re.compile(
    r"OK (\S+)\s+(\S+)\s+(\S+)\s+compile=\s*([\d.]+)s mem=\s*([\d.]+)GB "
    r"comp=([\d.e+-]+)s mem=([\d.e+-]+)s coll=([\d.e+-]+)s dom=(\S+)\s+useful=([\d.]+)"
)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def parse(path: str) -> List[Dict]:
    rows = []
    for line in open(path):
        m = LINE.search(line)
        if not m:
            continue
        arch, shape, mesh, comp_s, mem_gb, c, b, co, dom, useful = m.groups()
        compute_s, memory_s, collective_s = float(c), float(b), float(co)
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
            "chips": 512 if mesh == "2x16x16" else 256,
            "compile_s": float(comp_s),
            "memory": {"total_gb": float(mem_gb), "argument_bytes": 0,
                       "temp_bytes": int(float(mem_gb) * 1e9), "output_bytes": 0},
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dom,
            "useful_ratio": float(useful),
            "flops_per_device": compute_s * PEAK_FLOPS,
            "bytes_per_device": memory_s * HBM_BW,
            "collective_bytes_per_device": collective_s * LINK_BW,
            "model_flops": float(useful) * compute_s * PEAK_FLOPS
                           * (512 if mesh == "2x16x16" else 256),
            "reconstructed_from_log": True,
        })
    return rows


def main() -> None:
    log_path, out_path = sys.argv[1], sys.argv[2]
    rows = parse(log_path)
    existing = {}
    try:
        for r in json.load(open(out_path)):
            existing[(r["arch"], r["shape"], r["mesh"])] = r
    except FileNotFoundError:
        pass
    for r in rows:
        existing.setdefault((r["arch"], r["shape"], r["mesh"]), r)
    json.dump(list(existing.values()), open(out_path, "w"), indent=1)
    print(f"{len(rows)} parsed; {len(existing)} total -> {out_path}")


if __name__ == "__main__":
    main()
