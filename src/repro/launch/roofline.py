"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch × shape × mesh) we derive, per chip:

    compute_s    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
    memory_s     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective_s = collective_bytes_per_device / link_bw    (50 GB/s/link)

``compiled.cost_analysis()`` reports per-partition flops / bytes accessed
(verified empirically on the CPU backend). Collective bytes are NOT in
cost_analysis: we parse the post-SPMD optimized HLO and sum the *wire bytes
per device* of every collective under ring-algorithm cost models:

    all-gather          result_bytes × (g-1)/g
    all-reduce          result_bytes × 2(g-1)/g
    reduce-scatter      result_bytes × (g-1)        (operand = result × g)
    all-to-all          result_bytes × (g-1)/g
    collective-permute  result_bytes

with g the participant-group size parsed from replica_groups (iota
``[n,g]<=[...]`` or explicit ``{{...}}`` form).

MODEL_FLOPS uses the 6·N·D convention (train; 2·N·D forward-only), with
N_active for MoE; the ratio MODEL_FLOPS / (HLO_FLOPs × chips) measures how
much compiled compute is "useful" (catches remat recompute, GSPMD padding
waste, dispatch overhead).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, default_group: int) -> Tuple[float, Dict[str, float]]:
    """Per-device wire bytes summed over all collectives in the module."""
    per_op: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if f" {op}-start(" not in line and f" {op}(" not in line and not line.strip().startswith("ROOT"):
            # matched a -done or metadata line; only count the op itself
            if f"{op}-done" in line:
                continue
        b = _shape_bytes(m.group("shape"))
        g = max(2, _group_size(line, default_group))
        if op == "all-gather":
            wire = b * (g - 1) / g
        elif op == "all-reduce":
            wire = b * 2 * (g - 1) / g
        elif op == "reduce-scatter":
            wire = b * (g - 1)
        elif op == "all-to-all":
            wire = b * (g - 1) / g
        else:  # collective-permute
            wire = float(b)
        per_op[op] = per_op.get(op, 0.0) + wire
    return sum(per_op.values()), per_op


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs × chips)
    n_params: float
    n_params_active: float
    arg_bytes_per_device: float
    temp_bytes_per_device: float

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    compiled,
    model_flops: float,
    n_params: float,
    n_params_active: float,
) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    cbytes, breakdown = collective_bytes(compiled.as_text(), default_group=chips)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = cbytes / LINK_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", collective_s)),
        key=lambda kv: kv[1],
    )[0]
    ma = compiled.memory_analysis()
    arg_b = float(getattr(ma, "argument_size_in_bytes", 0) or 0)
    tmp_b = float(getattr(ma, "temp_size_in_bytes", 0) or 0)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        collective_bytes_per_device=cbytes, collective_breakdown=breakdown,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        n_params=n_params, n_params_active=n_params_active,
        arg_bytes_per_device=arg_b, temp_bytes_per_device=tmp_b,
    )


def model_flops_estimate(
    n_params: float, n_active: float, tokens: float, kind: str
) -> float:
    """6·N·D train, 2·N·D forward-only (prefill), 2·N_active per decoded token."""
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens
