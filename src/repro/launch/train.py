"""LM training launcher for the assigned architectures.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
        --steps 50 --batch 4 --seq 128

Trains an LM-family arch on synthetic token streams with the same train_step
the dry-run lowers for the pod meshes. On this CPU container use --reduced
(the full configs are exercised via launch/dryrun.py without allocation);
on a real pod, drop --reduced and pass --mesh to shard with the production
rules.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.train import optimizer as opt_lib
from repro.utils import get_logger

log = get_logger("repro.launch.train")


def synth_batch(rng, spec, batch: int, seq: int):
    vocab = spec.whisper.vocab if spec.kind == "whisper" else spec.lm.vocab
    # markov-ish synthetic stream: next token correlated with current
    base = rng.integers(0, vocab, size=(batch, seq + 1))
    drift = (base[:, :-1] + rng.integers(0, 7, size=(batch, seq))) % vocab
    tokens = np.where(rng.random((batch, seq)) < 0.7, drift, base[:, :-1])
    labels = np.roll(tokens, -1, axis=1).copy()
    labels[:, -1] = -1  # no target for the last position
    out = {"tokens": jnp.asarray(tokens, jnp.int32),
           "labels": jnp.asarray(labels, jnp.int32)}
    if spec.kind == "vlm":
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, spec.n_patches, spec.d_model)) * 0.02,
            spec.dtype)
    if spec.kind == "whisper":
        out["audio_embeds"] = jnp.asarray(
            rng.normal(size=(batch, spec.whisper.n_audio_frames, spec.d_model)) * 0.02,
            spec.dtype)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_arch(args.arch, reduced=args.reduced)
    if args.reduced:
        # smoke-scale: disable microbatching
        import dataclasses

        spec = dataclasses.replace(spec, microbatches=1)
    opt = opt_lib.adam(args.lr)
    params = spec.init_params(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    step_fn = jax.jit(spec.make_train_step(opt))

    rng = np.random.default_rng(args.seed)
    losses = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = synth_batch(rng, spec, args.batch, args.seq)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if (step + 1) % 10 == 0:
            log.info("step %d loss %.4f", step + 1, float(loss))
    dt = time.perf_counter() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"{args.arch}: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({args.steps} steps, {tok_s:.0f} tok/s on {jax.default_backend()})")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
