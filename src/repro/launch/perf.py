import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

"""Perf hillclimbing driver (§Perf): compile named variants of one
(arch × shape) and report the roofline-term deltas.

    PYTHONPATH=src python -m repro.launch.perf --arch qwen2-0.5b \
        --shape train_4k --variants baseline,gather_head

Each variant is a set of LMConfig/ArchSpec overrides (the perf knobs).
Results append to benchmarks/results/perf.json for EXPERIMENTS.md §Perf.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
from typing import Dict  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.launch.dryrun import run_one  # noqa: E402
from repro.utils import get_logger  # noqa: E402

log = get_logger("repro.perf")

# named variants: LMConfig field overrides (+ ArchSpec-level 'microbatches')
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    "gather_head": {"gather_head": True},
    "block_q_512": {"block_q": 512},
    "block_q_1024": {"block_q": 1024},
    "remat_dots": {"remat_policy": "dots"},
    "gather_head+block_q_512": {"gather_head": True, "block_q": 512},
    "gather_head+remat_dots": {"gather_head": True, "remat_policy": "dots"},
    "all": {"gather_head": True, "block_q": 512, "remat_policy": "dots"},
    "mb_2": {"__microbatches": 2},
    "mb_1": {"__microbatches": 1},
    "gather_head+mb_2": {"gather_head": True, "__microbatches": 2},
    "cache_seq": {"shard_cache_seq": True},
    "cache_seq+gather_head": {"shard_cache_seq": True, "gather_head": True},
    "pad_heads": {"pad_heads": True},
    "pad_heads+block_q_512": {"pad_heads": True, "block_q": 512},
    "pad_heads+block_q_1024": {"pad_heads": True, "block_q": 1024},
}


def apply_variant(spec, overrides: Dict):
    arch_over = {k[2:]: v for k, v in overrides.items() if k.startswith("__")}
    lm_over = {k: v for k, v in overrides.items() if not k.startswith("__")}
    if lm_over:
        spec = dataclasses.replace(spec, lm=dataclasses.replace(spec.lm, **lm_over))
    if arch_over:
        spec = dataclasses.replace(spec, **arch_over)
    return spec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline,gather_head")
    ap.add_argument("--out", default="benchmarks/results/perf.json")
    args = ap.parse_args()

    results = []
    for name in args.variants.split(","):
        spec = apply_variant(get_arch(args.arch), VARIANTS[name])
        r = run_one(args.arch, args.shape, multi_pod=False, spec=spec)
        r["variant"] = name
        results.append(r)
        if r["status"] == "ok":
            log.info(
                "%-28s comp=%.3es mem=%.3es coll=%.3es dev_mem=%.2fGB dom=%s",
                name, r["compute_s"], r["memory_s"], r["collective_s"],
                r["memory"]["total_gb"], r["dominant"],
            )

    existing = []
    if os.path.exists(args.out):
        existing = json.load(open(args.out))
    existing.extend(results)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    json.dump(existing, open(args.out, "w"), indent=1)
    print(f"appended {len(results)} variants -> {args.out}")


if __name__ == "__main__":
    main()
