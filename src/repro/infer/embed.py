"""Full-graph batched inference: every node -> embedding, in fixed shapes.

The trainer only ever embeds the nodes of its sampled batches; recall
serving (§4.2) needs the embedding of *every* node. This module streams the
whole node id space through the same encoder the trainer uses:

- ids are swept in fixed-size chunks (the last chunk PAD-padded), so the
  jitted encoder compiles exactly once per call regardless of graph size;
- GNN models sample an inference-time ego graph per chunk through
  ``sample_ego_batch`` -> ``engine_sample_many``, which means any engine
  backend works unchanged — the in-process partitioned engine or the
  multi-process shared-memory ``GraphClient`` (one pipelined request round
  per hop). Both draw one seed per query from the caller RNG
  (graph/engine.py randomness contract), so the produced matrix is bitwise
  identical across backends under a fixed seed;
- results land in a preallocated (num_nodes, dim) float32 matrix that
  ``export_embeddings`` shards through ``train/checkpoint.py`` for hand-off
  to the retrieval layer (repro.retrieval) or an external server.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

from repro.core import model as model_lib
from repro.lint.sanitizer import host_array
from repro.sampling.ego import EgoConfig, sample_ego_batch
from repro.train import checkpoint

PAD = -1


def embed_all_nodes(
    params,
    cfg: "model_lib.Graph4RecConfig",
    engine,
    graph,
    batch_size: int = 1024,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Embed every node of ``graph`` -> (num_nodes, dim) float32.

    ``engine`` is anything ``engine_sample_many`` accepts (HeteroGraph,
    DistributedGraphEngine, or graph/service.GraphClient); walk-based
    models never touch it. ``rng`` overrides ``seed`` for callers that
    thread their own stream (the trainer's evaluate).
    """
    N = graph.num_nodes
    batch_size = int(batch_size)
    if batch_size <= 0:
        raise ValueError(
            f"batch_size must be positive, got {batch_size} (a non-positive "
            "chunk width would loop forever or silently embed nothing)"
        )
    batch_size = min(batch_size, N)
    rng = rng if rng is not None else np.random.default_rng(seed)
    bspecs, vspecs = model_lib._split_slot_specs(cfg)
    slot_counts = model_lib.slot_count_arrays(graph, cfg) if bspecs else None

    if cfg.is_walk_based:
        enc = jax.jit(
            lambda p, ids, slots: model_lib.encode_ids(p, cfg, ids, slots, slot_counts)
        )
    else:
        enc = jax.jit(
            lambda p, levels, slots: model_lib.encode_ego(
                p, cfg, levels, slots, slot_counts
            )
        )
        rels = list(cfg.relations) or graph.relation_names()[: cfg.gnn.num_relations]
        ego_cfg = EgoConfig(relations=rels, fanouts=list(cfg.fanouts))

    out: Optional[np.ndarray] = None
    for lo in range(0, N, batch_size):
        n_real = min(batch_size, N - lo)
        ids = np.full(batch_size, PAD, dtype=np.int64)
        ids[:n_real] = np.arange(lo, lo + n_real, dtype=np.int64)
        if cfg.is_walk_based:
            slots = None
            if vspecs:
                slots = {
                    k: jax.device_put(v)
                    for k, v in model_lib._slots_for_ids(graph, ids, vspecs).items()
                }
            h = enc(params, jax.device_put(ids), slots)
        else:
            ego = sample_ego_batch(rng, engine, ids, ego_cfg)
            levels, slots = model_lib._ego_arrays(graph, ego, cfg)
            h = enc(params, levels, slots)
        h = host_array(h, dtype=np.float32)
        if out is None:
            out = np.empty((N, h.shape[-1]), dtype=np.float32)
        out[lo : lo + n_real] = h[:n_real]
    return out


# ------------------------------------------------------------------- export
def export_embeddings(
    path: str,
    emb: np.ndarray,
    num_shards: int = 1,
    meta: Optional[Dict] = None,
) -> str:
    """Shard a (num_nodes, dim) matrix row-wise and save via checkpoint.

    Shards are contiguous row ranges (``np.array_split`` layout) — the
    natural unit for a multi-host serving fleet where each replica memory-
    maps its own rows. Returns the normalized checkpoint path.
    """
    emb = host_array(emb)
    num_shards = max(1, min(int(num_shards), emb.shape[0] or 1))
    tree = {
        "meta": {
            "num_nodes": np.int64(emb.shape[0]),
            "dim": np.int64(emb.shape[1]),
            "num_shards": np.int64(num_shards),
            **(meta or {}),
        },
        "shards": {
            f"{i:05d}": shard
            for i, shard in enumerate(np.array_split(emb, num_shards, axis=0))
        },
    }
    checkpoint.save(path, tree)
    return checkpoint.normalize_path(path)


def load_embeddings(path: str) -> np.ndarray:
    """Reassemble an ``export_embeddings`` checkpoint -> (num_nodes, dim)."""
    tree = checkpoint.load_dict(path)
    shards = tree["shards"]
    emb = np.concatenate([shards[k] for k in sorted(shards)], axis=0)
    meta = tree["meta"]
    if int(meta["num_nodes"]) != emb.shape[0] or int(meta["dim"]) != emb.shape[1]:
        raise ValueError(
            f"embedding checkpoint corrupt: meta says "
            f"({int(meta['num_nodes'])}, {int(meta['dim'])}), shards sum to "
            f"{emb.shape}"
        )
    return emb
