"""Full-graph inference: trained checkpoint -> embeddings for every node.

``embed_all_nodes`` sweeps the whole id space through the training encoder
in fixed-shape chunks (any graph-engine backend, bitwise-deterministic
under a fixed seed); ``export_embeddings``/``load_embeddings`` move the
resulting (num_nodes, dim) matrix through ``train/checkpoint.py`` as
row-range shards. The retrieval layer (repro.retrieval) serves recall from
these matrices; ``examples/eval_recsys.py`` drives the full path.
"""
from repro.infer.embed import (
    embed_all_nodes, export_embeddings, load_embeddings,
)
