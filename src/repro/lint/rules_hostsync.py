"""Host-sync / tracer-hygiene rules (H family).

The trainer's throughput design overlaps host sampling with device compute;
one implicit sync in the step loop serializes the whole pipeline (the
ROADMAP's 0.78x mp gap is exactly this class of bug). These rules apply only
to the hot-path modules (``core.HOT_PATH_GLOBS``) and only when the module
imports jax — the graph service workers are numpy-only processes and may
sync however they like.

- **H001** implicit device->host sync: ``float(x)`` / ``x.item()`` /
  ``np.asarray(x)`` / ``block_until_ready`` force the device to drain.
  Deliberate syncs go through the audited helpers in
  ``repro.lint.sanitizer`` (``host_scalar`` / ``host_floats`` /
  ``device_barrier``), built on explicit ``jax.device_get``.
- **H002** implicit host->device transfer: ``jnp.asarray`` / ``jnp.array``
  on host data is an H2D copy that ``jax.transfer_guard("disallow")`` (the
  runtime sanitizer) treats as *explicit* and therefore cannot catch, and
  that a producer thread hides from profiles. ``jax.device_put`` is the
  one legal spelling in hot-path modules.
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.core import Finding, LintModule, Rule, call_name

_SYNC_CALLS = {"np.asarray", "numpy.asarray", "np.array", "numpy.array"}


def _applies(module: LintModule) -> bool:
    return module.is_hot_path and module.imports("jax")


def _check_h001(module: LintModule) -> List[Finding]:
    if not _applies(module):
        return []
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name == "float" and node.args and not isinstance(node.args[0], ast.Constant):
            out.append(
                module.finding(
                    H001, node,
                    "float() on a (possibly device) value blocks until the "
                    "device queue drains",
                    "use repro.lint.sanitizer.host_scalar(x) for a deliberate "
                    "sync (explicit jax.device_get)",
                )
            )
        elif name in _SYNC_CALLS:
            out.append(
                module.finding(
                    H001, node,
                    f"{name}() on a device value is an implicit D2H copy",
                    "jax.device_get(x) is the explicit spelling (or move the "
                    "conversion out of the hot path)",
                )
            )
        elif name.endswith("block_until_ready"):
            out.append(
                module.finding(
                    H001, node,
                    "block_until_ready stalls the dispatch pipeline",
                    "use repro.lint.sanitizer.device_barrier(x) at the one "
                    "audited drain point, not in the hot path",
                )
            )
        elif isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            out.append(
                module.finding(
                    H001, node,
                    ".item() forces a device sync per element",
                    "use repro.lint.sanitizer.host_scalar / host_floats",
                )
            )
    return out


def _check_h002(module: LintModule) -> List[Finding]:
    if not _applies(module):
        return []
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name in ("jnp.asarray", "jnp.array", "jax.numpy.asarray", "jax.numpy.array"):
            out.append(
                module.finding(
                    H002, node,
                    f"{name}() is an implicit H2D transfer that "
                    "jax.transfer_guard('disallow') cannot see",
                )
            )
    return out


H001 = Rule(
    "H001", "implicit-host-sync", "hostsync",
    "implicit device->host sync in a hot-path module",
    "route deliberate syncs through repro.lint.sanitizer "
    "(host_scalar/host_floats/device_barrier) or explicit jax.device_get",
    _check_h001,
)
H002 = Rule(
    "H002", "implicit-h2d-transfer", "hostsync",
    "implicit jnp.asarray host->device transfer in a hot-path module",
    "jax.device_put(x) — explicit, profiled, and the only spelling the "
    "transfer-guard sanitizer certifies",
    _check_h002,
)

RULES = (H001, H002)
