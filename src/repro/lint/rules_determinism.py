"""Determinism rules (D family).

The per-seed determinism contract (docs/sampling.md): every stochastic
component is keyed by an explicit caller seed, derived the way
``graph.engine.partition_rng`` does — ``np.random.default_rng([seed, ...])``
— so the same TrainerConfig.seed reproduces a run bitwise across engine
backends and process layouts. These rules flag the ways that contract
silently rots: entropy-seeded or id-seeded generators, legacy global-state
numpy RNG, constant PRNGKeys in library code, and JAX key reuse.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.lint.core import (
    Finding,
    LintModule,
    Rule,
    attr_source,
    call_name,
    keyword_arg,
)

# substrings that mark an identifier as carrying caller-derived randomness
_SEEDY = ("seed", "rng", "key", "entropy")

# numpy legacy global-state API (np.random.<fn> without a Generator)
_NP_GLOBAL = {
    "seed", "rand", "randn", "randint", "random", "choice", "shuffle",
    "permutation", "uniform", "normal", "standard_normal", "binomial",
    "poisson", "beta", "gamma", "exponential", "bytes", "sample", "ranf",
    "random_sample", "get_state", "set_state",
}

# jax.random functions that do NOT consume their key argument (fold_in and
# friends derive; PRNGKey/key construct). Everything else, split included,
# consumes it.
_KEY_NONCONSUMING = {"fold_in", "PRNGKey", "key", "key_data", "wrap_key_data", "clone"}


def _is_default_rng(node: ast.Call) -> bool:
    name = call_name(node)
    return name == "default_rng" or name.endswith(".default_rng")


def _seed_like(identifier: str) -> bool:
    low = identifier.lower()
    return any(t in low for t in _SEEDY)


def _derives_seed(node: ast.expr) -> bool:
    """True when the expression visibly carries a caller seed: a constant, a
    seed-named variable/attribute, or any compound expression with such a
    leaf (``self.cfg.seed + 7``, ``[int(seed), int(part)]``'s head, ...)."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return _seed_like(node.id)
    if isinstance(node, ast.Attribute):
        return _seed_like(node.attr)
    if isinstance(node, ast.Call):
        return any(_derives_seed(a) for a in node.args) or any(
            kw.value is not None and _derives_seed(kw.value) for kw in node.keywords
        )
    if isinstance(node, ast.BinOp):
        return _derives_seed(node.left) or _derives_seed(node.right)
    if isinstance(node, ast.UnaryOp):
        return _derives_seed(node.operand)
    if isinstance(node, ast.Subscript):
        return _derives_seed(node.value)
    return False


def _check_d001(module: LintModule) -> List[Finding]:
    out = []
    for node in ast.walk(module.tree):
        if (
            isinstance(node, ast.Call)
            and _is_default_rng(node)
            and not node.args
            and not node.keywords
        ):
            out.append(
                module.finding(
                    D001, node,
                    "np.random.default_rng() with no seed draws OS entropy — "
                    "every run differs",
                )
            )
    return out


def _check_d002(module: LintModule) -> List[Finding]:
    if module.is_test:  # test seeds come from fixed parametrize values
        return []
    out = []
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Call) and _is_default_rng(node) and node.args):
            continue
        arg = node.args[0]
        if isinstance(arg, (ast.List, ast.Tuple)):
            # the [seed, ...] spawn-key idiom: the head must carry the seed
            ok = bool(arg.elts) and _derives_seed(arg.elts[0])
        else:
            ok = _derives_seed(arg)
        if not ok:
            out.append(
                module.finding(
                    D002, node,
                    "default_rng seed is not derived from a caller seed "
                    "(no seed-carrying term in the expression)",
                )
            )
    return out


def _check_d003(module: LintModule) -> List[Finding]:
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name.startswith("np.random.") or name.startswith("numpy.random."):
            fn = name.rsplit(".", 1)[1]
            if fn in _NP_GLOBAL:
                out.append(
                    module.finding(
                        D003, node,
                        f"legacy global-state RNG np.random.{fn}() — shared "
                        "mutable state across every caller and thread",
                    )
                )
    return out


def _in_eval_shape(module: LintModule, node: ast.AST) -> bool:
    for anc in module.ancestors(node):
        if isinstance(anc, ast.Call) and call_name(anc).endswith("eval_shape"):
            return True
    return False


def _check_d004(module: LintModule) -> List[Finding]:
    if module.is_test:
        return []
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if not (name.endswith("random.PRNGKey") or name == "PRNGKey"):
            continue
        if node.args and isinstance(node.args[0], ast.Constant):
            # shape-only tracing never consumes the key's value
            if _in_eval_shape(module, node):
                continue
            out.append(
                module.finding(
                    D004, node,
                    f"constant PRNGKey({node.args[0].value!r}) in library code "
                    "pins the run to one stream regardless of caller seed",
                )
            )
    return out


# --------------------------------------------------------------- D005: reuse
def _terminates(body: List[ast.stmt]) -> bool:
    """True when a branch body cannot fall through to the next statement."""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break)
    )



def _key_consumer(node: ast.Call) -> Optional[str]:
    """Name of the bare key variable this jax.random call consumes, if any."""
    name = call_name(node)
    if not (name.startswith("jax.random.") or name.startswith("random.")):
        return None
    fn = name.rsplit(".", 1)[1]
    if fn in _KEY_NONCONSUMING:
        return None
    kw = keyword_arg(node, "key")
    first = node.args[0] if node.args else kw
    if isinstance(first, ast.Name):
        return first.id
    return None


class _KeyScope:
    """Statement-ordered traversal tracking which key names are consumed.

    Branch-aware: if/else arms see a copy of the state and merge by union
    (a key consumed in either arm counts as consumed after the if). Loop
    bodies are scanned twice so a key consumed on iteration 1 and reused on
    iteration 2 is caught, while loop-carried ``key, sub = split(key)``
    reassignment stays clean.
    """

    def __init__(self, module: LintModule):
        self.module = module
        self.findings: List[Finding] = []

    def run(self, body: List[ast.stmt]) -> None:
        self._exec_body(body, {}, report=True)

    # state: name -> lineno of the consuming call
    def _exec_body(self, body, state: Dict[str, int], report: bool) -> None:
        for stmt in body:
            self._exec_stmt(stmt, state, report)

    def _exec_stmt(self, stmt: ast.stmt, state: Dict[str, int], report: bool) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # nested scopes are walked separately
        if isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, state, report)
            s_body, s_else = dict(state), dict(state)
            self._exec_body(stmt.body, s_body, report)
            self._exec_body(stmt.orelse, s_else, report)
            # merge by union, excluding arms that never fall through (an
            # early-returning branch cannot leak its consumption forward)
            state.clear()
            if not _terminates(stmt.orelse):
                state.update(s_else)
            if not _terminates(stmt.body):
                state.update(s_body)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter, state, report)
            first = dict(state)
            self._reset_target(stmt.target, first)  # rebound every iteration
            self._exec_body(stmt.body, first, report)
            second = dict(first)
            self._reset_target(stmt.target, second)
            self._exec_body(stmt.body, second, report)
            state.clear()
            state.update(second)
            self._exec_body(stmt.orelse, state, report)
            return
        if isinstance(stmt, ast.While):
            self._scan_expr(stmt.test, state, report)
            first = dict(state)
            self._exec_body(stmt.body, first, report)
            second = dict(first)
            self._exec_body(stmt.body, second, report)
            state.clear()
            state.update(second)
            self._exec_body(stmt.orelse, state, report)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_expr(item.context_expr, state, report)
            self._exec_body(stmt.body, state, report)
            return
        if isinstance(stmt, ast.Try):
            self._exec_body(stmt.body, state, report)
            for h in stmt.handlers:
                self._exec_body(h.body, dict(state), report)
            self._exec_body(stmt.orelse, state, report)
            self._exec_body(stmt.finalbody, state, report)
            return
        if isinstance(stmt, ast.Assign):
            self._scan_expr(stmt.value, state, report)
            for tgt in stmt.targets:
                self._reset_target(tgt, state)
            return
        if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None:
                self._scan_expr(stmt.value, state, report)
            self._reset_target(stmt.target, state)
            return
        # any other statement: scan embedded expressions in source order
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._scan_expr(child, state, report)
            elif isinstance(child, ast.stmt):
                self._exec_stmt(child, state, report)

    def _reset_target(self, tgt: ast.AST, state: Dict[str, int]) -> None:
        for node in ast.walk(tgt):
            if isinstance(node, ast.Name):
                state.pop(node.id, None)

    def _scan_expr(self, expr: ast.expr, state: Dict[str, int], report: bool) -> None:
        for node in ast.walk(expr):
            if isinstance(node, (ast.Lambda,)):
                continue  # deferred body: not executed here
            if not isinstance(node, ast.Call):
                continue
            name = _key_consumer(node)
            if name is None:
                continue
            if name in state:
                if report:
                    self.findings.append(
                        self.module.finding(
                            D005, node,
                            f"PRNG key '{name}' already consumed by a "
                            f"jax.random call at line {state[name]} — reusing "
                            "it replays the same randomness",
                        )
                    )
            else:
                state[name] = node.lineno


def _check_d005(module: LintModule) -> List[Finding]:
    if not module.imports("jax"):
        return []
    scope = _KeyScope(module)
    # module body (skipping defs), then each function body independently
    scope.run(module.tree.body)
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scope.run(node.body)
    # deduplicate: a nested function is reachable from both walks
    seen: Set[tuple] = set()
    out = []
    for f in scope.findings:
        k = (f.line, f.col)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


D001 = Rule(
    "D001", "rng-entropy-seed", "determinism",
    "np.random.default_rng() without a seed argument",
    "pass an explicit seed: default_rng(seed) or default_rng([seed, part])",
    _check_d001,
)
D002 = Rule(
    "D002", "rng-underived-seed", "determinism",
    "default_rng seeded by something that does not carry a caller seed",
    "derive the seed like graph.engine.partition_rng: "
    "np.random.default_rng([seed, local_id])",
    _check_d002,
)
D003 = Rule(
    "D003", "np-global-random", "determinism",
    "legacy np.random.* global-state use",
    "create a Generator: rng = np.random.default_rng(seed); rng.<fn>(...)",
    _check_d003,
)
D004 = Rule(
    "D004", "constant-prngkey", "determinism",
    "constant jax.random.PRNGKey(...) outside tests",
    "thread a seed parameter: jax.random.PRNGKey(cfg.seed)",
    _check_d004,
)
D005 = Rule(
    "D005", "prng-key-reuse", "determinism",
    "same JAX key consumed by two jax.random calls without a split",
    "split first: k1, k2 = jax.random.split(key), or derive via fold_in",
    _check_d005,
)

RULES = (D001, D002, D003, D004, D005)
