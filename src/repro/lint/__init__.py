"""reprolint: AST static analysis enforcing this repo's three load-bearing
contracts — per-seed determinism, hot-path host-sync hygiene, and the Pallas
kernel conventions — plus thread/process lifecycle checks. See docs/lint.md.

Import surface: the static pass (core + rule modules) is stdlib-only so CI
can run ``make lint`` without jax installed; the runtime transfer sanitizer
lives in ``repro.lint.sanitizer`` (imports jax) and is loaded only by its
users (train/trainer.py, benchmarks, tests).
"""
from repro.lint.core import (  # noqa: F401
    BASELINE_FILE,
    Finding,
    LintModule,
    Rule,
    all_rules,
    load_baseline,
    new_findings,
    run_lint,
    write_baseline,
)
