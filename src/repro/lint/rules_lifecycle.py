"""Thread/process lifecycle rules (L family).

The trainer's prefetch thread and the graph service's worker fleet are
long-lived background actors; the failure mode is never a crash but a
silent leak — an unjoined producer sampling into a dead queue, a lock held
across an exception, an shm segment outliving the run. These rules pin the
conventions graph/service and train/trainer established:

- **L001** every ``threading.Thread`` / ``Process`` spawn carries a
  ``name=`` (leak warnings and ``py-spy`` dumps are useless without one).
- **L002** a timed ``join(timeout=...)`` is always followed by handling for
  the not-dead case — ``is_alive()`` (warn/escalate) or ``terminate()`` /
  ``kill()`` — in the same function. A bare timed join that falls through
  silently leaks a live thread into the caller (exactly the prefetcher bug
  this PR fixes at train/trainer.py).
- **L003** ``threading.Lock``/``RLock``/``Condition`` objects are acquired
  only via ``with`` — manual acquire/release pairs leak the lock on any
  exception between them.
- **L004** a module that creates ``SharedMemory(create=True)`` segments
  registers a ``weakref.finalize`` unlink backstop, so segments cannot
  outlive the interpreter when explicit shutdown is skipped.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.lint.core import (
    Finding,
    LintModule,
    Rule,
    attr_source,
    call_name,
    expr_source,
    keyword_arg,
)

_SPAWN_CALLS = ("threading.Thread", "Thread", "Process")
_LOCK_FACTORIES = {
    "threading.Lock", "threading.RLock", "threading.Condition",
    "Lock", "RLock", "Condition",
}


def _check_l001(module: LintModule) -> List[Finding]:
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if not (name in _SPAWN_CALLS or name.endswith(".Thread") or name.endswith(".Process")):
            continue
        if keyword_arg(node, "name") is None:
            out.append(
                module.finding(
                    L001, node,
                    f"{name}(...) spawned without name= — aliveness warnings "
                    "and stack dumps cannot identify it",
                )
            )
    return out


def _check_l002(module: LintModule) -> List[Finding]:
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        if not (isinstance(node.func, ast.Attribute) and node.func.attr == "join"):
            continue
        if keyword_arg(node, "timeout") is None:
            continue  # untimed join blocks until death — nothing to leak
        receiver = expr_source(module, node.func.value)
        scope: Optional[ast.AST] = module.enclosing_function(node) or module.tree
        handled = False
        for other in ast.walk(scope):
            if not (
                isinstance(other, ast.Attribute)
                and other.attr in ("is_alive", "terminate", "kill")
                and expr_source(module, other.value) == receiver
            ):
                continue
            if other.lineno >= node.lineno:
                handled = True
                break
        if not handled:
            out.append(
                module.finding(
                    L002, node,
                    f"timed join on '{receiver}' with no aliveness handling "
                    "afterwards — a thread outliving the timeout leaks "
                    "silently into the caller",
                )
            )
    return out


def _lock_names(module: LintModule) -> Set[str]:
    """Terminal names (attr or variable) assigned from a Lock factory."""
    names: Set[str] = set()
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Call) and call_name(node.value) in _LOCK_FACTORIES):
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                names.add(tgt.id)
            elif isinstance(tgt, ast.Attribute):
                names.add(tgt.attr)
    return names


def _check_l003(module: LintModule) -> List[Finding]:
    locks = _lock_names(module)
    if not locks:
        return []
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if not (isinstance(func, ast.Attribute) and func.attr in ("acquire", "release")):
            continue
        base = func.value
        terminal = base.attr if isinstance(base, ast.Attribute) else (
            base.id if isinstance(base, ast.Name) else ""
        )
        if terminal in locks:
            out.append(
                module.finding(
                    L003, node,
                    f"manual .{func.attr}() on lock "
                    f"'{expr_source(module, base)}' — an exception between "
                    "acquire and release leaks the lock",
                )
            )
    return out


def _check_l004(module: LintModule) -> List[Finding]:
    creates = []
    has_finalize = False
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name.endswith("SharedMemory"):
            create = keyword_arg(node, "create")
            if isinstance(create, ast.Constant) and create.value is True:
                creates.append(node)
        elif name.endswith("finalize") and "weakref" in name or name == "finalize":
            has_finalize = True
    if has_finalize:
        return []
    return [
        module.finding(
            L004, node,
            "SharedMemory(create=True) without a weakref.finalize unlink "
            "backstop in this module — a skipped shutdown leaks the segment "
            "past interpreter exit",
        )
        for node in creates
    ]


L001 = Rule(
    "L001", "unnamed-thread", "lifecycle",
    "Thread/Process spawned without a name",
    "pass name='repro-<role>' so leak warnings identify the actor",
    _check_l001,
)
L002 = Rule(
    "L002", "join-no-aliveness", "lifecycle",
    "timed join without aliveness handling on the same receiver",
    "after join(timeout=...), check is_alive() and warn (threads) or "
    "terminate()/kill() (processes)",
    _check_l002,
)
L003 = Rule(
    "L003", "lock-not-with", "lifecycle",
    "manual acquire/release on a threading lock",
    "acquire via 'with lock:' so every exit path releases",
    _check_l003,
)
L004 = Rule(
    "L004", "shm-no-finalizer", "lifecycle",
    "shm segment created without a finalizer unlink backstop",
    "register weakref.finalize(seg, <unlink-by-name>, seg.name) at creation",
    _check_l004,
)

RULES = (L001, L002, L003, L004)
