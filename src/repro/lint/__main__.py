"""``python -m repro.lint`` — run the static pass and gate on the baseline.

Exit status 0 when every finding is covered by ``lint_baseline.json`` (the
committed baseline is empty — the repo lints clean); 1 when new findings
appear. ``--write-baseline`` regenerates the baseline from the current
findings (for adopting the linter on a codebase with known debt — fix hot
-path findings instead of baselining them; CI enforces that the hot-path
modules stay finding-free).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.lint.core import (
    BASELINE_FILE,
    all_rules,
    load_baseline,
    new_findings,
    run_lint,
    write_baseline,
)


def _find_root(start: Path) -> Path:
    """Repo root = nearest ancestor holding src/repro (falls back to cwd)."""
    for cand in (start, *start.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return start


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.lint")
    ap.add_argument(
        "paths", nargs="*", default=["src", "tests"],
        help="files/directories to lint, relative to --root (default: src tests)",
    )
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detect from cwd)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_FILE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="fail on every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings as the new baseline and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id} [{r.name}] ({r.family}): {r.description}")
        return 0

    root = (args.root or _find_root(Path.cwd())).resolve()
    baseline_path = args.baseline or (root / BASELINE_FILE)
    findings = run_lint(root, args.paths, rules)

    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = {} if args.no_baseline else load_baseline(baseline_path)
    fresh = new_findings(findings, baseline)
    for f in fresh:
        print(f.render())
    known = len(findings) - len(fresh)
    print(
        f"repro.lint: {len(findings)} finding(s), {len(fresh)} new"
        + (f" ({known} baselined)" if known else "")
    )
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
