"""reprolint core: file walking, suppressions, findings, baseline.

The three contracts PR 3/5 established — bitwise per-seed determinism across
engine backends, no implicit host sync in the hot path, and the Pallas
aliasing/reference invariants — exist only as convention; this package turns
them into machine-checked rules (see docs/lint.md for the catalogue).

Design constraints:

- **stdlib only.** The analyzer imports ``ast``/``tokenize``/``json`` and
  nothing else, so ``make lint`` runs in CI without jax or numpy installed
  (the runtime sanitizer in ``lint/sanitizer.py`` is the one jax-importing
  module and is never imported by the static pass).
- **suppressions are inline and rule-scoped**: ``# repro: lint-ignore[RULE]``
  (comma-separated ids, or ``*``) on the offending line, or alone on the
  line directly above it.
- **baseline**: findings are fingerprinted (rule, path, enclosing def,
  stripped source line) — line-number free, so unrelated edits don't churn
  it. ``python -m repro.lint --write-baseline`` regenerates
  ``lint_baseline.json``; the run fails only on findings NOT in the
  baseline. The committed baseline is empty: every violation the pass
  surfaced in this repo was fixed, not recorded.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Set, Tuple

# Modules where an implicit host sync or H2D transfer is a performance bug,
# not a style nit (the prefetch/step overlap the ROADMAP's end-to-end item
# depends on). Paths are repo-relative posix globs.
HOT_PATH_GLOBS = (
    "src/repro/train/trainer.py",
    "src/repro/sampling/fused.py",
    "src/repro/graph/service/*.py",
    # the serving path: per-call host<->device traffic here is exactly the
    # "IVF loses to brute force" class of bug (BENCH_recall, ROADMAP item 3)
    "src/repro/retrieval/*.py",
    "src/repro/infer/*.py",
)
KERNEL_GLOB = "src/repro/kernels/*.py"
TEST_GLOB = "tests/*.py"

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*lint-ignore\[([A-Za-z0-9*,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str  # e.g. "D002"
    name: str  # short rule slug, e.g. "rng-underived-seed"
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    hint: str  # how to fix it
    context: str  # enclosing class/def chain, "<module>" at top level
    snippet: str  # stripped source line (fingerprint component)

    def fingerprint(self) -> Tuple[str, str, str, str]:
        """Line-number-free identity used for baseline matching."""
        return (self.rule, self.path, self.context, self.snippet)

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: {self.rule} [{self.name}] "
            f"{self.message}\n    hint: {self.hint}"
        )


class LintModule:
    """One parsed source file plus the per-file context every rule needs."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel  # repo-relative posix path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=rel)
        self.suppressions = _parse_suppressions(self.lines)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent

    # ------------------------------------------------------------ classifiers
    @property
    def is_test(self) -> bool:
        return fnmatch.fnmatch(self.rel, TEST_GLOB)

    @property
    def is_hot_path(self) -> bool:
        return any(fnmatch.fnmatch(self.rel, g) for g in HOT_PATH_GLOBS)

    @property
    def is_kernel(self) -> bool:
        return fnmatch.fnmatch(self.rel, KERNEL_GLOB)

    def imports(self, mod: str) -> bool:
        """True if the module imports ``mod`` (or a submodule of it)."""
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                if any(a.name == mod or a.name.startswith(mod + ".") for a in node.names):
                    return True
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module == mod or node.module.startswith(mod + "."):
                    return True
        return False

    # --------------------------------------------------------------- helpers
    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            yield cur
            cur = self._parents.get(cur)

    def context_of(self, node: ast.AST) -> str:
        chain = [
            a.name
            for a in self.ancestors(node)
            if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef))
        ]
        return ".".join(reversed(chain)) or "<module>"

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        for a in self.ancestors(node):
            if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return a
        return None

    def snippet_at(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(
        self, rule: "Rule", node: ast.AST, message: str, hint: Optional[str] = None
    ) -> Finding:
        return Finding(
            rule=rule.id,
            name=rule.name,
            path=self.rel,
            line=node.lineno,
            col=node.col_offset,
            message=message,
            hint=hint if hint is not None else rule.hint,
            context=self.context_of(node),
            snippet=self.snippet_at(node.lineno),
        )


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str  # "D002"
    name: str  # "rng-underived-seed"
    family: str  # "determinism" | "hostsync" | "pallas" | "lifecycle"
    description: str
    hint: str
    check: Callable[[LintModule], List[Finding]]


# ------------------------------------------------------------- suppressions
def _parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """line number -> suppressed rule ids ("*" = all).

    A ``# repro: lint-ignore[...]`` comment suppresses its own line; when the
    line holds nothing but the comment, it suppresses the next line instead
    (for statements too long to carry a trailing comment).
    """
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        ids = {t.strip() for t in m.group(1).split(",") if t.strip()}
        target = i + 1 if text.strip().startswith("#") else i
        out.setdefault(target, set()).update(ids)
    return out


def is_suppressed(module: LintModule, finding: Finding) -> bool:
    ids = module.suppressions.get(finding.line, ())
    return "*" in ids or finding.rule in ids


# ------------------------------------------------------------------ AST utils
def attr_source(node: ast.AST) -> str:
    """Dotted source of a Name/Attribute chain ('' for anything else)."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return ""


def call_name(node: ast.Call) -> str:
    """Dotted name of a call's callee ('' when not a plain name chain)."""
    return attr_source(node.func)


def expr_source(module: LintModule, node: ast.AST) -> str:
    try:
        return ast.get_source_segment(module.source, node) or ast.dump(node)
    except Exception:  # pragma: no cover - defensive
        return ast.dump(node)


def keyword_arg(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


# ------------------------------------------------------------------- runner
def iter_py_files(root: Path, paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        base = (root / p) if not Path(p).is_absolute() else Path(p)
        if base.is_file() and base.suffix == ".py":
            yield base
        elif base.is_dir():
            for f in sorted(base.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f


def run_lint(
    root: Path,
    paths: Sequence[str] = ("src", "tests"),
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    """Lint every .py file under ``paths`` (repo-relative); returns findings
    sorted by (path, line), with inline suppressions already filtered."""
    if rules is None:
        rules = all_rules()
    root = Path(root).resolve()
    findings: List[Finding] = []
    for f in iter_py_files(root, paths):
        try:
            rel = f.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            module = LintModule(f, rel, f.read_text())
        except (SyntaxError, UnicodeDecodeError) as e:
            raise RuntimeError(f"lint: cannot parse {rel}: {e}") from e
        for rule in rules:
            for finding in rule.check(module):
                if not is_suppressed(module, finding):
                    findings.append(finding)
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings


def all_rules() -> List[Rule]:
    from repro.lint import (
        rules_determinism,
        rules_hostsync,
        rules_lifecycle,
        rules_obs,
        rules_pallas,
    )

    return (
        list(rules_determinism.RULES)
        + list(rules_hostsync.RULES)
        + list(rules_pallas.RULES)
        + list(rules_lifecycle.RULES)
        + list(rules_obs.RULES)
    )


# ------------------------------------------------------------------ baseline
BASELINE_FILE = "lint_baseline.json"


def load_baseline(path: Path) -> Dict[Tuple[str, str, str, str], int]:
    """Baseline as a fingerprint multiset (fingerprint -> count)."""
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    out: Dict[Tuple[str, str, str, str], int] = {}
    for item in data.get("findings", []):
        fp = (item["rule"], item["path"], item["context"], item["snippet"])
        out[fp] = out.get(fp, 0) + 1
    return out


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    data = {
        "version": 1,
        "findings": [
            {
                "rule": f.rule,
                "path": f.path,
                "context": f.context,
                "snippet": f.snippet,
            }
            for f in findings
        ],
    }
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


def new_findings(
    findings: Sequence[Finding],
    baseline: Dict[Tuple[str, str, str, str], int],
) -> List[Finding]:
    """Findings beyond the baseline's per-fingerprint counts."""
    remaining = dict(baseline)
    out: List[Finding] = []
    for f in findings:
        fp = f.fingerprint()
        if remaining.get(fp, 0) > 0:
            remaining[fp] -= 1
        else:
            out.append(f)
    return out
