"""Pallas kernel rules (P family), over ``src/repro/kernels/*.py``.

Every kernel in this repo follows three conventions the fused training path
depends on:

- **P001** every ``//`` in a ``pl.pallas_call`` grid is exact: the dividend
  is either padded to a tile multiple first (the ``Bp = -(-B // tb) * tb``
  ceil-pad idiom) or guarded by an ``assert X % tile == 0``. A silently
  floor-divided grid drops the ragged tail of the input.
- **P002** ``input_output_aliases`` indices are consistent: operand indices
  count scalar-prefetch args (``PrefetchScalarGridSpec.num_scalar_prefetch``
  offsets them), stay within the call's operand arity, map to declared
  ``out_shape`` entries, and each aliased output's dtype is tied to its
  input operand (``table.dtype``) — the shape/dtype agreement buffer
  donation requires (callers jit these wrappers with ``donate_argnums`` on
  the aliased operands).
- **P003** every public ``*_pallas`` wrapper has a pure-jnp oracle
  ``*_ref`` in ``kernels/ref.py`` — the correctness contract the
  cross-backend tests sweep.
- **P004** ``pl.pallas_call`` appears only under ``kernels/`` (keeps the
  grid/alias/ref conventions auditable in one place).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.lint.core import (
    Finding,
    LintModule,
    Rule,
    call_name,
    expr_source,
    keyword_arg,
)

_REF_CACHE: Dict[Path, Set[str]] = {}


def _is_pallas_call(node: ast.Call) -> bool:
    name = call_name(node)
    return name == "pallas_call" or name.endswith(".pallas_call")


def _grid_expr(module: LintModule, node: ast.Call) -> Optional[ast.expr]:
    """The grid tuple of a pallas_call: ``grid=`` directly, or ``grid=``
    inside a ``grid_spec=SomeGridSpec(...)`` call."""
    grid = keyword_arg(node, "grid")
    if grid is not None:
        return grid
    spec = keyword_arg(node, "grid_spec")
    if isinstance(spec, ast.Call):
        return keyword_arg(spec, "grid")
    return None


def _resolve_name(func: Optional[ast.AST], name: str) -> Optional[ast.expr]:
    """Last assignment to ``name`` in the enclosing function body."""
    if func is None:
        return None
    found = None
    for stmt in ast.walk(func):
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name) and tgt.id == name:
                    found = stmt.value
    return found


def _is_padded_assign(value: ast.expr) -> bool:
    """Matches the ceil-pad idiom: any expression computing a tile multiple
    (contains a FloorDiv later multiplied, e.g. ``-(-B // tb) * tb``)."""
    has_floordiv = any(
        isinstance(n, ast.BinOp) and isinstance(n.op, ast.FloorDiv)
        for n in ast.walk(value)
    )
    has_mult = any(
        isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mult)
        for n in ast.walk(value)
    )
    return has_floordiv and has_mult


def _has_divisibility_assert(func: Optional[ast.AST], dividend_src: str, module) -> bool:
    if func is None:
        return False
    for stmt in ast.walk(func):
        if not isinstance(stmt, ast.Assert):
            continue
        for n in ast.walk(stmt.test):
            if (
                isinstance(n, ast.BinOp)
                and isinstance(n.op, ast.Mod)
                and expr_source(module, n.left) == dividend_src
            ):
                return True
    return False


def _check_p001(module: LintModule) -> List[Finding]:
    if not module.is_kernel:
        return []
    out = []
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(node)):
            continue
        func = module.enclosing_function(node)
        grid = _grid_expr(module, node)
        if isinstance(grid, ast.Name):
            grid = _resolve_name(func, grid.id)
        if not isinstance(grid, (ast.Tuple, ast.List)):
            continue
        for elt in grid.elts:
            if not (isinstance(elt, ast.BinOp) and isinstance(elt.op, ast.FloorDiv)):
                continue
            dividend = elt.left
            src = expr_source(module, dividend)
            if _has_divisibility_assert(func, src, module):
                continue
            if isinstance(dividend, ast.Name):
                assigned = _resolve_name(func, dividend.id)
                if assigned is not None and _is_padded_assign(assigned):
                    continue
            out.append(
                module.finding(
                    P001, elt,
                    f"grid dimension '{expr_source(module, elt)}' floor-divides "
                    f"'{src}' without a pad-to-multiple or divisibility assert "
                    "— a ragged tail would be silently dropped",
                )
            )
    return out


def _const_int(node: Optional[ast.expr]) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _check_p002(module: LintModule) -> List[Finding]:
    if not module.is_kernel:
        return []
    out = []
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(node)):
            continue
        aliases = keyword_arg(node, "input_output_aliases")
        if not isinstance(aliases, ast.Dict):
            continue
        pairs: List[Tuple[int, int]] = []
        for k, v in zip(aliases.keys, aliases.values):
            ki, vi = _const_int(k), _const_int(v)
            if ki is not None and vi is not None:
                pairs.append((ki, vi))
        # scalar-prefetch offset: operand indices include prefetch args
        n_prefetch = 0
        spec = keyword_arg(node, "grid_spec")
        if isinstance(spec, ast.Call):
            n_prefetch = _const_int(keyword_arg(spec, "num_scalar_prefetch")) or 0
        # the outer invocation pallas_call(...)(operands) carries the arity
        parent = module.parent(node)
        n_operands = None
        operand_exprs: List[ast.expr] = []
        if isinstance(parent, ast.Call) and parent.func is node:
            if not any(isinstance(a, ast.Starred) for a in parent.args):
                n_operands = len(parent.args)
                operand_exprs = list(parent.args)
        out_shape = keyword_arg(node, "out_shape")
        out_shapes = (
            out_shape.elts if isinstance(out_shape, (ast.List, ast.Tuple)) else None
        )
        for ki, vi in pairs:
            if ki < n_prefetch:
                out.append(
                    module.finding(
                        P002, aliases,
                        f"alias input {ki} is a scalar-prefetch operand "
                        f"(num_scalar_prefetch={n_prefetch}); aliasing it "
                        "corrupts the prefetched scalars",
                    )
                )
                continue
            if n_operands is not None and ki >= n_operands:
                out.append(
                    module.finding(
                        P002, aliases,
                        f"alias input {ki} out of range: the call passes only "
                        f"{n_operands} operands",
                    )
                )
                continue
            if out_shapes is not None:
                if vi >= len(out_shapes):
                    out.append(
                        module.finding(
                            P002, aliases,
                            f"alias output {vi} out of range: out_shape "
                            f"declares {len(out_shapes)} results",
                        )
                    )
                    continue
                # donated-buffer dtype agreement: the aliased out_shape must
                # reference its input operand (e.g. table.dtype)
                if operand_exprs:
                    op_src = expr_source(module, operand_exprs[ki])
                    shape_src = expr_source(module, out_shapes[vi])
                    if (
                        isinstance(operand_exprs[ki], ast.Name)
                        and op_src not in shape_src
                    ):
                        out.append(
                            module.finding(
                                P002, out_shapes[vi],
                                f"aliased output {vi} does not tie its dtype/"
                                f"shape to operand '{op_src}' (alias {ki}->"
                                f"{vi} requires matching buffers for "
                                "donation)",
                            )
                        )
    return out


def _ref_names(module: LintModule) -> Set[str]:
    """Top-level ``*_ref`` names defined in this module's sibling ref.py."""
    ref_path = module.path.parent / "ref.py"
    key = ref_path.resolve()
    if key in _REF_CACHE:
        return _REF_CACHE[key]
    names: Set[str] = set()
    if ref_path.exists():
        tree = ast.parse(ref_path.read_text())
        for stmt in tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                names.add(stmt.name)
            elif isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
    _REF_CACHE[key] = names
    return names


def _check_p003(module: LintModule) -> List[Finding]:
    if not module.is_kernel or module.path.name in ("ref.py", "ops.py"):
        return []
    out = []
    refs = _ref_names(module)
    for stmt in module.tree.body:
        if not isinstance(stmt, ast.FunctionDef):
            continue
        if not stmt.name.endswith("_pallas") or stmt.name.startswith("_"):
            continue
        want = stmt.name[: -len("_pallas")] + "_ref"
        if want not in refs:
            out.append(
                module.finding(
                    P003, stmt,
                    f"kernel wrapper '{stmt.name}' has no '{want}' oracle in "
                    "kernels/ref.py — the correctness contract is untestable",
                )
            )
    return out


def _check_p004(module: LintModule) -> List[Finding]:
    if module.is_kernel or module.is_test:
        return []
    out = []
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call) and _is_pallas_call(node):
            out.append(
                module.finding(
                    P004, node,
                    "pl.pallas_call outside src/repro/kernels/ escapes the "
                    "grid/alias/ref conventions",
                )
            )
    return out


P001 = Rule(
    "P001", "grid-divisibility", "pallas",
    "pallas_call grid floor-division without pad or assert",
    "pad the axis to a tile multiple (Xp = -(-X // t) * t) or assert "
    "X % t == 0 before the call",
    _check_p001,
)
P002 = Rule(
    "P002", "alias-consistency", "pallas",
    "input_output_aliases inconsistent with prefetch offset/arity/out_shape",
    "offset alias keys by num_scalar_prefetch, keep them within the operand "
    "list, and declare aliased out_shapes from the operand (x.shape, x.dtype)",
    _check_p002,
)
P003 = Rule(
    "P003", "missing-ref-oracle", "pallas",
    "*_pallas kernel without a *_ref oracle in kernels/ref.py",
    "add the pure-jnp reference with the same signature to kernels/ref.py "
    "and sweep it in tests/test_kernels.py",
    _check_p003,
)
P004 = Rule(
    "P004", "pallas-outside-kernels", "pallas",
    "pl.pallas_call outside src/repro/kernels/",
    "move the kernel into src/repro/kernels/ with a ref.py oracle",
    _check_p004,
)

RULES = (P001, P002, P003, P004)
