"""Runtime transfer sanitizer + the sanctioned host-sync helpers.

This is the dynamic half of the H-family lint rules: the static pass bans
implicit syncs/transfers from hot-path modules, and this module provides
(1) the guard that makes implicit transfers *fail at runtime* and (2) the
one audited place where deliberate syncs are spelled explicitly.

``transfer_sanitizer`` wraps a scope in ``jax.transfer_guard("disallow")``:
any implicit host<->device transfer inside it raises instead of silently
serializing the pipeline. The trainer runs every jitted step dispatch under
it (``TrainerConfig.sanitize_transfers``), the hot-path tests assert train
runs stay green under it, and ``bench_throughput.py --sanitize`` fails hard
on transfer regressions. Two blind spots the static rules cover instead:
the guard treats ``jnp.asarray`` as an *explicit* transfer (hence lint rule
H002 bans it in hot paths — ``jax.device_put`` is the one legal spelling),
and the guard is thread-local, so it cannot see conversions in the prefetch
producer thread.

The helpers below are intentionally the only place in the hot path where
``float``/``block_until_ready`` appear: every use is an explicit
``jax.device_get``-routed drain a reviewer can audit in one screen.
"""
from __future__ import annotations

import contextlib
from typing import Iterable, List

import jax
import numpy as np


def transfer_sanitizer(enabled: bool = True):
    """Context manager: disallow implicit transfers inside the scope.

    Explicit ``jax.device_put`` / ``jax.device_get`` remain legal; anything
    implicit (a numpy array or python scalar fed straight to a jitted call,
    ``float()`` on a device value) raises. ``enabled=False`` returns a
    no-op context so call sites can thread a config flag without branching.
    """
    if not enabled:
        return contextlib.nullcontext()
    return jax.transfer_guard("disallow")


def host_scalar(x) -> float:
    """Deliberate single-value device->host sync (explicit device_get)."""
    return float(jax.device_get(x))


def host_array(x, dtype=None):
    """Deliberate device->host (or host->host) array materialization.

    The audited spelling of ``np.asarray`` for hot-path modules: device
    values are drained through an explicit ``jax.device_get`` first, so the
    transfer shows up in profiles and the static H001 rule has exactly one
    call site to trust. ``dtype`` applies a final cast on the host copy.
    """
    return np.asarray(jax.device_get(x), dtype=dtype)


def host_floats(xs: Iterable) -> List[float]:
    """Deliberate batched device->host drain: one device_get for the lot."""
    return [float(v) for v in jax.device_get(list(xs))]


class AsyncFloats:
    """A started (non-blocking) device->host drain, resolved later.

    ``host_floats`` blocks until every value's compute AND copy complete —
    in the trainer loop that stall serialized the pipeline once per drain
    window. ``host_floats_async`` instead kicks off the D2H copies
    (``copy_to_host_async`` where the backend provides it — a no-op hint
    otherwise) and returns this handle; :meth:`resolve` performs the same
    explicit ``jax.device_get`` as ``host_floats``, which is near-free by
    the time a full drain window of steps has been dispatched on top of
    the copy. Values and ordering are identical to a blocking drain.
    """

    def __init__(self, xs: Iterable):
        self._xs = list(xs)
        for x in self._xs:
            start = getattr(x, "copy_to_host_async", None)
            if start is not None:
                start()

    def __len__(self) -> int:
        return len(self._xs)

    def resolve(self) -> List[float]:
        return host_floats(self._xs)


def host_floats_async(xs: Iterable) -> AsyncFloats:
    """Begin a deliberate device->host drain without blocking the loop."""
    return AsyncFloats(xs)


def device_barrier(x):
    """Deliberate pipeline drain point (end of run / before timing)."""
    return jax.block_until_ready(x)
