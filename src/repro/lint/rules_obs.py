"""Observability / timing-hygiene rules (O family).

The telemetry layer (``repro.obs``) correlates spans across threads and
processes on the ``time.perf_counter_ns`` timebase (CLOCK_MONOTONIC on
Linux), and every latency metric the registry aggregates assumes a
monotonic source. ``time.time()`` is wall-clock: NTP slews and steps it,
so intervals measured with it can be negative or wildly wrong, and spans
stamped with it land on a different timeline than everything else in the
exported trace.

- **O001** ``time.time()`` in an instrumented module (the hot-path globs
  plus every module the telemetry layer instruments or implements). Use
  ``time.perf_counter_ns()`` / ``time.perf_counter()`` for intervals and
  spans, ``time.monotonic()`` for deadlines; ``time.time()`` is only for
  actual wall-clock timestamps (log records, file names) — which do not
  belong in these modules.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import List

from repro.lint.core import HOT_PATH_GLOBS, Finding, LintModule, Rule, call_name

# The hot-path modules plus everything the telemetry layer touches: the obs
# package itself (health watchdog included — its stall deadlines MUST be
# monotonic), the attribution timer it backs, and the instrumented
# sampling/retrieval/serving call sites.
INSTRUMENTED_GLOBS = HOT_PATH_GLOBS + (
    "src/repro/obs/*.py",
    "src/repro/train/attribution.py",
    "src/repro/sampling/*.py",
    "src/repro/retrieval/*.py",
    "src/repro/core/recall.py",
    "src/repro/serve/*.py",
)


def _applies(module: LintModule) -> bool:
    return any(fnmatch.fnmatch(module.rel, g) for g in INSTRUMENTED_GLOBS)


def _check_o001(module: LintModule) -> List[Finding]:
    if not _applies(module):
        return []
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        if call_name(node) == "time.time":
            out.append(
                module.finding(
                    O001, node,
                    "time.time() is wall-clock (NTP can slew/step it): "
                    "intervals measured with it are unreliable and spans "
                    "stamped with it misalign with the perf_counter_ns "
                    "trace timeline",
                )
            )
    return out


O001 = Rule(
    "O001", "wall-clock-in-instrumented-module", "obs",
    "time.time() used in a hot-path or telemetry-instrumented module",
    "time.perf_counter_ns()/perf_counter() for intervals and spans, "
    "time.monotonic() for deadlines",
    _check_o001,
)

RULES = (O001,)
