"""Contrastive objectives (Eq. 2) and in-batch negative sampling (§3.6).

Eq. 2 (skip-gram with negative sampling):

    L = -log σ(y_vu) - Σ_m E_{w~P}[log σ(-y_{w u})],   y_vu = h_vᵀ h_u

In-batch variant: within a batch of P positive pairs, every other dst in the
batch serves as a negative for each src — a P×P score matrix with a
softmax-CE on the diagonal. ``kernels/inbatch_loss`` provides the fused
Pallas implementation; this module is the reference/jnp path (and delegates
to the kernel when ``use_kernel=True``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def neg_sampling_loss(
    h_src: jnp.ndarray,  # (P, d)
    h_dst: jnp.ndarray,  # (P, d)
    h_neg: jnp.ndarray,  # (P, M, d)
) -> jnp.ndarray:
    """Eq. 2 with explicit random negatives."""
    pos = jnp.einsum("pd,pd->p", h_src, h_dst)
    neg = jnp.einsum("pd,pmd->pm", h_src, h_neg)
    return (
        -jax.nn.log_sigmoid(pos).mean()
        - jax.nn.log_sigmoid(-neg).sum(axis=-1).mean()
    )


def inbatch_softmax_loss(
    h_src: jnp.ndarray,  # (P, d)
    h_dst: jnp.ndarray,  # (P, d)
    temperature: float = 1.0,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """In-batch negatives: maximize diag scores vs the rest of the batch."""
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.inbatch_loss(h_src, h_dst, temperature=temperature)
    logits = (h_src @ h_dst.T) / temperature  # (P, P)
    labels = jnp.arange(h_src.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    return (logz - logits[labels, labels]).mean()


def inbatch_sigmoid_loss(
    h_src: jnp.ndarray, h_dst: jnp.ndarray, num_negatives: int = 5, key=None
) -> jnp.ndarray:
    """Eq. 2 shape with negatives drawn from the batch (paper's described
    variant: 'minimizing the scores of other nodes in a batch')."""
    P = h_src.shape[0]
    pos = jnp.einsum("pd,pd->p", h_src, h_dst)
    if key is None:
        # deterministic stride-based in-batch negatives
        idx = (jnp.arange(P)[:, None] + jnp.arange(1, num_negatives + 1)[None, :]) % P
    else:
        idx = jax.random.randint(key, (P, num_negatives), 0, P)
    neg = jnp.einsum("pd,pmd->pm", h_src, h_dst[idx])
    return (
        -jax.nn.log_sigmoid(pos).mean()
        - jax.nn.log_sigmoid(-neg).sum(axis=-1).mean()
    )
