"""The Graph4Rec model: PS embeddings + relation-wise GNN + contrastive loss.

This is the paper's §3 pipeline head: a training sample is a pair of ego
graphs (or bare node ids for walk-based models); the model embeds every
sampled node from the sharded table (plus side-info slots), runs the
relation-wise GNN bottom-up, and scores src/dst representations under Eq. 2
or the in-batch objective.

Everything is pure-functional: ``init_model_params`` returns a dict pytree,
``loss_fn`` is jit/pjit-able, and host-side batch conversion lives in
``device_batch`` (ego layouts + padded slot values -> jnp arrays).
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hetero import HeteroGNNConfig, hetero_forward, init_hetero_params
from repro.core import loss as loss_lib
from repro.embedding import table as emb
from repro.sampling.ego import EgoBatch
from repro.sampling.pipeline import TrainBatch
from repro.utils import get_logger

log = get_logger("repro.model")

PAD = -1
Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Graph4RecConfig:
    embedding: emb.EmbeddingConfig
    gnn: Optional[HeteroGNNConfig]  # None -> walk-based (DeepWalk/metapath2vec)
    fanouts: Tuple[int, ...] = ()
    relations: Tuple[str, ...] = ()  # relation order used for ego sampling
    use_side_info: bool = False
    # "bag": side info as precomputed count-matrix GEMMs (embedding-bag) —
    # no host-side value padding, no per-value backward scatter. Exactly
    # equivalent to "values" (padded value lists through embed_nodes); keep
    # "values" for slots whose vocab is too large for dense count rows.
    slot_mode: str = "bag"  # bag | values
    # Bag vocab guard: a 'bag'-mode slot whose vocab exceeds this many rows
    # falls back to the 'values' representation (with a one-time warning)
    # instead of materializing an O(num_nodes x vocab) count matrix. 0
    # disables the guard.
    bag_vocab_limit: int = 32768
    loss: str = "inbatch_softmax"  # inbatch_softmax | inbatch_sigmoid | neg_sampling
    temperature: float = 1.0
    use_kernel_loss: bool = False

    @property
    def is_walk_based(self) -> bool:
        return self.gnn is None


# one warning per (slot, vocab, limit) combination per process
_bag_fallback_warned: set = set()


def _split_slot_specs(
    cfg: "Graph4RecConfig",
) -> Tuple[Tuple[emb.SlotSpec, ...], Tuple[emb.SlotSpec, ...]]:
    """(bag-mode specs, values-mode specs) after the bag vocab guard."""
    if not cfg.use_side_info or not cfg.embedding.slots:
        return (), ()
    if cfg.slot_mode == "values":
        return (), tuple(cfg.embedding.slots)
    if cfg.slot_mode != "bag":
        raise ValueError(f"unknown slot_mode {cfg.slot_mode!r}")
    bag, values = [], []
    for spec in cfg.embedding.slots:
        if cfg.bag_vocab_limit and spec.vocab_size > cfg.bag_vocab_limit:
            key = (spec.name, spec.vocab_size, cfg.bag_vocab_limit)
            if key not in _bag_fallback_warned:
                _bag_fallback_warned.add(key)
                log.warning(
                    "slot %r vocab %d exceeds bag_vocab_limit=%d; using "
                    "slot_mode='values' for this slot instead of a dense "
                    "(num_nodes, %d) count matrix",
                    spec.name, spec.vocab_size, cfg.bag_vocab_limit,
                    spec.vocab_size,
                )
            values.append(spec)
        else:
            bag.append(spec)
    return tuple(bag), tuple(values)


def bag_slot_specs(cfg: "Graph4RecConfig") -> Tuple[emb.SlotSpec, ...]:
    return _split_slot_specs(cfg)[0]


def value_slot_specs(cfg: "Graph4RecConfig") -> Tuple[emb.SlotSpec, ...]:
    return _split_slot_specs(cfg)[1]


def init_model_params(key: jax.Array, cfg: Graph4RecConfig) -> Params:
    k_emb, k_gnn = jax.random.split(key)
    params: Params = {f"emb/{k}": v for k, v in emb.init_params(k_emb, cfg.embedding).items()}
    if cfg.gnn is not None:
        for k, v in init_hetero_params(k_gnn, cfg.gnn).items():
            params[f"gnn/{k}"] = v
    return params


def split_params(params: Params) -> Tuple[Params, Params]:
    e = {k[4:]: v for k, v in params.items() if k.startswith("emb/")}
    g = {k[4:]: v for k, v in params.items() if k.startswith("gnn/")}
    return e, g


def sparse_dense_split(params: Params) -> Tuple[Params, Params]:
    """Sparse (PS-resident) vs dense parameters — the paper's RQ on how
    sparse/dense parameters affect performance keys off this split."""
    sparse = {k: v for k, v in params.items() if k.startswith("emb/")}
    dense = {k: v for k, v in params.items() if not k.startswith("emb/")}
    return sparse, dense


# ------------------------------------------------------------------ encoding
def _embed(
    e: Params,
    ids: jnp.ndarray,
    slots: Optional[Mapping[str, jnp.ndarray]],
    slot_counts: Optional[Mapping[str, jnp.ndarray]],
) -> jnp.ndarray:
    # A slot arrives through exactly one representation: count matrices for
    # bag-mode slots, padded value lists for values-mode (including slots the
    # bag vocab guard demoted). Both may be present in one batch.
    return emb.embed_nodes_mixed(
        e, ids, slot_values=slots, slot_counts=slot_counts, pad_id=PAD
    )


def encode_ids(
    params: Params,
    cfg: Graph4RecConfig,
    ids: jnp.ndarray,
    slots: Optional[Mapping[str, jnp.ndarray]] = None,
    slot_counts: Optional[Mapping[str, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """Walk-based encoder: the embedding row (+ side info) IS the output."""
    e, _ = split_params(params)
    return _embed(e, ids, slots, slot_counts)


def encode_ego(
    params: Params,
    cfg: Graph4RecConfig,
    levels: Sequence[jnp.ndarray],  # level k ids (B, W_k)
    level_slots: Optional[Sequence[Optional[Mapping[str, jnp.ndarray]]]] = None,
    slot_counts: Optional[Mapping[str, jnp.ndarray]] = None,
) -> jnp.ndarray:
    """GNN encoder over a batched relation-wise ego graph -> (B, d)."""
    e, g = split_params(params)
    feats = []
    masks = []
    for k, ids in enumerate(levels):
        slots = level_slots[k] if level_slots else None
        feats.append(_embed(e, ids, slots, slot_counts))
        masks.append(ids >= 0)
    return hetero_forward(g, cfg.gnn, feats, masks, list(cfg.fanouts))


def encode(
    params: Params,
    cfg: Graph4RecConfig,
    sample,
    slot_counts: Optional[Mapping[str, jnp.ndarray]] = None,
) -> jnp.ndarray:
    if cfg.is_walk_based:
        ids, slots = sample
        return encode_ids(params, cfg, ids, slots, slot_counts)
    levels, slots = sample
    return encode_ego(params, cfg, levels, slots, slot_counts)


# (graph -> {slot specs -> count arrays}); weak keys so graphs can be GC'd.
_slot_count_cache: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def slot_count_arrays(graph, cfg: Graph4RecConfig) -> Dict[str, jnp.ndarray]:
    """Count matrices for the bag-mode slots (the 'bag' side-info path).

    Slots the bag vocab guard demoted to 'values' are skipped — their
    O(num_nodes x vocab) count matrix is exactly what the guard exists to
    avoid. Cached per (graph, bag specs): slot values are static data, so
    callers like ``device_batch`` can omit the precomputed argument without
    paying a per-batch rebuild.
    """
    per_graph = _slot_count_cache.setdefault(graph, {})
    key = bag_slot_specs(cfg)
    if key not in per_graph:
        per_graph[key] = {
            spec.name: jnp.asarray(
                emb.slot_count_matrix(
                    graph.slots[spec.name].indptr, graph.slots[spec.name].values,
                    graph.num_nodes, spec.vocab_size, spec.max_values,
                )
            )
            for spec in key
        }
    return per_graph[key]


# ---------------------------------------------------------------------- loss
def loss_fn(params: Params, cfg: Graph4RecConfig, batch: Mapping) -> jnp.ndarray:
    slot_counts = batch.get("slot_counts")
    if "shared" in batch:
        # Shared-tower layout (fused walk_ego_pair): encode the unique
        # ego towers once, then gather per-pair embeddings by index.
        # Row-independent encoder => identical to encoding gathered towers.
        h_all = encode(params, cfg, batch["shared"], slot_counts)
        h_src = h_all[batch["src_sel"]]
        h_dst = h_all[batch["dst_sel"]]
    else:
        h_src = encode(params, cfg, batch["src"], slot_counts)
        h_dst = encode(params, cfg, batch["dst"], slot_counts)
    if cfg.loss == "inbatch_softmax":
        return loss_lib.inbatch_softmax_loss(
            h_src, h_dst, cfg.temperature, use_kernel=cfg.use_kernel_loss
        )
    if cfg.loss == "inbatch_sigmoid":
        return loss_lib.inbatch_sigmoid_loss(h_src, h_dst)
    if cfg.loss == "neg_sampling":
        h_neg = encode(params, cfg, batch["neg"], slot_counts)
        P = h_src.shape[0]
        return loss_lib.neg_sampling_loss(
            h_src, h_dst, h_neg.reshape(P, -1, h_neg.shape[-1])
        )
    raise ValueError(f"unknown loss {cfg.loss!r}")


# --------------------------------------------------------- host-side batching
def _slots_for_ids(
    graph, ids: np.ndarray, slot_specs: Sequence[emb.SlotSpec]
) -> Dict[str, np.ndarray]:
    out = {}
    for spec in slot_specs:
        sf = graph.slots[spec.name]
        out[spec.name] = emb.pad_slot_values(
            sf.indptr, sf.values, ids.reshape(-1), spec.max_values, pad_id=PAD
        ).reshape(ids.shape + (spec.max_values,))
    return out


def _values_mode(cfg: Graph4RecConfig) -> bool:
    return bool(value_slot_specs(cfg))


def _ego_arrays_np(graph, ego: EgoBatch, cfg: Graph4RecConfig):
    """One ego part as HOST numpy arrays (no H2D here — see host_batch)."""
    levels = list(ego.levels)
    slots = None
    vspecs = value_slot_specs(cfg)
    if vspecs:
        slots = [_slots_for_ids(graph, l, vspecs) for l in ego.levels]
    return (levels, slots)


def _ego_arrays(graph, ego: EgoBatch, cfg: Graph4RecConfig):
    return jax.device_put(_ego_arrays_np(graph, ego, cfg))


def host_batch(
    graph,
    batch: TrainBatch,
    cfg: Graph4RecConfig,
    slot_counts: Optional[Mapping[str, jnp.ndarray]] = None,
) -> Dict:
    """Convert a TrainBatch into a HOST numpy pytree, jit-shaped.

    This is the assemble stage of the trainer pipeline: everything a batch
    needs except the H2D transfer itself. The trainer's prefetch producer
    runs this (overlapping device compute) and the consumer-side stager
    performs the one explicit ``jax.device_put`` per batch — so transfers
    never hide inside a producer thread where neither the transfer guard
    nor a profiler can see them. ``device_batch`` composes the two for
    callers that want the old single-call behavior.

    In 'bag' slot mode no per-value padding happens here at all — side info
    rides along as the (cached, possibly already device-resident) count
    matrices from ``slot_count_arrays``. Callers that loop over batches
    should build those once and pass them in; they are computed on the fly
    otherwise.
    """
    out: Dict = {}
    bspecs, vspecs = _split_slot_specs(cfg)
    if bspecs and slot_counts is None:
        slot_counts = slot_count_arrays(graph, cfg)
    if cfg.is_walk_based:
        for name, ids in (("src", batch.src_ids), ("dst", batch.dst_ids)):
            slots = _slots_for_ids(graph, ids, vspecs) if vspecs else None
            out[name] = (ids, slots)
        if batch.neg_ids is not None:
            ids = batch.neg_ids.reshape(-1)
            slots = _slots_for_ids(graph, ids, vspecs) if vspecs else None
            out["neg"] = (ids, slots)
    else:
        out["src"] = _ego_arrays_np(graph, batch.src_ego, cfg)
        out["dst"] = _ego_arrays_np(graph, batch.dst_ego, cfg)
        if batch.neg_ego is not None:
            out["neg"] = _ego_arrays_np(graph, batch.neg_ego, cfg)
    if bspecs:
        out["slot_counts"] = dict(slot_counts)
    return out


def device_batch(
    graph,
    batch: TrainBatch,
    cfg: Graph4RecConfig,
    slot_counts: Optional[Mapping[str, jnp.ndarray]] = None,
) -> Dict:
    """``host_batch`` + one explicit H2D transfer of the whole pytree."""
    return jax.device_put(host_batch(graph, batch, cfg, slot_counts))


# ------------------------------------------- sparse (gather→step→scatter) path
def sparse_host_batch(
    graph,
    batch: TrainBatch,
    cfg: Graph4RecConfig,
    buckets: Optional[Dict[str, int]] = None,
) -> Dict:
    """``host_batch`` under the gather→step→scatter contract.

    Same structure as ``device_batch`` — so ``loss_fn`` runs unchanged — but
    every id is remapped onto rows of a per-table gathered sub-table, and
    ``out["uniq"]`` carries each table's global touched ids (PAD-padded in
    front to a power-of-two bucket; see ``embedding.table.unique_pad_ids``).
    In 'bag' slot mode ``out["slot_counts"]`` becomes a per-batch
    (node_bucket, value_bucket) sub count matrix — the touched rows/columns
    of the full (num_nodes, vocab) matrix — instead of the device-resident
    full one, so the jitted step never touches O(num_nodes) state.

    ``buckets`` (table key -> bucket width) is mutated in place and should be
    persisted by the caller across batches so jit shapes stay stable.
    """
    if buckets is None:
        buckets = {}
    out: Dict = {}
    bspecs, vspecs = _split_slot_specs(cfg)
    vm = bool(vspecs)
    bag = bool(bspecs)

    if cfg.is_walk_based:
        parts: Dict[str, np.ndarray] = {"src": batch.src_ids, "dst": batch.dst_ids}
        if batch.neg_ids is not None:
            parts["neg"] = batch.neg_ids.reshape(-1)
        id_arrays = list(parts.values())
    else:
        parts = {"src": batch.src_ego, "dst": batch.dst_ego}
        if batch.neg_ego is not None:
            parts["neg"] = batch.neg_ego
        id_arrays = [l for ego in parts.values() for l in ego.levels]

    uniq_node = emb.unique_pad_ids(id_arrays, buckets.get("node", 0))
    buckets["node"] = len(uniq_node)
    uniq: Dict[str, np.ndarray] = {"node": uniq_node}

    # Per-slot global value lists. 'values': the padded per-id lists that the
    # batch itself consumes. 'bag': each touched node's max_values-truncated
    # value set — exactly the nonzero columns of its count-matrix row.
    slot_globals: Dict[str, List[np.ndarray]] = (
        {s.name: [] for s in cfg.embedding.slots} if (vm or bag) else {}
    )
    part_slots: Dict[str, object] = {}
    if vm:
        for pname, p in parts.items():
            if cfg.is_walk_based:
                s = _slots_for_ids(graph, np.asarray(p).reshape(-1), vspecs)
                part_slots[pname] = s
                for sn, arr in s.items():
                    slot_globals[sn].append(arr)
            else:
                per_level = [
                    _slots_for_ids(graph, l, vspecs) for l in p.levels
                ]
                part_slots[pname] = per_level
                for lv in per_level:
                    for sn, arr in lv.items():
                        slot_globals[sn].append(arr)
    if bag:
        real_nodes = uniq_node[uniq_node >= 0]
        for spec in bspecs:
            sf = graph.slots[spec.name]
            slot_globals[spec.name].append(
                emb.pad_slot_values(
                    sf.indptr, sf.values, real_nodes, spec.max_values, pad_id=PAD
                )
            )
    for spec in cfg.embedding.slots:
        if not slot_globals:
            break
        key = f"slot:{spec.name}"
        uniq[key] = emb.unique_pad_ids(slot_globals[spec.name], buckets.get(key, 0))
        buckets[key] = len(uniq[key])

    if cfg.is_walk_based:
        for pname, ids in parts.items():
            local = emb.remap_ids(uniq_node, ids)
            slots = None
            if vm:
                slots = {
                    sn: emb.remap_ids(uniq[f"slot:{sn}"], arr)
                    for sn, arr in part_slots[pname].items()
                }
            out[pname] = (local, slots)
    else:
        for pname, ego in parts.items():
            levels = [emb.remap_ids(uniq_node, l) for l in ego.levels]
            slots = None
            if vm:
                slots = [
                    {
                        sn: emb.remap_ids(uniq[f"slot:{sn}"], arr)
                        for sn, arr in lv.items()
                    }
                    for lv in part_slots[pname]
                ]
            out[pname] = (levels, slots)

    if bag:
        out["slot_counts"] = {}
        n_bucket = len(uniq_node)
        offset = n_bucket - int((uniq_node >= 0).sum())
        for spec in bspecs:
            u = uniq[f"slot:{spec.name}"]
            vals = slot_globals[spec.name][0]  # (n_real, max_values) global ids
            cmat = np.zeros((n_bucket, len(u)), np.float32)
            valid = vals >= 0
            if valid.any():
                rows = offset + np.broadcast_to(
                    np.arange(vals.shape[0])[:, None], vals.shape
                )
                cols = emb.remap_ids(u, vals)
                np.add.at(cmat, (rows[valid], cols[valid]), 1.0)
            out["slot_counts"][spec.name] = cmat

    out["uniq"] = dict(uniq)
    return out


def sparse_device_batch(
    graph,
    batch: TrainBatch,
    cfg: Graph4RecConfig,
    buckets: Optional[Dict[str, int]] = None,
) -> Dict:
    """``sparse_host_batch`` + one explicit H2D transfer of the pytree."""
    return jax.device_put(sparse_host_batch(graph, batch, cfg, buckets))


# ------------------------------------------------------------- full inference
def encode_all_nodes(
    params: Params,
    cfg: Graph4RecConfig,
    engine,
    rng: np.random.Generator,
    graph,
    batch_size: int = 1024,
) -> np.ndarray:
    """Embed every node for recall evaluation (§4.2).

    Back-compat wrapper around ``repro.infer.embed_all_nodes`` — the
    full-graph inference subsystem (fixed-shape chunks, one jitted encoder
    compile, engine-backend agnostic). Imported lazily to keep core free of
    an infer dependency at module load."""
    from repro.infer import embed_all_nodes

    return embed_all_nodes(
        params, cfg, engine, graph, batch_size=batch_size, rng=rng
    )
