"""GNN zoo (Graph4Rec §3.5): per-relation message-passing layers.

Every layer implements Eq. 1's AGGREGATE/COMBINE over the dense ego layout
(see sampling/ego.py): given the self representations ``h_self`` (B, W, d),
the sampled neighbor representations for ONE relation ``h_nbr`` (B, W, F, d)
and a validity mask (B, W, F), produce the relation-wise output h_{v,r}
(B, W, d_out). The relation mixture, residual and attention live one level
up in core/hetero.py (Eq. 3), applied uniformly to every zoo member — the
paper does the same "for a fair comparison".

Zoo members and their aggregation:
    gcn        mean(nbr ∪ self) -> W -> relu              (Kipf & Welling)
    sage-mean  [self ‖ mean(nbr)] -> W -> relu            (GraphSAGE)
    sage-sum   [self ‖ sum(nbr)]  -> W -> relu
    gat        masked softmax attention over nbr -> W     (Veličković)
    gin        MLP((1+eps)·self + sum(nbr))               (Xu et al.)
    lightgcn   mean(nbr), NO transform/nonlinearity       (He et al.)
    ngcf       W1(self+mean) + W2(mean(nbr⊙self)), lrelu  (Wang et al.)

All functions are pure; parameters are plain dicts of jnp arrays. The mean
aggregation routes through kernels/seg_aggr's op so the Pallas kernel is the
production hot path (interpret-mode on CPU).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]

GNN_TYPES = ("gcn", "sage-mean", "sage-sum", "gat", "gin", "lightgcn", "ngcf")

# Process-wide default for routing masked mean/sum aggregation through the
# Pallas seg_aggr kernel (kernels/seg_aggr.py) — the TPU production hot path.
# The production way to select the kernel is per-config: set
# ``HeteroGNNConfig.use_kernel_aggr`` (or ``TrainerConfig.use_kernel_aggr``,
# which forwards to it); every aggregation entry point below also takes an
# explicit ``use_kernel`` argument. This global only backs the legacy
# ``use_kernel_aggregation()`` trace-time switch and applies when neither is
# specified (``use_kernel=None``).
_USE_KERNEL_AGGR = False


def use_kernel_aggregation(flag: bool) -> None:
    """Legacy process-wide switch; prefer ``HeteroGNNConfig.use_kernel_aggr``."""
    global _USE_KERNEL_AGGR
    _USE_KERNEL_AGGR = bool(flag)


def _kernel_selected(use_kernel: Optional[bool]) -> bool:
    return _USE_KERNEL_AGGR if use_kernel is None else bool(use_kernel)


def _dense(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else (2.0 / (d_in + d_out)) ** 0.5
    return jax.random.normal(key, (d_in, d_out)) * scale


def _kernel_aggr(h_nbr: jnp.ndarray, mask: jnp.ndarray, mode: str) -> jnp.ndarray:
    from repro.kernels import ops as kops

    B, W, F, d = h_nbr.shape
    out = kops.seg_aggr(h_nbr.reshape(B * W, F, d), mask.reshape(B * W, F), mode=mode)
    return out.reshape(B, W, d)


def masked_mean(
    h_nbr: jnp.ndarray, mask: jnp.ndarray, use_kernel: Optional[bool] = None
) -> jnp.ndarray:
    """(B,W,F,d),(B,W,F) -> (B,W,d); zero where no valid neighbor."""
    if _kernel_selected(use_kernel):
        return _kernel_aggr(h_nbr, mask, "mean")
    m = mask[..., None].astype(h_nbr.dtype)
    s = (h_nbr * m).sum(axis=-2)
    c = jnp.maximum(m.sum(axis=-2), 1.0)
    return s / c


def masked_sum(
    h_nbr: jnp.ndarray, mask: jnp.ndarray, use_kernel: Optional[bool] = None
) -> jnp.ndarray:
    if _kernel_selected(use_kernel):
        return _kernel_aggr(h_nbr, mask, "sum")
    return (h_nbr * mask[..., None].astype(h_nbr.dtype)).sum(axis=-2)


# ------------------------------------------------------------------- layers
def init_layer(key: jax.Array, gnn_type: str, dim: int) -> Params:
    ks = jax.random.split(key, 4)
    if gnn_type == "lightgcn":
        return {}  # parameter-free by design
    if gnn_type == "gcn":
        return {"w": _dense(ks[0], dim, dim)}
    if gnn_type in ("sage-mean", "sage-sum"):
        return {"w": _dense(ks[0], 2 * dim, dim)}
    if gnn_type == "gat":
        return {
            "w": _dense(ks[0], dim, dim),
            "a_self": jax.random.normal(ks[1], (dim,)) * 0.1,
            "a_nbr": jax.random.normal(ks[2], (dim,)) * 0.1,
        }
    if gnn_type == "gin":
        return {
            "eps": jnp.zeros(()),
            "w1": _dense(ks[0], dim, dim),
            "w2": _dense(ks[1], dim, dim),
        }
    if gnn_type == "ngcf":
        return {"w1": _dense(ks[0], dim, dim), "w2": _dense(ks[1], dim, dim)}
    raise ValueError(f"unknown gnn type {gnn_type!r}; choose from {GNN_TYPES}")


def apply_layer(
    params: Params,
    gnn_type: str,
    h_self: jnp.ndarray,  # (B, W, d)
    h_nbr: jnp.ndarray,  # (B, W, F, d)
    mask: jnp.ndarray,  # (B, W, F) bool
    use_kernel: Optional[bool] = None,  # None -> legacy global flag
) -> jnp.ndarray:
    if gnn_type == "lightgcn":
        # Linear propagation only — "transformation has no positive effect on CF".
        return masked_mean(h_nbr, mask, use_kernel)
    if gnn_type == "gcn":
        agg = masked_mean(
            jnp.concatenate([h_self[..., None, :], h_nbr], axis=-2),
            jnp.concatenate([jnp.ones_like(mask[..., :1]), mask], axis=-1),
            use_kernel,
        )
        return jax.nn.relu(agg @ params["w"])
    if gnn_type == "sage-mean":
        agg = masked_mean(h_nbr, mask, use_kernel)
        return jax.nn.relu(jnp.concatenate([h_self, agg], axis=-1) @ params["w"])
    if gnn_type == "sage-sum":
        agg = masked_sum(h_nbr, mask, use_kernel)
        return jax.nn.relu(jnp.concatenate([h_self, agg], axis=-1) @ params["w"])
    if gnn_type == "gat":
        wh_self = h_self @ params["w"]  # (B,W,d)
        wh_nbr = h_nbr @ params["w"]  # (B,W,F,d)
        e = jax.nn.leaky_relu(
            (wh_self * params["a_self"]).sum(-1)[..., None]
            + (wh_nbr * params["a_nbr"]).sum(-1),
            negative_slope=0.2,
        )  # (B,W,F)
        e = jnp.where(mask, e, -1e9)
        att = jax.nn.softmax(e, axis=-1)
        att = jnp.where(mask, att, 0.0)  # all-PAD rows -> zero output
        return jax.nn.relu((att[..., None] * wh_nbr).sum(axis=-2))
    if gnn_type == "gin":
        agg = (1.0 + params["eps"]) * h_self + masked_sum(h_nbr, mask, use_kernel)
        return jax.nn.relu(jax.nn.relu(agg @ params["w1"]) @ params["w2"])
    if gnn_type == "ngcf":
        m = masked_mean(h_nbr, mask, use_kernel)
        return jax.nn.leaky_relu(
            (h_self + m) @ params["w1"] + (m * h_self) @ params["w2"],
            negative_slope=0.2,
        )
    raise ValueError(f"unknown gnn type {gnn_type!r}")
