"""Recall strategies and metrics (paper §4.2): ICF, UCF, U2I @ K.

- **ICF**: for each interacted item i of user u, recall the top-N most
  similar items; recommend the top-K items most frequent in that pool.
- **UCF**: recall the top-N most similar users u' of u; recommend the top-K
  items most frequent among their interactions.
- **U2I**: retrieve items directly by user-embedding · item-embedding.

Recall@K = |recommended ∩ held-out| / |held-out| per user, averaged.
Brute-force similarity (exact top-N) — datasets here are synthetic and small.
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence, Tuple

import numpy as np


def _topk(sim_row: np.ndarray, k: int, exclude: np.ndarray = None) -> np.ndarray:
    if exclude is not None and len(exclude):
        sim_row = sim_row.copy()
        sim_row[exclude] = -np.inf
    k = min(k, sim_row.shape[0])
    idx = np.argpartition(-sim_row, k - 1)[:k]
    return idx[np.argsort(-sim_row[idx])]


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def _user_histories(train_pairs: np.ndarray, num_users: int) -> Dict[int, np.ndarray]:
    hist: Dict[int, list] = {}
    for u, i in train_pairs:
        hist.setdefault(int(u), []).append(int(i))
    return {u: np.unique(np.array(v, dtype=np.int64)) for u, v in hist.items()}


def evaluate_recall(
    user_emb: np.ndarray,  # (num_users, d)
    item_emb: np.ndarray,  # (num_items, d)
    train_pairs: np.ndarray,  # (Nt, 2) local (user, item) train interactions
    eval_pairs: np.ndarray,  # (Ne, 2) local held-out (user, item)
    top_k: int = 100,
    top_n: int = 20,
    max_users: int = 512,
    seed: int = 0,
) -> Dict[str, float]:
    """Returns {"icf": recall, "ucf": recall, "u2i": recall} @ top_k."""
    num_users, num_items = len(user_emb), len(item_emb)
    ue = _normalize(user_emb)
    ie = _normalize(item_emb)
    hist = _user_histories(train_pairs, num_users)
    held: Dict[int, set] = {}
    for u, i in eval_pairs:
        held.setdefault(int(u), set()).add(int(i))
    users = [u for u in held if u in hist]
    if not users:
        return {"icf": 0.0, "ucf": 0.0, "u2i": 0.0}
    rng = np.random.default_rng(seed)
    if len(users) > max_users:
        users = list(rng.choice(np.array(users), size=max_users, replace=False))

    ii_sim = ie @ ie.T  # (I, I)
    uu_sim = ue @ ue.T  # (U, U)
    ui_sim = ue @ ie.T  # (U, I)

    recalls = {"icf": [], "ucf": [], "u2i": []}
    for u in users:
        truth = held[u]
        seen = hist[u]
        # --- ICF: top-N similar items per history item, count frequency
        votes = np.zeros(num_items)
        for i in seen:
            for j in _topk(ii_sim[i], top_n, exclude=np.array([i])):
                votes[j] += 1
        votes[seen] = -np.inf
        rec = _topk(votes + 1e-9 * ui_sim[u], top_k)
        recalls["icf"].append(len(truth & set(rec.tolist())) / len(truth))
        # --- UCF: top-N similar users, aggregate their histories
        votes = np.zeros(num_items)
        sim_users = _topk(uu_sim[u], top_n + 1, exclude=np.array([u]))
        for v, w in zip(sim_users, np.linspace(1.0, 0.5, len(sim_users))):
            hv = hist.get(int(v))
            if hv is not None:
                votes[hv] += w
        votes[seen] = -np.inf
        rec = _topk(votes + 1e-9 * ui_sim[u], top_k)
        recalls["ucf"].append(len(truth & set(rec.tolist())) / len(truth))
        # --- U2I: direct embedding retrieval
        row = ui_sim[u].copy()
        row[seen] = -np.inf
        rec = _topk(row, top_k)
        recalls["u2i"].append(len(truth & set(rec.tolist())) / len(truth))
    return {k: float(np.mean(v)) for k, v in recalls.items()}
