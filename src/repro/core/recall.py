"""Recall strategies and metrics (paper §4.2): ICF, UCF, U2I @ K.

- **ICF**: for each interacted item i of user u, recall the top-N most
  similar items; recommend the top-K items most frequent in that pool.
- **UCF**: recall the top-N most similar users u' of u; recommend the top-K
  items most frequent among their interactions.
- **U2I**: retrieve items directly by user-embedding · item-embedding.

Metrics per strategy (the standard GNN-recsys comparison triple):
Recall@K = |recommended ∩ held-out| / |held-out|; HitRate@K = 1 if any
held-out item was recommended; NDCG@K = DCG over the ranked list / ideal
DCG. All averaged over evaluated users.

The evaluation is built on ``repro.retrieval``: every similarity search
(user→item, item→item, user→user) goes through one pluggable top-k
primitive, so the same orchestration runs as

- ``method="device"`` — chunked/streaming device top-k, O(chunk) memory,
  no similarity matrix ever materialized (production path; ``backend=
  "pallas"`` selects the fused kernel);
- ``method="ivf"`` — IVF coarse partitioning over both tables
  (million-item serving; bounded-recall approximation);
- ``method="bruteforce"`` — the numpy full-matrix oracle, retained for
  tests and as the seed-equivalent baseline arm of bench_recall.

All paths share one tie-break contract (equal scores → lower id wins), so
"device" is exact: bitwise the same recommendations as the oracle.

There is no user subsampling by default (``max_users=0`` evaluates every
held-out user); pass ``max_users>0`` for the old capped behavior.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

import time

import numpy as np

from repro.retrieval import (
    IVFConfig, IVFIndex, brute_force_topk, chunked_topk, pad_id_rows,
)
# the dense ICF/UCF re-rank shares the retrieval backends' tie-break rule
from repro.retrieval.topk import _deterministic_topk_rows

STRATEGIES = ("icf", "ucf", "u2i")


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def _user_histories(train_pairs: np.ndarray, num_users: int) -> Dict[int, np.ndarray]:
    hist: Dict[int, list] = {}
    for u, i in train_pairs:
        hist.setdefault(int(u), []).append(int(i))
    return {u: np.unique(np.array(v, dtype=np.int64)) for u, v in hist.items()}


# ------------------------------------------------------------------ metrics
def ranked_metrics(
    rec: np.ndarray, truths: Sequence[set], top_k: int
) -> Dict[str, float]:
    """Recall/HitRate/NDCG @ top_k for ranked id lists vs held-out sets.

    ``rec``: (B, K) ranked item ids (-1 = unfilled slot, never counts).
    Closed forms: DCG gain 1/log2(rank+2) for each held-out item recommended
    at ``rank``; ideal DCG places min(|truth|, K) hits at the top ranks.
    """
    discounts = 1.0 / np.log2(np.arange(top_k) + 2.0)
    recalls, hits, ndcgs = [], [], []
    for r, truth in zip(rec, truths):
        if not truth:
            continue
        r = np.asarray(r[:top_k])
        gain = (
            np.isin(r, np.fromiter(truth, np.int64, len(truth))) & (r >= 0)
        ).astype(np.float64)
        n_hit = gain.sum()
        recalls.append(n_hit / len(truth))
        hits.append(1.0 if n_hit else 0.0)
        ideal = discounts[: min(len(truth), len(r))].sum()
        ndcgs.append(float(gain @ discounts[: len(r)]) / ideal)
    if not recalls:
        return {"recall": 0.0, "hit": 0.0, "ndcg": 0.0}
    return {
        "recall": float(np.mean(recalls)),
        "hit": float(np.mean(hits)),
        "ndcg": float(np.mean(ndcgs)),
    }


# ------------------------------------------------------- retrieval dispatch
def _make_searchers(
    method: str,
    ue: np.ndarray,
    ie: np.ndarray,
    backend: str,
    item_chunk: int,
    query_chunk: int,
    ivf: Optional[IVFConfig],
    telemetry=None,
) -> Dict[str, Callable]:
    """One top-k callable per corpus ("item", "user"), method-specific.

    With ``telemetry`` wired, every searcher is wrapped so each retrieval
    search emits a ``retrieval.<corpus>`` span and observes the
    ``retrieval.search_ns`` latency histogram — the backends themselves
    stay untouched."""
    if method == "bruteforce":
        fn = brute_force_topk
        searchers = {"item": lambda q, k, ex=None: fn(q, ie, k, exclude=ex),
                     "user": lambda q, k, ex=None: fn(q, ue, k, exclude=ex)}
    elif method == "device":
        def make(corpus):
            def search(q, k, ex=None):
                return chunked_topk(
                    q, corpus, k, exclude=ex, item_chunk=item_chunk,
                    query_chunk=query_chunk, backend=backend,
                )
            return search
        searchers = {"item": make(ie), "user": make(ue)}
    elif method == "ivf":
        cfg = ivf or IVFConfig()
        idx = {"item": IVFIndex.build(ie, cfg), "user": IVFIndex.build(ue, cfg)}
        if telemetry is not None:
            # introspection counters: why IVF recall/latency is what it is
            # (cells probed, and the candidates the gather stage *actually*
            # scored — true CSR list lengths, not the padded upper bound;
            # spill events = items only findable via their 2nd-best cell)
            m = telemetry.metrics
            m.counter("ivf.spill_events").inc(
                sum(ix.spilled_items for ix in idx.values())
            )
            c_cells = m.counter("ivf.cells_probed")
            c_cand = m.counter("ivf.candidates_scored")

            def make_counted(ix):
                def search(q, k, ex=None):
                    res = ix.search(q, k, exclude=ex)
                    c_cells.inc(ix.last_cells_probed)
                    c_cand.inc(ix.last_candidates_scored)
                    return res

                return search

            searchers = {name: make_counted(ix) for name, ix in idx.items()}
        else:
            searchers = {
                name: (lambda ix: lambda q, k, ex=None: ix.search(q, k, exclude=ex))(ix)
                for name, ix in idx.items()
            }
    else:
        raise ValueError(f"unknown recall method {method!r}")
    if telemetry is not None:
        tracer = telemetry.tracer
        hist = telemetry.metrics.histogram("retrieval.search_ns")

        def wrap(corpus_name, inner):
            def traced(q, k, ex=None):
                t0 = time.perf_counter_ns()
                res = inner(q, k, ex)
                dur = time.perf_counter_ns() - t0
                tracer.add_span(
                    f"retrieval.{corpus_name}", "retrieval", t0, dur,
                    {"method": method, "queries": len(q)},
                )
                hist.observe(dur)
                return res
            return traced

        searchers = {name: wrap(name, s) for name, s in searchers.items()}
    return searchers


# --------------------------------------------------------------- evaluation
def evaluate_recall(
    user_emb: np.ndarray,  # (num_users, d)
    item_emb: np.ndarray,  # (num_items, d)
    train_pairs: np.ndarray,  # (Nt, 2) local (user, item) train interactions
    eval_pairs: np.ndarray,  # (Ne, 2) local held-out (user, item)
    top_k: int = 100,
    top_n: int = 20,
    max_users: int = 0,  # 0 -> every held-out user (no subsampling)
    seed: int = 0,
    method: str = "device",  # device | ivf | bruteforce
    backend: str = "ref",  # device top-k flavor: ref (lax.scan) | pallas
    strategies: Sequence[str] = STRATEGIES,
    item_chunk: int = 8192,
    user_chunk: int = 512,
    ivf: Optional[IVFConfig] = None,
    telemetry=None,  # repro.obs.Telemetry: traces every retrieval search
) -> Dict[str, float]:
    """Recall/HitRate/NDCG @ top_k per strategy over the held-out pairs.

    Returns a flat dict: ``{"u2i": recall, "u2i_hit": …, "u2i_ndcg": …}``
    per requested strategy (the bare strategy key is Recall@K, the historic
    shape every caller already consumes).

    U2I runs entirely through the retrieval primitive with the user's
    training history excluded in-search. ICF/UCF use the primitive for the
    expensive O(I²)/O(U²) neighbor searches, then aggregate votes in
    ``user_chunk``-bounded dense blocks (identical numpy accumulation for
    every method, so methods differ only in how neighbors are found).
    """
    strategies = tuple(strategies)
    unknown = set(strategies) - set(STRATEGIES)
    if unknown:
        raise ValueError(f"unknown recall strategies {sorted(unknown)!r}; "
                         f"expected a subset of {STRATEGIES}")
    num_users, num_items = len(user_emb), len(item_emb)
    ue = _normalize(np.asarray(user_emb, dtype=np.float32))
    ie = _normalize(np.asarray(item_emb, dtype=np.float32))
    top_k = min(top_k, num_items)
    top_n = min(top_n, num_items)
    hist = _user_histories(train_pairs, num_users)
    held: Dict[int, set] = {}
    for u, i in eval_pairs:
        held.setdefault(int(u), set()).add(int(i))
    users = [u for u in held if u in hist]
    if not users:
        out = {}
        for s in strategies:
            out.update({s: 0.0, f"{s}_hit": 0.0, f"{s}_ndcg": 0.0})
        return out
    if max_users and len(users) > max_users:
        rng = np.random.default_rng(seed)
        users = list(rng.choice(np.array(users), size=max_users, replace=False))

    search = _make_searchers(
        method, ue, ie, backend, item_chunk, user_chunk, ivf,
        telemetry=telemetry,
    )
    uarr = np.array(users, dtype=np.int64)
    truths = [held[u] for u in users]
    seen_pad = pad_id_rows([hist[u] for u in users])  # (B, E)
    out: Dict[str, float] = {}

    def add(strategy: str, rec: np.ndarray) -> None:
        m = ranked_metrics(rec, truths, top_k)
        out[strategy] = m["recall"]
        out[f"{strategy}_hit"] = m["hit"]
        out[f"{strategy}_ndcg"] = m["ndcg"]

    # --- U2I: direct embedding retrieval, history excluded in-search
    if "u2i" in strategies:
        _, rec = search["item"](ue[uarr], top_k, seen_pad)
        add("u2i", rec)

    # --- ICF / UCF: neighbor searches up front, then one shared chunk loop
    # so the (chunk, num_items) tie-break GEMM is computed once per chunk
    want_icf = "icf" in strategies
    want_ucf = "ucf" in strategies
    if want_icf:
        # item-item neighbors of each history item vote for items
        seen_items = np.unique(np.concatenate([hist[u] for u in users]))
        _, nbrs = search["item"](
            ie[seen_items], top_n, seen_items[:, None].astype(np.int32)
        )  # (S, top_n), self excluded
        row_of_item = {int(i): r for r, i in enumerate(seen_items)}
        rec_icf = np.empty((len(users), top_k), dtype=np.int64)
    if want_ucf:
        # similar users' histories vote, rank-decayed weights
        n_sim = min(top_n + 1, num_users - 1) or 1
        _, sim_users = search["user"](
            ue[uarr], n_sim, uarr[:, None].astype(np.int32)
        )  # (B, n_sim), self excluded
        weights = np.linspace(1.0, 0.5, n_sim)
        rec_ucf = np.empty((len(users), top_k), dtype=np.int64)
    for lo in range(0, len(users), user_chunk) if (want_icf or want_ucf) else ():
        cu = users[lo : lo + user_chunk]
        ui = ue[uarr[lo : lo + len(cu)]] @ ie.T  # tie-break term, shared
        if want_icf:
            votes = np.zeros((len(cu), num_items), dtype=np.float64)
            for r, u in enumerate(cu):
                for i in hist[u]:
                    n = nbrs[row_of_item[int(i)]]
                    np.add.at(votes[r], n[n >= 0], 1.0)
                votes[r, hist[u]] = -np.inf
            rec_icf[lo : lo + len(cu)] = _deterministic_topk_rows(
                votes + 1e-9 * ui, top_k
            )
        if want_ucf:
            votes = np.zeros((len(cu), num_items), dtype=np.float64)
            # ranks ascending: per-cell accumulation order matches the
            # per-user neighbor loop of the seed implementation
            for rank in range(n_sim):
                for r, _ in enumerate(cu):
                    v = int(sim_users[lo + r, rank])
                    if v < 0:
                        continue
                    hv = hist.get(v)
                    if hv is not None:
                        votes[r, hv] += weights[rank]
            for r, u in enumerate(cu):
                votes[r, hist[u]] = -np.inf
            rec_ucf[lo : lo + len(cu)] = _deterministic_topk_rows(
                votes + 1e-9 * ui, top_k
            )
    if want_icf:
        add("icf", rec_icf)
    if want_ucf:
        add("ucf", rec_ucf)

    return out


def evaluate_recall_bruteforce(*args, **kwargs) -> Dict[str, float]:
    """The numpy full-matrix oracle (seed-equivalent semantics + new
    metrics). Tests compare the device/IVF paths against this."""
    kwargs["method"] = "bruteforce"
    return evaluate_recall(*args, **kwargs)
