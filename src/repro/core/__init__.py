from repro.core.gnn import GNN_TYPES, init_layer, apply_layer, masked_mean, masked_sum
from repro.core.hetero import HeteroGNNConfig, init_hetero_params, hetero_forward, relation_mix
from repro.core.loss import neg_sampling_loss, inbatch_softmax_loss, inbatch_sigmoid_loss
from repro.core.model import (
    Graph4RecConfig, init_model_params, loss_fn, encode_ids, encode_ego,
    device_batch, host_batch, sparse_device_batch, sparse_host_batch,
    encode_all_nodes, split_params, sparse_dense_split,
)
from repro.core.recall import evaluate_recall
