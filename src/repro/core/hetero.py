"""Relation-wise heterogeneous aggregation — Eq. 3 of the paper.

    h_{v,r}^k = GNN_r(h_v^{k-1}, {h_u^{k-1} : u in N_{v,r}})
    h_v^k     = α·h_v^0 + (1-α)·Σ_r φ_r · h_{v,r}^k

- GNN_r: any zoo member (core/gnn.py), with *distinct weights per relation*
  (R-GCN style).
- φ_r: uniform constant (φ_r = 1/R, "constant uniform") or GATNE-style
  learned attention φ = softmax_r(wᵀ tanh(W h_{v,r})).
- α: residual to the hop-0 features against over-smoothing (APPNP-flavored).

Applied uniformly to every zoo model, as the paper does for fairness.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import gnn as gnn_lib

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class HeteroGNNConfig:
    gnn_type: str = "lightgcn"
    num_relations: int = 2
    num_layers: int = 2  # = number of ego hops K
    dim: int = 64
    alpha: float = 0.15  # residual weight on h^0
    relation_agg: str = "uniform"  # "uniform" | "gatne"
    # Route masked mean/sum through the Pallas seg_aggr kernel. None defers
    # to the legacy process-wide gnn.use_kernel_aggregation() flag.
    use_kernel_aggr: "bool | None" = None


def init_hetero_params(key: jax.Array, cfg: HeteroGNNConfig) -> Params:
    params: Params = {}
    keys = jax.random.split(key, cfg.num_layers * cfg.num_relations + 2)
    ki = 0
    for layer in range(cfg.num_layers):
        for r in range(cfg.num_relations):
            sub = gnn_lib.init_layer(keys[ki], cfg.gnn_type, cfg.dim)
            ki += 1
            for name, val in sub.items():
                params[f"l{layer}/r{r}/{name}"] = val
    if cfg.relation_agg == "gatne":
        params["att/W"] = jax.random.normal(keys[ki], (cfg.dim, cfg.dim)) * 0.05
        params["att/w"] = jax.random.normal(keys[ki + 1], (cfg.dim,)) * 0.05
    return params


def _layer_params(params: Params, layer: int, r: int) -> Params:
    pre = f"l{layer}/r{r}/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def relation_mix(
    params: Params, cfg: HeteroGNNConfig, h_rel: jnp.ndarray
) -> jnp.ndarray:
    """Mix per-relation outputs h_rel (B, W, R, d) -> (B, W, d) via φ_r."""
    if cfg.relation_agg == "uniform":
        return h_rel.mean(axis=-2)
    # GATNE: φ_r = softmax(wᵀ tanh(W h_{v,r}))
    score = jnp.einsum(
        "bwrd,d->bwr", jnp.tanh(h_rel @ params["att/W"]), params["att/w"]
    )
    phi = jax.nn.softmax(score, axis=-1)
    return jnp.einsum("bwr,bwrd->bwd", phi, h_rel)


def hetero_forward(
    params: Params,
    cfg: HeteroGNNConfig,
    level_feats: Sequence[jnp.ndarray],  # level k: (B, W_k, d) raw embeddings
    level_masks: Sequence[jnp.ndarray],  # level k: (B, W_k) bool validity
    fanouts: Sequence[int],
) -> jnp.ndarray:
    """Bottom-up sampled message passing over the dense ego layout.

    Returns the final center representation (B, d). ``level_feats[k]`` are
    hop-k node embeddings laid out per sampling/ego.py; each GNN layer
    collapses the deepest remaining level into its parents, relation-wise.
    """
    K = cfg.num_layers
    R = cfg.num_relations
    assert len(level_feats) == K + 1, (len(level_feats), K)
    h: List[jnp.ndarray] = list(level_feats)
    h0: List[jnp.ndarray] = list(level_feats)
    masks = list(level_masks)

    for layer in range(K):
        new_h: List[jnp.ndarray] = []
        # after `layer` collapses, levels 0..K-layer survive
        for k in range(K - layer):
            B, W, d = h[k].shape
            F = fanouts[k]
            child = h[k + 1].reshape(B, W, R, F, d)
            child_mask = masks[k + 1].reshape(B, W, R, F)
            outs = []
            for r in range(R):
                lp = _layer_params(params, layer, r)
                outs.append(
                    gnn_lib.apply_layer(
                        lp, cfg.gnn_type, h[k], child[:, :, r], child_mask[:, :, r],
                        use_kernel=cfg.use_kernel_aggr,
                    )
                )
            h_rel = jnp.stack(outs, axis=-2)  # (B, W, R, d)
            mixed = relation_mix(params, cfg, h_rel)
            out = cfg.alpha * h0[k] + (1.0 - cfg.alpha) * mixed
            # keep PAD rows zero so they contribute nothing upstream
            out = out * masks[k][..., None]
            new_h.append(out)
        h = new_h
    return h[0][:, 0, :]
