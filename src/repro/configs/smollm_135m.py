"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152 — llama-arch small.
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import LMConfig

ARCH_ID = "smollm-135m"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="dense",
        citation="hf:HuggingFaceTB/SmolLM-135M",
        lm=LMConfig(
            name=ARCH_ID, vocab=49152, d_model=576, n_layers=30,
            n_heads=9, n_kv=3, d_ff=1536, head_dim=64,
            rope_theta=10000.0, tie_embeddings=True,
        ),
        sub_quadratic=False,
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="dense",
        citation="hf:HuggingFaceTB/SmolLM-135M",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=96, n_layers=2,
            n_heads=3, n_kv=3, d_ff=192, head_dim=32,
            tie_embeddings=True, dtype="float32", remat=False,
        ),
        sub_quadratic=False,
    )
