"""DeepSeek-Coder-33B [arXiv:2401.14196].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256 — llama-arch.
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import LMConfig

ARCH_ID = "deepseek-coder-33b"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="dense", citation="arXiv:2401.14196",
        lm=LMConfig(
            name=ARCH_ID, vocab=32256, d_model=7168, n_layers=62,
            n_heads=56, n_kv=8, d_ff=19200, head_dim=128,
            rope_theta=100000.0,
        ),
        sub_quadratic=False,
        microbatches=4,
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="dense",
        citation="arXiv:2401.14196",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=2,
            n_heads=4, n_kv=2, d_ff=256, head_dim=32,
            dtype="float32", remat=False,
        ),
        sub_quadratic=False,
    )
