"""Jamba-v0.1 (52B) [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
Mamba : attention interleave 1:7 (one attention layer per 8-layer period, at
offset 4 — the paper's block layout), MoE replacing the dense MLP every
other layer. Only 4 attention layers total -> a full 500k KV cache is small
(the arch's design point), so long_500k runs WITHOUT sliding window.

The paper uses Mamba-1 internally; we substitute our Mamba2/SSD mixer with
the paper's state size (N=16) — noted in DESIGN.md (same interface, TPU-
friendly chunked dual form).
"""
from repro.configs.base import ArchSpec
from repro.models.mamba2 import Mamba2Config
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "jamba-v0.1-52b"


def _blocks(n_layers: int):
    out = []
    for i in range(n_layers):
        mixer = "attn" if i % 8 == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        out.append((mixer, ffn))
    return tuple(out)


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="hybrid", citation="arXiv:2403.19887",
        lm=LMConfig(
            name=ARCH_ID, vocab=65536, d_model=4096, n_layers=32,
            n_heads=32, n_kv=8, d_ff=14336, head_dim=128,
            blocks=_blocks(32),
            moe=MoEConfig(d_model=4096, d_ff=14336, num_experts=16, top_k=2,
                          shard="ep"),
            mamba=Mamba2Config(d_model=4096, d_state=16, headdim=64, expand=2),
        ),
        sub_quadratic=True,
        microbatches=2,  # mb=4 triggers pathological XLA while-loop compile times
        notes="1:7 attn:mamba, MoE every other layer; 4 attn layers -> "
              "full-cache long_500k is feasible by design.",
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="hybrid",
        citation="arXiv:2403.19887",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=8,
            n_heads=4, n_kv=2, d_ff=256, head_dim=32,
            blocks=_blocks(8),
            moe=MoEConfig(d_model=128, d_ff=256, num_experts=4, top_k=2,
                          group_size=64, shard="ep"),
            mamba=Mamba2Config(d_model=128, d_state=16, headdim=32, expand=2,
                               chunk=32),
            dtype="float32", remat=False,
        ),
        sub_quadratic=True,
    )
