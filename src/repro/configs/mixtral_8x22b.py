"""Mixtral-8x22B [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts top-2,
sliding-window attention. Experts (8) don't divide the 16-way model axis, so
expert weights use tensor-parallel sharding within each expert ("tp" mode).
SWA (window 4096) bounds the decode cache -> long_500k runs.
"""
from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "mixtral-8x22b"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="moe", citation="arXiv:2401.04088",
        lm=LMConfig(
            name=ARCH_ID, vocab=32768, d_model=6144, n_layers=56,
            n_heads=48, n_kv=8, d_ff=16384, head_dim=128,
            rope_theta=1e6, sliding_window=4096,
            blocks=tuple([("attn", "moe")] * 56),
            moe=MoEConfig(d_model=6144, d_ff=16384, num_experts=8, top_k=2,
                          shard="tp"),
        ),
        sub_quadratic=True,
        microbatches=4,
        notes="SWA ring cache (4096) => long_500k decodes with O(window) state.",
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="moe",
        citation="arXiv:2401.04088",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=2,
            n_heads=4, n_kv=2, d_ff=256, head_dim=32,
            sliding_window=16, blocks=tuple([("attn", "moe")] * 2),
            moe=MoEConfig(d_model=128, d_ff=256, num_experts=4, top_k=2,
                          group_size=64, shard="tp"),
            dtype="float32", remat=False,
        ),
        sub_quadratic=True,
    )
