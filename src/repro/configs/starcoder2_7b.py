"""StarCoder2-7B [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152 — GQA, RoPE,
LayerNorm + GELU MLP, QKV bias, sliding-window attention (4096) -> long_500k
runs with the ring cache.
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import LMConfig

ARCH_ID = "starcoder2-7b"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="dense", citation="arXiv:2402.19173",
        lm=LMConfig(
            name=ARCH_ID, vocab=49152, d_model=4608, n_layers=32,
            n_heads=36, n_kv=4, d_ff=18432, head_dim=128,
            qkv_bias=True, rope_theta=1e5, sliding_window=4096,
            mlp_kind="gelu", norm="ln",
        ),
        sub_quadratic=True,
        microbatches=2,
        notes="SWA 4096 per the StarCoder2 paper; ring cache enables long_500k.",
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="dense",
        citation="arXiv:2402.19173",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=2,
            n_heads=4, n_kv=2, d_ff=256, head_dim=32,
            qkv_bias=True, sliding_window=16, mlp_kind="gelu", norm="ln",
            dtype="float32", remat=False,
        ),
        sub_quadratic=True,
    )
