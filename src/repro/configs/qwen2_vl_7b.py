"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — M-RoPE, dynamic
resolution. Vision encoder is a stub; input_specs supplies patch embeddings.
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2-vl-7b"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="vlm", family="vlm", citation="arXiv:2409.12191",
        lm=LMConfig(
            name=ARCH_ID, vocab=152064, d_model=3584, n_layers=28,
            n_heads=28, n_kv=4, d_ff=18944, head_dim=128,
            qkv_bias=True, rope_theta=1e6, mrope_sections=(16, 24, 24),
            mlp_kind="swiglu", norm="rms",
        ),
        n_patches=1024, grid_hw=(32, 32),
        sub_quadratic=False,
        microbatches=2,
        notes="M-RoPE sections (t,h,w)=(16,24,24); image span after BOS.",
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="vlm", family="vlm",
        citation="arXiv:2409.12191",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=2,
            n_heads=4, n_kv=2, d_ff=256, head_dim=32,
            qkv_bias=True, rope_theta=1e6, mrope_sections=(4, 6, 6),
            mlp_kind="swiglu", norm="rms", dtype="float32", remat=False,
        ),
        n_patches=16, grid_hw=(4, 4), sub_quadratic=False,
    )
