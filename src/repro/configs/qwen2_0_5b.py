"""Qwen2-0.5B [arXiv:2407.10671].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — GQA with QKV bias,
tied embeddings.
"""
from repro.configs.base import ArchSpec
from repro.models.transformer import LMConfig

ARCH_ID = "qwen2-0.5b"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="dense", citation="arXiv:2407.10671",
        lm=LMConfig(
            name=ARCH_ID, vocab=151936, d_model=896, n_layers=24,
            n_heads=14, n_kv=2, d_ff=4864, head_dim=64,
            qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
        ),
        sub_quadratic=False,
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="dense",
        citation="arXiv:2407.10671",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=2,
            n_heads=4, n_kv=2, d_ff=256, head_dim=32,
            qkv_bias=True, tie_embeddings=True, dtype="float32", remat=False,
        ),
        sub_quadratic=False,
    )
