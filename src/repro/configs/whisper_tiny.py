"""Whisper-tiny backbone [arXiv:2212.04356].

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 — enc-dec; conv/mel frontend
stubbed (input_specs supplies 1500 frame embeddings).
"""
from repro.configs.base import ArchSpec
from repro.models.whisper import WhisperConfig

ARCH_ID = "whisper-tiny"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="whisper", family="audio", citation="arXiv:2212.04356",
        whisper=WhisperConfig(
            name=ARCH_ID, vocab=51865, d_model=384, n_layers=4,
            n_heads=6, n_kv=6, d_ff=1536, n_audio_frames=1500,
        ),
        sub_quadratic=False,
        notes="decode_32k exercises the decoder cache beyond the trained "
              "448-token context (lowering/sharding exercise, see DESIGN.md).",
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="whisper", family="audio",
        citation="arXiv:2212.04356",
        whisper=WhisperConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=96, n_layers=2,
            n_heads=4, n_kv=4, d_ff=192, n_audio_frames=32,
            dtype="float32", remat=False,
        ),
        sub_quadratic=False,
    )
