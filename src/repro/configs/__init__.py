from repro.configs.base import ArchSpec, ShapeSpec, SHAPES
from repro.configs.registry import get_arch, ARCH_IDS, all_pairs
