"""Mamba2-1.3B [arXiv:2405.21060].

48L d_model=2048 attn-free d_ff=0 vocab=50280, ssm_state=128 — SSD
(state-space duality). Pure (mamba, none) blocks; decode is O(1) state
update, so every decode shape including long_500k runs.
"""
from repro.configs.base import ArchSpec
from repro.models.mamba2 import Mamba2Config
from repro.models.transformer import LMConfig

ARCH_ID = "mamba2-1.3b"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="ssm", citation="arXiv:2405.21060",
        lm=LMConfig(
            name=ARCH_ID, vocab=50280, d_model=2048, n_layers=48,
            n_heads=1, n_kv=1, d_ff=0, head_dim=64,  # attn fields unused
            blocks=tuple([("mamba", "none")] * 48),
            mamba=Mamba2Config(d_model=2048, d_state=128, headdim=64, expand=2),
        ),
        sub_quadratic=True,
        notes="attention-free: Graph4Rec's sampling techniques inapplicable "
              "(DESIGN.md §Arch-applicability); shares the PS-sharded vocab table.",
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="ssm",
        citation="arXiv:2405.21060",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=2,
            n_heads=1, n_kv=1, d_ff=0, head_dim=32,
            blocks=tuple([("mamba", "none")] * 2),
            mamba=Mamba2Config(d_model=128, d_state=32, headdim=32, expand=2,
                               chunk=32),
            dtype="float32", remat=False,
        ),
        sub_quadratic=True,
    )
