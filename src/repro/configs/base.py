"""Architecture/shape specs: the dry-run and benchmark surface.

Every assigned architecture provides ``full()`` (exact paper config) and
``reduced()`` (2-layer, d_model<=512, <=4 experts smoke variant) returning an
``ArchSpec``. The spec knows how to build abstract params, input specs
(ShapeDtypeStructs — never allocated), sharding specs, and the jittable
step functions (train loss / prefill / one-token serve step) for each input
shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import qwen2_vl as VLM
from repro.models import transformer as T
from repro.models import whisper as W


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def resolve_shape(shape) -> ShapeSpec:
    """Accept a shape name or an explicit ShapeSpec (dry-run seq probes)."""
    return SHAPES[shape] if isinstance(shape, str) else shape


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    kind: str  # "lm" | "vlm" | "whisper"
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    lm: Optional[T.LMConfig] = None
    whisper: Optional[W.WhisperConfig] = None
    # vlm extras
    n_patches: int = 0
    grid_hw: Tuple[int, int] = (0, 0)
    sub_quadratic: bool = False  # may run long_500k
    # gradient-accumulation microbatches for train_4k (activation memory
    # control on the big configs; global batch unchanged)
    microbatches: int = 1
    notes: str = ""

    def unrolled(self) -> "ArchSpec":
        """Variant with python-unrolled layers (true FLOP/byte analysis —
        XLA's cost analysis counts while-loop bodies once)."""
        if self.kind == "whisper":
            return dataclasses.replace(
                self, whisper=dataclasses.replace(self.whisper, scan_layers=False)
            )
        return dataclasses.replace(
            self, lm=dataclasses.replace(self.lm, scan_layers=False)
        )

    def with_layers(self, n: int) -> "ArchSpec":
        """Depth-reduced probe variant (same width/pattern, n layers).

        Used by the dry-run's trip-count correction: XLA cost analysis counts
        scan bodies once, so we compile 1- and 2-period probes unrolled and
        extrapolate linearly in depth (exact — layers repeat per period)."""
        if self.kind == "whisper":
            return dataclasses.replace(
                self, whisper=dataclasses.replace(self.whisper, n_layers=n)
            )
        lm = self.lm
        p = lm.period()
        assert n % p == 0, (n, p)
        blocks = tuple(lm.block_list()[:p]) * (n // p) if lm.blocks else ()
        return dataclasses.replace(
            self, lm=dataclasses.replace(lm, n_layers=n, blocks=blocks)
        )

    @property
    def depth_reps(self) -> int:
        """Number of repeating-period units in the full depth."""
        if self.kind == "whisper":
            return self.whisper.n_layers
        return self.lm.n_layers // self.lm.period()

    @property
    def period_layers(self) -> int:
        return 1 if self.kind == "whisper" else self.lm.period()

    # ------------------------------------------------------------- supports
    def supports(self, shape: str) -> Tuple[bool, str]:
        s = SHAPES[shape]
        if s.name == "long_500k" and not self.sub_quadratic:
            return False, "full-attention arch: long_500k skipped (see DESIGN.md)"
        return True, ""

    # ----------------------------------------------------------- parameters
    def init_params(self, key: jax.Array):
        if self.kind == "whisper":
            return W.init_whisper_params(key, self.whisper)
        return T.init_lm_params(key, self.lm)

    def abstract_params(self):
        if self.kind == "whisper":
            return W.abstract_params(self.whisper)
        return T.abstract_params(self.lm)

    def param_pspecs(self):
        if self.kind == "whisper":
            return W.param_pspecs(self.whisper)
        return T.param_pspecs(self.lm)

    @property
    def d_model(self) -> int:
        return self.whisper.d_model if self.kind == "whisper" else self.lm.d_model

    @property
    def dtype(self):
        return jnp.dtype(
            self.whisper.dtype if self.kind == "whisper" else self.lm.dtype
        )

    # --------------------------------------------------------------- inputs
    def input_specs(self, shape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        s = resolve_shape(shape)
        B, S = s.global_batch, s.seq_len
        i32 = jnp.int32
        if s.kind in ("train", "prefill"):
            if self.kind == "lm":
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32),
                }
            if self.kind == "vlm":
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32),
                    "patch_embeds": jax.ShapeDtypeStruct(
                        (B, self.n_patches, self.d_model), self.dtype
                    ),
                }
            if self.kind == "whisper":
                return {
                    "audio_embeds": jax.ShapeDtypeStruct(
                        (B, self.whisper.n_audio_frames, self.d_model), self.dtype
                    ),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32),
                }
        # decode: ONE new token + the KV/state cache of seq_len
        return {"token": jax.ShapeDtypeStruct((B, 1), i32)}

    def input_pspecs(self, shape) -> Dict[str, Any]:
        from repro.models.sharding import spec as SP

        s = resolve_shape(shape)
        if s.kind in ("train", "prefill"):
            if self.kind == "lm":
                return {"tokens": SP("batch", None), "labels": SP("batch", None)}
            if self.kind == "vlm":
                return {
                    "tokens": SP("batch", None),
                    "labels": SP("batch", None),
                    "patch_embeds": SP("batch", None, None),
                }
            if self.kind == "whisper":
                return {
                    "audio_embeds": SP("batch", None, None),
                    "tokens": SP("batch", None),
                    "labels": SP("batch", None),
                }
        return {"token": SP("batch", None)}

    # ---------------------------------------------------------------- cache
    def abstract_cache(self, shape):
        s = resolve_shape(shape)
        assert s.kind == "decode", shape
        if self.kind == "whisper":
            audio = jax.ShapeDtypeStruct(
                (s.global_batch, self.whisper.n_audio_frames, self.d_model), self.dtype
            )
            return jax.eval_shape(
                lambda p, a: W.init_cache(p, self.whisper, a, s.seq_len),
                self.abstract_params(), audio,
            )
        return jax.eval_shape(
            lambda: T.init_cache(self.lm, s.global_batch, s.seq_len)
        )

    def init_cache(self, params, shape):
        s = resolve_shape(shape)
        if self.kind == "whisper":
            audio = jnp.zeros(
                (s.global_batch, self.whisper.n_audio_frames, self.d_model), self.dtype
            )
            return W.init_cache(params, self.whisper, audio, s.seq_len)
        return T.init_cache(self.lm, s.global_batch, s.seq_len)

    def cache_pspecs(self):
        if self.kind == "whisper":
            return W.cache_pspecs(self.whisper)
        return T.cache_pspecs(self.lm)

    # ------------------------------------------------------- step functions
    def make_train_loss(self) -> Callable:
        if self.kind == "lm":
            cfg = self.lm

            def loss(params, batch):
                return T.lm_loss(params, cfg, batch["tokens"], batch["labels"])

            return loss
        if self.kind == "vlm":
            cfg, grid = self.lm, self.grid_hw

            def loss(params, batch):
                return VLM.vlm_loss(
                    params, cfg, batch["tokens"], batch["labels"],
                    batch["patch_embeds"], grid,
                )

            return loss
        cfg = self.whisper

        def loss(params, batch):
            return W.loss(
                params, cfg, batch["audio_embeds"], batch["tokens"], batch["labels"]
            )

        return loss

    def make_train_step(self, optimizer) -> Callable:
        import repro.train.optimizer as opt_lib

        loss_fn = self.make_train_loss()
        k = self.microbatches
        scan_mb = (self.whisper.scan_layers if self.kind == "whisper"
                   else self.lm.scan_layers)

        def train_step(params, opt_state, batch):
            if k == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                # gradient accumulation over k microbatches (batch dim split)
                mbs = jax.tree_util.tree_map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
                )

                def one(mb):
                    return jax.value_and_grad(loss_fn)(params, mb)

                if scan_mb:
                    def body(acc, mb):
                        l, g = one(mb)
                        loss_acc, grad_acc = acc
                        return (loss_acc + l,
                                jax.tree_util.tree_map(jnp.add, grad_acc, g)), None

                    zero = (jnp.zeros(()),
                            jax.tree_util.tree_map(jnp.zeros_like, params))
                    (loss, grads), _ = jax.lax.scan(body, zero, mbs)
                else:  # python unroll (dry-run probes: true FLOP counts)
                    loss = jnp.zeros(())
                    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
                    for i in range(k):
                        mb = jax.tree_util.tree_map(lambda x: x[i], mbs)
                        l, g = one(mb)
                        loss = loss + l
                        grads = jax.tree_util.tree_map(jnp.add, grads, g)
                loss = loss / k
                grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            params = opt_lib.apply_updates(params, updates)
            return params, opt_state, loss

        return train_step

    def make_prefill(self) -> Callable:
        """Prefill: full forward, emit only the last-position logits."""
        if self.kind == "whisper":
            cfg = self.whisper

            def prefill(params, batch):
                enc = W.encode(params, cfg, batch["audio_embeds"])
                logits = W.decode_train(params, cfg, enc, batch["tokens"])
                return logits[:, -1, :]

            return prefill
        cfg = self.lm
        if self.kind == "vlm":
            grid, n_p = self.grid_hw, self.n_patches

            def prefill(params, batch):
                B, S = batch["tokens"].shape
                x = VLM.merge_vision_embeds(params, cfg, batch["tokens"],
                                            batch["patch_embeds"])
                pos = VLM.mrope_positions(B, S, n_p, grid)
                logits, _ = T.forward(params, cfg, inputs_embeds=x, positions=pos)
                return logits[:, -1, :]

            return prefill

        def prefill(params, batch):
            logits, _ = T.forward(params, cfg, batch["tokens"])
            return logits[:, -1, :]

        return prefill

    def make_serve_step(self) -> Callable:
        if self.kind == "whisper":
            cfg = self.whisper

            def serve_step(params, cache, batch):
                return W.decode_step(params, cfg, cache, batch["token"])

            return serve_step
        cfg = self.lm

        def serve_step(params, cache, batch):
            return T.decode_step(params, cfg, cache, batch["token"])

        return serve_step
