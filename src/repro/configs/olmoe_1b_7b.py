"""OLMoE-1B-7B [arXiv:2409.02060].

16L d_model=2048 16H (kv=16, MHA) d_ff=1024 per expert vocab=50304,
MoE 64 experts top-8 — expert-parallel sharding (64 % 16 == 0).
"""
from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "olmoe-1b-7b"


def full() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID, kind="lm", family="moe", citation="arXiv:2409.02060",
        lm=LMConfig(
            name=ARCH_ID, vocab=50304, d_model=2048, n_layers=16,
            n_heads=16, n_kv=16, d_ff=1024, head_dim=128,
            rope_theta=10000.0,
            blocks=tuple([("attn", "moe")] * 16),
            moe=MoEConfig(d_model=2048, d_ff=1024, num_experts=64, top_k=8,
                          group_size=512, shard="ep"),
        ),
        sub_quadratic=False,
    )


def reduced() -> ArchSpec:
    return ArchSpec(
        arch_id=ARCH_ID + "-smoke", kind="lm", family="moe",
        citation="arXiv:2409.02060",
        lm=LMConfig(
            name=ARCH_ID + "-smoke", vocab=512, d_model=128, n_layers=2,
            n_heads=4, n_kv=4, d_ff=64, head_dim=32,
            blocks=tuple([("attn", "moe")] * 2),
            moe=MoEConfig(d_model=128, d_ff=64, num_experts=4, top_k=2,
                          group_size=64, shard="ep"),
            dtype="float32", remat=False,
        ),
        sub_quadratic=False,
    )
