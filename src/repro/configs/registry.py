"""Architecture registry: ``--arch <id>`` resolution for launcher/benches."""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.configs.base import ArchSpec, SHAPES, ShapeSpec
from repro.configs import (
    deepseek_coder_33b,
    jamba_v0_1_52b,
    mamba2_1_3b,
    mixtral_8x22b,
    olmoe_1b_7b,
    qwen2_0_5b,
    qwen2_vl_7b,
    smollm_135m,
    starcoder2_7b,
    whisper_tiny,
)

_MODULES = {
    "qwen2-vl-7b": qwen2_vl_7b,
    "whisper-tiny": whisper_tiny,
    "mixtral-8x22b": mixtral_8x22b,
    "qwen2-0.5b": qwen2_0_5b,
    "smollm-135m": smollm_135m,
    "starcoder2-7b": starcoder2_7b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "mamba2-1.3b": mamba2_1_3b,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_arch(arch_id: str, reduced: bool = False) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = _MODULES[arch_id]
    return mod.reduced() if reduced else mod.full()


def all_pairs():
    """Every (arch, shape) with its support verdict."""
    out = []
    for aid in ARCH_IDS:
        spec = get_arch(aid)
        for shape in SHAPES:
            ok, reason = spec.supports(shape)
            out.append((aid, shape, ok, reason))
    return out
