"""Multi-process shared-memory graph engine tests (graph/service).

Covers the ISSUE-3 acceptance surface: bitwise in-process vs multi-process
sample equivalence under a fixed seed, cross-partition stat aggregation
across the process boundary, worker crash -> raised error (never a hang),
and double-shutdown idempotence. Every test runs under a hard SIGALRM
watchdog so a stuck worker can fail tier-1 but can never wedge it.
"""
import signal
import threading

import numpy as np
import pytest

from repro.graph import DistributedGraphEngine, GraphClient, TOY, generate
from repro.graph.service import EngineWorkerError, attach_shard, build_shard
from repro.sampling import EgoConfig, PairConfig, PipelineConfig
from repro.sampling.pipeline import SamplePipeline
from repro.walk import WalkConfig

pytestmark = pytest.mark.mp

HARD_TIMEOUT_S = 120

RELS = ("u2click2i", "i2click2u")


@pytest.fixture(autouse=True)
def _watchdog():
    """Hard per-test timeout: a hung worker/pipe fails loudly, never blocks."""

    def _expired(signum, frame):
        raise TimeoutError(f"test exceeded hard {HARD_TIMEOUT_S}s watchdog")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="module")
def ds(toy_ds_alt):
    # shared session dataset (tests/conftest.py) — seed-1 instance so the
    # mp suite exercises a graph independent of the seed-0 consumers
    return toy_ds_alt


@pytest.fixture(scope="module")
def inproc(ds):
    return DistributedGraphEngine(ds.graph, num_partitions=4)


@pytest.fixture(scope="module")
def client(ds):
    with GraphClient(ds.graph, num_partitions=4, num_workers=2) as c:
        yield c


def _pipe_cfg(with_ego: bool = True) -> PipelineConfig:
    return PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
        pair=PairConfig(win_size=2, neg_mode="random", num_negatives=3),
        ego=EgoConfig(relations=list(RELS), fanouts=[3, 2]) if with_ego else None,
        batch_pairs=64,
        walks_per_round=32,
    )


@pytest.mark.quick
class TestShmShards:
    def test_shard_roundtrip_bitwise(self, ds):
        seg, manifest = build_shard(ds.graph, part_id=1, num_parts=4)
        try:
            att, views = attach_shard(manifest)
            ref = DistributedGraphEngine(ds.graph, num_partitions=4).partitions[1]
            for rel, (indptr, indices) in ref.rel_rows.items():
                np.testing.assert_array_equal(views[f"{rel}/indptr"], indptr)
                np.testing.assert_array_equal(views[f"{rel}/indices"], indices)
                assert not views[f"{rel}/indices"].flags.writeable
            att.close()
        finally:
            seg.close()
            seg.unlink()


@pytest.mark.quick
class TestBitwiseEquivalence:
    def test_sample_neighbors_matches_inproc(self, ds, inproc, client):
        for seed in (0, 7):
            a = inproc.sample_neighbors(
                np.random.default_rng(seed), np.arange(80), RELS[0], 5
            )
            b = client.sample_neighbors(
                np.random.default_rng(seed), np.arange(80), RELS[0], 5
            )
            np.testing.assert_array_equal(a, b)

    def test_sample_many_matches_inproc(self, ds, inproc, client):
        nodes = np.random.default_rng(3).integers(0, ds.graph.num_nodes, 120)
        queries = [(nodes, RELS[0], 4, -1), (nodes[:50], RELS[1], 2, -1)]
        a = inproc.sample_many(np.random.default_rng(11), queries)
        b = client.sample_many(np.random.default_rng(11), queries)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_owner_dispatch_and_slab_overflow_match(self, ds, inproc):
        """Owner fan-out, and the pickle fallback for calls too large for a
        slab slot, are bitwise-identical to the balanced shm path."""
        nodes = np.random.default_rng(5).integers(0, ds.graph.num_nodes, 300)
        ref = inproc.sample_many(
            np.random.default_rng(9), [(nodes, RELS[0], 6, -1), (nodes, RELS[1], 2, -1)]
        )
        for kw in (
            dict(dispatch="owner"),
            dict(dispatch="owner", slot_bytes=256),  # forces pickle replies
            dict(dispatch="balanced", slot_bytes=256),  # falls back to owner
        ):
            with GraphClient(ds.graph, num_partitions=4, num_workers=2, **kw) as c:
                got = c.sample_many(
                    np.random.default_rng(9),
                    [(nodes, RELS[0], 6, -1), (nodes, RELS[1], 2, -1)],
                )
                for x, y in zip(ref, got):
                    np.testing.assert_array_equal(x, y)
                if kw.get("slot_bytes") == 256:
                    assert sum(
                        s["pickle_replies"] for s in c.worker_stats()
                    ) > 0

    def test_async_submit_gather_pipelines(self, client):
        """Two in-flight requests gathered out of submission order."""
        rng1, rng2 = np.random.default_rng(1), np.random.default_rng(2)
        h1 = client.submit(rng1, [(np.arange(60), RELS[0], 3, -1)])
        h2 = client.submit(rng2, [(np.arange(60), RELS[0], 3, -1)])
        out2 = client.gather(h2)[0]
        out1 = client.gather(h1)[0]
        ref1 = client.sample_neighbors(np.random.default_rng(1), np.arange(60), RELS[0], 3)
        ref2 = client.sample_neighbors(np.random.default_rng(2), np.arange(60), RELS[0], 3)
        np.testing.assert_array_equal(out1, ref1)
        np.testing.assert_array_equal(out2, ref2)

    def test_out_of_order_gather_never_reuses_held_slots(self, ds, inproc):
        """Regression: deep pipelining with out-of-order gathers must not
        hand a new request a slab slot an un-gathered request still owns
        (the old ring-pointer allocation corrupted the straggler's reply)."""
        with GraphClient(
            ds.graph, num_partitions=4, num_workers=1, slab_slots=4
        ) as c:
            rngs = [np.random.default_rng(100 + i) for i in range(8)]
            refs = [
                inproc.sample_neighbors(
                    np.random.default_rng(100 + i), np.arange(70), RELS[0], 4
                )
                for i in range(8)
            ]
            # fill the slab ring, then free ONE slot by gathering the newest
            handles = {
                i: c.submit(rngs[i], [(np.arange(70), RELS[0], 4, -1)])
                for i in range(4)
            }
            np.testing.assert_array_equal(c.gather(handles.pop(3))[0], refs[3])
            # these reservations recycle freed slots; the held ones (0..2)
            # must keep their data intact the whole time
            for i in range(4, 8):
                h = c.submit(rngs[i], [(np.arange(70), RELS[0], 4, -1)])
                np.testing.assert_array_equal(c.gather(h)[0], refs[i])
            for i, h in handles.items():
                np.testing.assert_array_equal(c.gather(h)[0], refs[i])


@pytest.mark.quick
class TestHybridLocalServing:
    """``local_threshold``: small rounds served in-process by the client
    over zero-copy views of its own shard segments, bitwise identical to
    worker replies, with the served == issued stats invariant intact."""

    def test_local_round_bitwise_matches_workers_and_inproc(self, ds, inproc, client):
        nodes = np.random.default_rng(13).integers(0, ds.graph.num_nodes, 200)
        queries = [(nodes, RELS[0], 4, -1), (nodes[:60], RELS[1], 3, -1)]
        ref = inproc.sample_many(np.random.default_rng(21), queries)
        remote = client.sample_many(np.random.default_rng(21), queries)
        with GraphClient(
            ds.graph, num_partitions=4, num_workers=2, local_threshold=10_000
        ) as c:
            local = c.sample_many(np.random.default_rng(21), queries)
            # the round really was served locally, not by a worker
            assert c.aggregate_stats()["local_neighbor_requests"] == len(nodes) + 60
        for a, b, d in zip(ref, remote, local):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, d)

    def test_rng_stream_identical_across_serving_modes(self, ds):
        """A local round consumes the caller's generator exactly like a
        remote round, so a later query is unaffected by who served earlier
        ones — the property that makes the threshold a pure perf knob."""
        nodes = np.arange(50)
        follow = np.arange(120)
        outs = {}
        for thr in (0, 10_000):
            with GraphClient(
                ds.graph, num_partitions=4, num_workers=1, local_threshold=thr
            ) as c:
                rng = np.random.default_rng(4)
                c.sample_many(rng, [(nodes, RELS[0], 3, -1)])
                outs[thr] = c.sample_many(rng, [(follow, RELS[1], 2, -1)])[0]
        np.testing.assert_array_equal(outs[0], outs[10_000])

    def test_mixed_local_remote_stats_invariant(self, ds):
        with GraphClient(
            ds.graph, num_partitions=4, num_workers=2, local_threshold=100
        ) as c:
            rng = np.random.default_rng(0)
            c.sample_many(rng, [(np.arange(80), RELS[0], 2, -1)])  # local
            c.sample_many(rng, [(np.arange(300), RELS[0], 2, -1)])  # remote
            agg = c.aggregate_stats()
            assert agg["local_neighbor_requests"] == 80
            assert agg["local_batches"] == 1
            # served (workers + local) == issued (client mirror)
            assert agg["neighbor_requests"] == c.stats.neighbor_requests == 380
            c.reset_stats()
            agg = c.aggregate_stats()
            assert agg["neighbor_requests"] == 0
            assert agg["local_neighbor_requests"] == 0

    def test_threshold_zero_is_all_remote(self, ds, client):
        # the module fixture client has local_threshold=0: nothing local
        client.reset_stats()
        client.sample_many(
            np.random.default_rng(1), [(np.arange(16), RELS[0], 2, -1)]
        )
        agg = client.aggregate_stats()
        assert agg["local_neighbor_requests"] == 0
        assert agg["neighbor_requests"] == 16  # all worker-served


class TestPipelineEquivalence:
    def test_walks_egos_pairs_bitwise(self, ds, inproc, client):
        """Fixed seed -> identical TrainBatches from either backend."""
        a = list(SamplePipeline(inproc, _pipe_cfg(), seed=5).batches(3))
        b = list(SamplePipeline(client, _pipe_cfg(), seed=5).batches(3))
        assert len(a) == len(b) == 3
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x.src_ids, y.src_ids)
            np.testing.assert_array_equal(x.dst_ids, y.dst_ids)
            np.testing.assert_array_equal(x.neg_ids, y.neg_ids)
            for ex, ey in ((x.src_ego, y.src_ego), (x.dst_ego, y.dst_ego),
                           (x.neg_ego, y.neg_ego)):
                for lx, ly in zip(ex.levels, ey.levels):
                    np.testing.assert_array_equal(lx, ly)

    def test_training_losses_bitwise(self, ds):
        """engine_backend='mp' reproduces the inproc run loss-for-loss."""
        from repro.core import Graph4RecConfig
        from repro.embedding import EmbeddingConfig
        from repro.train import Graph4RecTrainer, TrainerConfig

        mc = Graph4RecConfig(
            embedding=EmbeddingConfig(num_nodes=ds.graph.num_nodes, dim=16),
            gnn=None, relations=RELS,
        )
        losses = {}
        for backend in ("inproc", "mp"):
            eng = DistributedGraphEngine(ds.graph, num_partitions=4)
            tr = Graph4RecTrainer(
                ds, eng, mc, _pipe_cfg(with_ego=False),
                TrainerConfig(
                    num_steps=8, log_every=0, eval_at_end=False, seed=2,
                    engine_backend=backend, num_engine_workers=2,
                    # force every round across the process boundary — this
                    # test is about the worker-served path specifically
                    engine_local_threshold=0,
                ),
            )
            with tr:
                losses[backend] = tr.train().losses
        assert losses["inproc"] == losses["mp"]


@pytest.mark.quick
class TestStatsAggregation:
    def test_worker_counters_survive_process_boundary(self, ds, inproc, client):
        client.reset_stats()
        inproc.stats.reset()
        rng_a, rng_b = np.random.default_rng(0), np.random.default_rng(0)
        for lo in (0, 40, 160):
            nodes = np.arange(lo, lo + 40)
            inproc.sample_neighbors(rng_a, nodes, RELS[0], 3)
            client.sample_neighbors(rng_b, nodes, RELS[0], 3)
        # client-side mirror matches the in-process engine exactly
        assert client.stats.neighbor_requests == inproc.stats.neighbor_requests == 120
        assert (
            client.stats.cross_partition_requests
            == inproc.stats.cross_partition_requests
        )
        # and the per-worker counters, summed across processes, cover every
        # query the client issued
        agg = client.aggregate_stats()
        assert agg["neighbor_requests"] == client.stats.neighbor_requests
        assert agg["num_workers"] == 2
        per = client.worker_stats()
        assert sum(s["neighbor_requests"] for s in per) == 120
        assert all(s["batches"] > 0 for s in per)

    def test_reset_stats_clears_both_sides(self, client):
        client.sample_neighbors(np.random.default_rng(0), np.arange(20), RELS[0], 2)
        client.reset_stats()
        assert client.stats.neighbor_requests == 0
        assert client.aggregate_stats()["neighbor_requests"] == 0


class TestFailureModes:
    def test_worker_error_raises_with_traceback(self, ds):
        with GraphClient(
            ds.graph, num_partitions=2, num_workers=1, slab_slots=4
        ) as c:
            # more failures than slab slots: error replies must recycle
            # their slot (a leak would wedge the 5th call on reservation)
            for _ in range(6):
                with pytest.raises(EngineWorkerError, match="KeyError"):
                    c.sample_neighbors(
                        np.random.default_rng(0), np.arange(10), "no2such2rel", 2
                    )
            # the worker survives bad requests and keeps serving
            out = c.sample_neighbors(np.random.default_rng(0), np.arange(10), RELS[0], 2)
            assert out.shape == (10, 2)

    def test_worker_crash_raises_not_hangs(self, ds):
        c = GraphClient(ds.graph, num_partitions=2, num_workers=2)
        try:
            c._procs[0].kill()
            with pytest.raises(EngineWorkerError, match="died|unreachable|closed"):
                # several partitions -> some sub-request lands on the corpse
                c.sample_neighbors(np.random.default_rng(0), np.arange(50), RELS[0], 2)
        finally:
            c.shutdown()
        assert all(not p.is_alive() for p in c._procs)

    def test_trainer_propagates_dead_workers_and_reaps(self, ds):
        """A dead engine worker fails train() instead of blocking the queue,
        and the trainer reaps the remaining workers on the way out."""
        from repro.core import Graph4RecConfig
        from repro.embedding import EmbeddingConfig
        from repro.train import Graph4RecTrainer, TrainerConfig

        mc = Graph4RecConfig(
            embedding=EmbeddingConfig(num_nodes=ds.graph.num_nodes, dim=8),
            gnn=None, relations=RELS,
        )
        eng = DistributedGraphEngine(ds.graph, num_partitions=4)
        tr = Graph4RecTrainer(
            ds, eng, mc, _pipe_cfg(with_ego=False),
            TrainerConfig(
                num_steps=50, log_every=0, eval_at_end=False,
                engine_backend="mp", num_engine_workers=2,
                # hybrid serving would answer these tiny rounds in-process
                # and never notice the corpses; this test needs the boundary
                engine_local_threshold=0,
            ),
        )
        client = tr.engine
        for proc in client._procs:
            proc.kill()
        with pytest.raises(EngineWorkerError):
            tr.train()
        # train()'s failure path reaped the service
        assert all(not p.is_alive() for p in client._procs)

    @pytest.mark.quick
    def test_double_shutdown_idempotent(self, ds):
        c = GraphClient(ds.graph, num_partitions=2, num_workers=1)
        c.shutdown()
        c.shutdown()  # second call is a no-op, not an error
        with pytest.raises(RuntimeError):
            c.sample_neighbors(np.random.default_rng(0), np.arange(4), RELS[0], 1)
        # context-manager exit after manual shutdown is fine too
        with GraphClient(ds.graph, num_partitions=2, num_workers=1) as c2:
            c2.shutdown()
        assert all(not p.is_alive() for p in c2._procs)
