"""Encoder-decoder (whisper) and VLM (M-RoPE) model-level tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import qwen2_vl as VLM
from repro.models import transformer as T
from repro.models import whisper as W

KEY = jax.random.PRNGKey(0)


class TestWhisperConsistency:
    def test_decode_matches_teacher_forcing(self):
        spec = get_arch("whisper-tiny", reduced=True)
        cfg = spec.whisper
        params = spec.init_params(KEY)
        B, S = 2, 16
        audio = jax.random.normal(jax.random.PRNGKey(1),
                                  (B, cfg.n_audio_frames, cfg.d_model)) * 0.1
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
        enc = W.encode(params, cfg, audio)
        full = W.decode_train(params, cfg, enc, toks)  # (B, S, Vp)
        cache = W.init_cache(params, cfg, audio, S)
        step = jax.jit(lambda p, c, t: W.decode_step(p, cfg, c, t))
        outs = []
        for i in range(S):
            lg, cache = step(params, cache, toks[:, i : i + 1])
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
        assert rel < 2e-2, rel

    def test_cross_attention_sees_audio(self):
        """Different audio -> different decoder logits (cross-attn is live)."""
        spec = get_arch("whisper-tiny", reduced=True)
        cfg = spec.whisper
        params = spec.init_params(KEY)
        toks = jnp.zeros((1, 4), jnp.int32)
        a1 = jnp.zeros((1, cfg.n_audio_frames, cfg.d_model))
        a2 = jnp.ones((1, cfg.n_audio_frames, cfg.d_model)) * 0.3
        l1 = W.decode_train(params, cfg, W.encode(params, cfg, a1), toks)
        l2 = W.decode_train(params, cfg, W.encode(params, cfg, a2), toks)
        assert not np.allclose(np.asarray(l1), np.asarray(l2))

    def test_padded_vocab_masked(self):
        spec = get_arch("whisper-tiny", reduced=True)
        cfg = spec.whisper
        assert cfg.vocab_padded % 256 == 0
        params = spec.init_params(KEY)
        audio = jnp.zeros((1, cfg.n_audio_frames, cfg.d_model))
        logits = W.decode_train(params, cfg, W.encode(params, cfg, audio),
                                jnp.zeros((1, 2), jnp.int32))
        if cfg.vocab_padded != cfg.vocab:
            assert float(logits[..., cfg.vocab:].max()) < -1e20


class TestVLM:
    def test_patches_change_loss(self):
        spec = get_arch("qwen2-vl-7b", reduced=True)
        cfg = spec.lm
        params = spec.init_params(KEY)
        B, S = 2, 32
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
        labels = jnp.roll(toks, -1, axis=1)
        p1 = jnp.zeros((B, spec.n_patches, cfg.d_model))
        p2 = jax.random.normal(jax.random.PRNGKey(4),
                               (B, spec.n_patches, cfg.d_model)) * 0.1
        l1 = VLM.vlm_loss(params, cfg, toks, labels, p1, spec.grid_hw)
        l2 = VLM.vlm_loss(params, cfg, toks, labels, p2, spec.grid_hw)
        assert float(l1) != float(l2)

    @pytest.mark.quick
    def test_merge_overwrites_image_span(self):
        spec = get_arch("qwen2-vl-7b", reduced=True)
        cfg = spec.lm
        params = spec.init_params(KEY)
        toks = jnp.zeros((1, 32), jnp.int32)
        patches = jnp.full((1, spec.n_patches, cfg.d_model), 7.0, cfg.dtype)
        x = VLM.merge_vision_embeds(params, cfg, toks, patches)
        np.testing.assert_allclose(
            np.asarray(x[0, 1 : 1 + spec.n_patches], np.float32), 7.0)
        # BOS position untouched
        assert not np.allclose(np.asarray(x[0, 0], np.float32), 7.0)

    def test_mrope_gradients_flow_to_patches(self):
        spec = get_arch("qwen2-vl-7b", reduced=True)
        cfg = spec.lm
        params = spec.init_params(KEY)
        toks = jnp.zeros((1, 32), jnp.int32)
        labels = jnp.ones((1, 32), jnp.int32)

        def loss(p_emb):
            return VLM.vlm_loss(params, cfg, toks, labels, p_emb, spec.grid_hw)

        g = jax.grad(loss)(jnp.zeros((1, spec.n_patches, cfg.d_model)))
        assert float(jnp.abs(g).sum()) > 0.0
