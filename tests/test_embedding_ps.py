"""Parameter-server embedding table tests (pull/push semantics, side info,
warm start, row-wise sparse optimizer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.quick

from repro.embedding import (
    EmbeddingConfig, SlotSpec, embed_nodes, init_params, lookup,
    pad_slot_values, ps_lookup, rowwise_adagrad_init, rowwise_adagrad_update,
    warm_start,
)
from repro.launch.mesh import make_host_mesh

KEY = jax.random.PRNGKey(0)


class TestLookup:
    def test_pad_rows_zero(self):
        table = jnp.arange(12.0).reshape(4, 3)
        out = lookup(table, jnp.array([0, -1, 2]))
        np.testing.assert_allclose(np.asarray(out[1]), 0.0)
        np.testing.assert_allclose(np.asarray(out[2]), np.arange(6.0, 9.0))

    def test_ps_lookup_matches_plain(self):
        """Explicit shard_map pull == plain gather (1-device mesh)."""
        mesh = make_host_mesh()
        cfg = EmbeddingConfig(num_nodes=16, dim=4)
        params = init_params(KEY, cfg)
        ids = jnp.array([[0, 5], [15, -1]])
        a = lookup(params["node"], ids)
        b = ps_lookup(params["node"], ids, mesh)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_ps_lookup_grad_is_scatter_add(self):
        """The 'push': cotangent lands only on touched rows."""
        mesh = make_host_mesh()
        cfg = EmbeddingConfig(num_nodes=8, dim=2)
        params = init_params(KEY, cfg)

        def f(tab):
            return ps_lookup(tab, jnp.array([1, 1, 3]), mesh).sum()

        g = jax.grad(f)(params["node"])
        np.testing.assert_allclose(np.asarray(g[1]), 2.0)  # touched twice
        np.testing.assert_allclose(np.asarray(g[3]), 1.0)
        np.testing.assert_allclose(np.asarray(g[0]), 0.0)  # untouched


class TestSideInfo:
    def test_slot_sum_added(self):
        cfg = EmbeddingConfig(
            num_nodes=4, dim=3, slots=(SlotSpec("cat", 5, 2),)
        )
        params = init_params(KEY, cfg)
        ids = jnp.array([0, 1])
        base = embed_nodes(params, ids)
        slots = {"cat": jnp.array([[0, 1], [2, -1]])}
        out = embed_nodes(params, ids, slots)
        expect0 = base[0] + params["slot:cat"][0] + params["slot:cat"][1]
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect0), rtol=1e-5)
        expect1 = base[1] + params["slot:cat"][2]  # PAD value ignored
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(expect1), rtol=1e-5)

    def test_pad_slot_values(self):
        indptr = np.array([0, 2, 2, 5])
        values = np.array([7, 8, 1, 2, 3], dtype=np.int32)
        out = pad_slot_values(indptr, values, np.array([0, 1, 2]), max_values=2)
        np.testing.assert_array_equal(out[0], [7, 8])
        np.testing.assert_array_equal(out[1], [-1, -1])
        np.testing.assert_array_equal(out[2], [1, 2])  # truncated to max_values


class TestWarmStart:
    def test_shape_matched_tables_inherited(self):
        cfg = EmbeddingConfig(num_nodes=6, dim=4)
        params = init_params(KEY, cfg)
        pre = {"node": np.ones((6, 4), np.float32), "bogus": np.ones((2, 2))}
        out = warm_start(dict(params), pre)
        np.testing.assert_allclose(np.asarray(out["node"]), 1.0)

    def test_shape_mismatch_ignored(self):
        cfg = EmbeddingConfig(num_nodes=6, dim=4)
        params = init_params(KEY, cfg)
        pre = {"node": np.ones((5, 4), np.float32)}
        out = warm_start(dict(params), pre)
        np.testing.assert_allclose(np.asarray(out["node"]), np.asarray(params["node"]))


class TestRowAdagrad:
    def test_untouched_rows_unchanged(self):
        params = {"node": jnp.ones((4, 3))}
        grads = {"node": jnp.zeros((4, 3)).at[1].set(1.0)}
        state = rowwise_adagrad_init(params)
        new, state = rowwise_adagrad_update(params, grads, state, lr=0.1)
        np.testing.assert_allclose(np.asarray(new["node"][0]), 1.0)
        assert (np.asarray(new["node"][1]) < 1.0).all()
