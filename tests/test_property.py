"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

pytestmark = pytest.mark.quick

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.graph.hetero_graph import HeteroGraph, _csr_from_pairs
from repro.kernels import ops, ref
from repro.sampling.pairs import window_pairs
from repro.core.recall import evaluate_recall

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def edge_lists(draw):
    n_u = draw(st.integers(2, 12))
    n_i = draw(st.integers(2, 12))
    n_e = draw(st.integers(1, 40))
    src = draw(st.lists(st.integers(0, n_u - 1), min_size=n_e, max_size=n_e))
    dst = draw(st.lists(st.integers(0, n_i - 1), min_size=n_e, max_size=n_e))
    return n_u, n_i, np.array(src), np.array(dst)


class TestGraphInvariants:
    @given(edge_lists())
    @settings(**SETTINGS)
    def test_csr_roundtrip(self, data):
        n_u, n_i, src, dst = data
        g = HeteroGraph.from_edges(
            {"u": n_u, "i": n_i}, {"u2click2i": (src, dst)}, symmetry=True
        )
        csr = g.relations["u2click2i"]
        # every edge present exactly once
        rebuilt = []
        for v in range(g.num_nodes):
            for x in csr.neighbors(v):
                rebuilt.append((v, int(x)))
        expect = sorted(zip(src.tolist(), (dst + n_u).tolist()))
        assert sorted(rebuilt) == expect

    @given(edge_lists())
    @settings(**SETTINGS)
    def test_symmetry_is_transpose(self, data):
        n_u, n_i, src, dst = data
        g = HeteroGraph.from_edges(
            {"u": n_u, "i": n_i}, {"u2click2i": (src, dst)}, symmetry=True
        )
        fwd = g.relations["u2click2i"]
        rev = g.relations["i2click2u"]
        fwd_edges = sorted(
            (v, int(x)) for v in range(g.num_nodes) for x in fwd.neighbors(v)
        )
        rev_edges = sorted(
            (int(x), v) for v in range(g.num_nodes) for x in rev.neighbors(v)
        )
        assert fwd_edges == rev_edges

    @given(edge_lists(), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
    @settings(**SETTINGS)
    def test_sampled_neighbors_are_neighbors(self, data, k, seed):
        n_u, n_i, src, dst = data
        g = HeteroGraph.from_edges(
            {"u": n_u, "i": n_i}, {"u2click2i": (src, dst)}, symmetry=True
        )
        rng = np.random.default_rng(seed)
        nodes = np.arange(g.num_nodes)
        out = g.sample_neighbors(rng, nodes, "u2click2i", k)
        for row, v in zip(out, nodes):
            nbrs = set(g.relations["u2click2i"].neighbors(v).tolist())
            assert all((x == -1 and not nbrs) or x in nbrs for x in row)


class TestPairInvariants:
    @given(
        st.integers(2, 8),  # L
        st.integers(1, 4),  # win
        st.integers(1, 5),  # B
        st.integers(0, 2 ** 31 - 1),
    )
    @settings(**SETTINGS)
    def test_window_pairs_within_window(self, L, win, B, seed):
        rng = np.random.default_rng(seed)
        paths = rng.integers(0, 50, size=(B, L))
        # randomly truncate with PAD suffixes
        for b in range(B):
            cut = rng.integers(1, L + 1)
            paths[b, cut:] = -1
        pairs = window_pairs(paths, win)
        for r, sc, dc in pairs:
            assert sc != dc
            assert abs(sc - dc) <= win
            assert paths[r, sc] != -1 and paths[r, dc] != -1


class TestJaxWalkProperties:
    """Invariants of the on-device walker (walk/metapath.py:jax_walk_multi)
    on randomly generated heterographs: PAD propagation (once PAD, always
    PAD), walk-length/shape invariants, and metapath type chaining."""

    @staticmethod
    def _walk_setup(data, walk_len, max_degree=8):
        from repro.graph.hetero_graph import HeteroGraph

        n_u, n_i, src, dst = data
        g = HeteroGraph.from_edges(
            {"u": n_u, "i": n_i}, {"u2click2i": (src, dst)}, symmetry=True
        )
        rels = ["u2click2i", "i2click2u"]
        adj, deg = zip(*(g.padded_adjacency(r, max_degree) for r in rels))
        sched = np.array(
            [[k % 2 for k in range(max(walk_len - 1, 1))]], dtype=np.int32
        )  # u2click2i, i2click2u, u2click2i, ...
        return g, jnp.asarray(np.stack(adj)), jnp.asarray(np.stack(deg)), sched

    @given(edge_lists(), st.integers(2, 7), st.integers(0, 2 ** 31 - 1))
    @settings(**SETTINGS)
    def test_pad_propagates_and_shape(self, data, walk_len, seed):
        from repro.walk import jax_walk_multi

        g, adj, deg, sched = self._walk_setup(data, walk_len)
        n_u = data[0]
        starts = np.concatenate([np.arange(n_u), [-1]])  # include a PAD start
        out = np.asarray(jax_walk_multi(
            jax.random.PRNGKey(seed % (2 ** 31)), adj, deg,
            jnp.asarray(starts), jnp.asarray(sched),
            jnp.zeros(len(starts), jnp.int32), walk_len,
        ))
        assert out.shape == (len(starts), walk_len)
        np.testing.assert_array_equal(out[:, 0], starts)
        for row in out:
            seen_pad = False
            for x in row[1:]:
                if x == -1:
                    seen_pad = True
                else:
                    assert not seen_pad  # once PAD, always PAD
        assert (out[-1, 1:] == -1).all()  # PAD start stays PAD

    @given(edge_lists(), st.integers(0, 2 ** 31 - 1))
    @settings(**SETTINGS)
    def test_metapath_type_chaining(self, data, seed):
        """Every non-PAD node at step t has the type the metapath's t-th
        relation produces (u at even steps, i at odd steps)."""
        from repro.walk import jax_walk_multi

        walk_len = 6
        g, adj, deg, sched = self._walk_setup(data, walk_len)
        n_u = data[0]
        out = np.asarray(jax_walk_multi(
            jax.random.PRNGKey(seed % (2 ** 31)), adj, deg,
            jnp.arange(n_u), jnp.asarray(sched),
            jnp.zeros(n_u, jnp.int32), walk_len,
        ))
        for row in out:
            for t, x in enumerate(row):
                if x == -1:
                    continue
                assert (x < n_u) == (t % 2 == 0)

    @given(edge_lists(), st.integers(0, 2 ** 31 - 1))
    @settings(**SETTINGS)
    def test_steps_are_true_neighbors(self, data, seed):
        from repro.walk import jax_walk_multi

        walk_len = 5
        g, adj, deg, sched = self._walk_setup(data, walk_len)
        n_u = data[0]
        rels = ["u2click2i", "i2click2u"]
        out = np.asarray(jax_walk_multi(
            jax.random.PRNGKey(seed % (2 ** 31)), adj, deg,
            jnp.arange(n_u), jnp.asarray(sched),
            jnp.zeros(n_u, jnp.int32), walk_len,
        ))
        for row in out:
            for t in range(1, walk_len):
                if row[t] == -1:
                    break
                nbrs = g.relations[rels[(t - 1) % 2]].neighbors(int(row[t - 1]))
                assert int(row[t]) in nbrs

    @given(edge_lists(), st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
    @settings(**SETTINGS)
    def test_single_relation_wrapper_consistent(self, data, walk_len, seed):
        """jax_walk (the degenerate single-relation case) emits nodes of the
        collapsed relation's adjacency and respects PAD semantics."""
        from repro.walk import jax_walk

        g, adj, deg, _ = self._walk_setup(data, walk_len)
        n_u = data[0]
        out = np.asarray(jax_walk(
            jax.random.PRNGKey(seed % (2 ** 31)), adj[0], deg[0],
            jnp.arange(n_u), walk_len,
        ))
        assert out.shape == (n_u, walk_len)
        padded = np.asarray(adj[0])
        for row in out:
            for t in range(1, walk_len):
                if row[t] == -1:
                    break
                assert int(row[t]) in padded[int(row[t - 1])]


class TestKernelProperties:
    @given(
        st.integers(1, 40),  # N
        st.integers(1, 9),  # F
        st.integers(1, 200),  # D
        st.sampled_from(["mean", "sum", "max"]),
        st.integers(0, 2 ** 31 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_seg_aggr_matches_oracle(self, N, F, D, mode, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed % (2 ** 31)))
        x = jax.random.normal(k1, (N, F, D))
        mask = jax.random.bernoulli(k2, 0.5, (N, F))
        got = ops.seg_aggr(x, mask, mode=mode)
        want = ref.seg_aggr_ref(x, mask, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    @given(st.integers(2, 80), st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_inbatch_loss_matches_oracle(self, P, d, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed % (2 ** 31)))
        hs = jax.random.normal(k1, (P, d))
        hd = jax.random.normal(k2, (P, d))
        got = float(ops.inbatch_loss(hs, hd, 1.0))
        want = float(ref.inbatch_loss_ref(hs, hd, 1.0))
        np.testing.assert_allclose(got, want, rtol=1e-4)

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_inbatch_loss_lower_bound(self, seed):
        """CE over P classes is >= 0 and == log P for identical rows."""
        P, d = 16, 8
        hs = jax.random.normal(jax.random.PRNGKey(seed % (2 ** 31)), (P, d))
        loss = float(ops.inbatch_loss(hs, jnp.zeros((P, d))))
        np.testing.assert_allclose(loss, np.log(P), rtol=1e-5)


class TestRecallProperties:
    @given(st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_recall_bounds(self, seed):
        rng = np.random.default_rng(seed % (2 ** 31))
        U, I, d = 10, 20, 4
        ue = rng.normal(size=(U, d))
        ie = rng.normal(size=(I, d))
        train = np.stack([rng.integers(0, U, 30), rng.integers(0, I, 30)], 1)
        evalp = np.stack([rng.integers(0, U, 10), rng.integers(0, I, 10)], 1)
        out = evaluate_recall(ue, ie, train, evalp, top_k=5)
        for v in out.values():
            assert 0.0 <= v <= 1.0

    def test_perfect_embeddings_perfect_u2i(self):
        """Users colinear with their single held-out item -> recall 1."""
        U = I = 8
        ue = np.eye(U)
        ie = np.eye(I)
        train = np.stack([np.arange(U), (np.arange(U) + 1) % I], 1)
        evalp = np.stack([np.arange(U), np.arange(I)], 1)
        # u2i: user u retrieves item u first (identical embedding)
        out = evaluate_recall(ue, ie, train, evalp, top_k=1)
        assert out["u2i"] == 1.0
