"""Pure-JAX optimizer tests (no optax offline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as O

pytestmark = pytest.mark.quick


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["emb/t"] - 1.0) ** 2)


def run(opt, steps=200):
    params = {"w": jnp.zeros((4,)), "emb/t": jnp.zeros((4,))}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(quad_loss)(params)
        updates, state = opt.update(grads, state, params)
        params = O.apply_updates(params, updates)
    return params


@pytest.mark.parametrize("opt", [
    O.sgd(0.1), O.sgd(0.05, momentum=0.9), O.adagrad(0.5), O.adam(0.1),
    O.adamw(0.1, weight_decay=0.0),
])
def test_converges_on_quadratic(opt):
    params = run(opt)
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0, atol=0.05)


def test_adamw_decays_weights():
    opt_wd = O.adamw(0.05, weight_decay=0.1)
    opt_no = O.adam(0.05)
    p_wd = run(opt_wd, steps=300)
    p_no = run(opt_no, steps=300)
    # decay pulls the optimum below 3.0
    assert float(p_wd["w"][0]) < float(p_no["w"][0])


def test_masked_routes_by_key():
    opt = O.masked(O.adagrad(1.0), O.sgd(0.0), select_a=lambda k: k.startswith("emb/"))
    params = {"w": jnp.zeros((2,)), "emb/t": jnp.zeros((2,))}
    state = opt.init(params)
    grads = {"w": jnp.ones((2,)), "emb/t": jnp.ones((2,))}
    updates, _ = opt.update(grads, state, params)
    assert float(jnp.abs(updates["emb/t"]).sum()) > 0  # adagrad moved
    np.testing.assert_allclose(np.asarray(updates["w"]), 0.0)  # lr 0 sgd


def test_clip_by_global_norm():
    updates = {"a": jnp.full((3,), 10.0)}
    clipped = O.clip_by_global_norm(updates, 1.0)
    norm = float(jnp.linalg.norm(clipped["a"]))
    np.testing.assert_allclose(norm, 1.0, rtol=1e-5)


def test_adam_state_pytree_matches_params():
    opt = O.adam(1e-3)
    params = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    assert state.mu["a"].shape == (2, 3) and state.nu["b"].shape == (4,)
