"""Gather→step→scatter sparse training tests: sparse-vs-dense equivalence
(params + losses after K steps, across model families, slot modes, and the
ps_lookup/shard_map pull path), padded-bucket edge cases, the fused Pallas
row-AdaGrad kernel, and the O(batch)-not-O(N) regression guard."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Graph4RecConfig, HeteroGNNConfig
from repro.embedding import (
    EmbeddingConfig, SlotSpec, gather_rows, lookup, ps_lookup, remap_ids,
    rowwise_adagrad_init, rowwise_adagrad_scatter_update, scatter_rows,
    unique_pad_ids,
)
from repro.graph import DistributedGraphEngine, TOY, generate
from repro.launch.mesh import make_host_mesh
from repro.sampling import EgoConfig, PairConfig, PipelineConfig
from repro.train import Graph4RecTrainer, TrainerConfig
from repro.train import optimizer as opt_lib
from repro.walk import WalkConfig

pytestmark = pytest.mark.quick

RELS = ("u2click2i", "i2click2u")


@pytest.fixture(scope="module")
def ds():
    return generate(TOY, seed=0)


def build_trainer(ds, sparse, gnn_type="lightgcn", side_info=False,
                  slot_mode="bag", loss="inbatch_softmax", steps=12, **cfg_kw):
    g = ds.graph
    slots = (
        (SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 3)) if side_info else ()
    )
    walk_based = gnn_type is None
    mc = Graph4RecConfig(
        embedding=EmbeddingConfig(num_nodes=g.num_nodes, dim=16, slots=slots),
        gnn=None if walk_based else HeteroGNNConfig(
            gnn_type=gnn_type, num_relations=2, num_layers=2, dim=16),
        fanouts=() if walk_based else (3, 2),
        relations=RELS,
        use_side_info=side_info,
        slot_mode=slot_mode,
        loss=loss,
    )
    pc = PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=5),
        pair=PairConfig(win_size=2,
                        neg_mode="random" if loss == "neg_sampling" else "inbatch"),
        ego=None if walk_based else EgoConfig(relations=list(RELS), fanouts=[3, 2]),
        batch_pairs=64, walks_per_round=32,
    )
    eng = DistributedGraphEngine(g, num_partitions=2)
    # The toy graph sits below the default sparse/dense crossover
    # (sparse_min_rows) — force the sparse path so these tests keep
    # exercising gather→step→scatter rather than the dense reroute.
    cfg_kw.setdefault("sparse_min_rows", 0)
    return Graph4RecTrainer(
        ds, eng, mc, pc,
        TrainerConfig(num_steps=steps, log_every=0, seed=0, sparse_lr=0.5,
                      prefetch_batches=0, eval_at_end=False,
                      sparse_updates=sparse, **cfg_kw),
    )


def assert_runs_match(rs, rd, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(rs.losses, rd.losses, rtol=rtol, atol=atol)
    assert rs.params.keys() == rd.params.keys()
    for k in rs.params:
        np.testing.assert_allclose(
            np.asarray(rs.params[k]), np.asarray(rd.params[k]),
            rtol=rtol, atol=atol, err_msg=k,
        )


class TestSparseDenseEquivalence:
    @pytest.mark.parametrize("kw", [
        dict(gnn_type=None),
        dict(gnn_type="lightgcn"),
        dict(gnn_type="lightgcn", side_info=True, slot_mode="bag"),
        dict(gnn_type=None, side_info=True, slot_mode="values"),
        dict(gnn_type=None, loss="neg_sampling"),
    ], ids=["walk", "gnn", "gnn-bag", "walk-values", "walk-negsamp"])
    def test_k_steps_match(self, ds, kw):
        rs = build_trainer(ds, sparse=True, **kw).train()
        rd = build_trainer(ds, sparse=False, **kw).train()
        assert_runs_match(rs, rd)

    def test_bucket_overflow_still_exact(self, ds):
        """Batches touching more unique ids than the initial bucket width:
        the bucket grows (power-of-two recompile), results stay exact."""
        tr = build_trainer(ds, sparse=True, unique_bucket=8)
        assert tr._buckets["node"] == 8
        rs = tr.train()
        assert tr._buckets["node"] > 8  # grew past the deliberately-tiny seed
        rd = build_trainer(ds, sparse=False).train()
        assert_runs_match(rs, rd)

    def test_untouched_slot_tables_pass_through(self, ds):
        """Slot tables exist but side info is off: the batch never touches
        them, the sparse step must leave them (and training) intact."""
        tr = build_trainer(ds, sparse=True, gnn_type=None, steps=4)
        mc = tr.model_cfg
        mc = dataclasses.replace(
            mc,
            embedding=dataclasses.replace(
                mc.embedding, slots=(SlotSpec("ghost", 16, 2),)
            ),
            use_side_info=False,
        )
        tr2 = Graph4RecTrainer(ds, tr.engine, mc, tr.pipe_cfg, tr.cfg)
        params0 = tr2.init_params()
        ghost0 = np.asarray(params0["emb/slot:ghost"]).copy()
        res = tr2.train(params0)
        assert np.isfinite(res.losses).all()
        np.testing.assert_array_equal(
            np.asarray(res.params["emb/slot:ghost"]), ghost0
        )

    def test_kernel_rowopt_matches(self, ds):
        """Fused Pallas gather/AdaGrad/scatter == the XLA scatter path."""
        rs = build_trainer(ds, sparse=True, use_kernel_rowopt=True,
                           gnn_type=None, steps=6).train()
        rd = build_trainer(ds, sparse=False, gnn_type=None, steps=6).train()
        assert_runs_match(rs, rd)


class TestPsLookupEquivalence:
    def test_sparse_scatter_matches_ps_lookup_training(self):
        """K manual steps where embeddings are pulled via the shard_map
        ps_lookup (dense grads, full-table row-wise AdaGrad) vs the
        gather→step→scatter path — identical tables."""
        mesh = make_host_mesh()
        N, D, K = 32, 8, 6
        rng = np.random.default_rng(0)
        table_a = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
        table_b = table_a
        dense_opt = opt_lib.rowwise_adagrad(0.3, init_accum=0.1)
        st_a = dense_opt.init({"node": table_a})
        st_b = rowwise_adagrad_init({"node": table_b}, init_accum=0.1)
        batches = [rng.integers(0, N, size=24) for _ in range(K)]
        # a PAD in the batch exercises the masking on both paths
        batches[2][0] = -1

        def loss_ps(tab, ids):
            return (ps_lookup(tab, ids, mesh) ** 2).mean()

        def loss_local(sub, local_ids):
            return (lookup(sub, local_ids) ** 2).mean()

        for ids in batches:
            ids_j = jnp.asarray(ids)
            g = jax.grad(loss_ps)(table_a, ids_j)
            upd, st_a = dense_opt.update({"node": g}, st_a)
            table_a = table_a + upd["node"]

            uniq = unique_pad_ids([ids], bucket=64)
            local = jnp.asarray(remap_ids(uniq, ids))
            uniq_j = jnp.asarray(uniq)
            sub = gather_rows(table_b, uniq_j)
            g_sub = jax.grad(loss_local)(sub, local)
            new_p, st_b = rowwise_adagrad_scatter_update(
                {"node": table_b}, {"node": g_sub}, {"node": uniq_j}, st_b,
                lr=0.3,
            )
            table_b = new_p["node"]
        np.testing.assert_allclose(
            np.asarray(table_a), np.asarray(table_b), rtol=1e-5, atol=1e-6
        )


class TestUniqueBucketHelpers:
    def test_unique_pad_ids_layout(self):
        uniq = unique_pad_ids([np.array([5, 3, 5, -1, 9])], bucket=8)
        np.testing.assert_array_equal(uniq, [-1, -1, -1, -1, -1, 3, 5, 9])

    def test_bucket_grows_power_of_two(self):
        uniq = unique_pad_ids([np.arange(20)], bucket=8)
        assert len(uniq) == 32

    def test_remap_roundtrip(self):
        ids = np.array([[7, 2], [-1, 11]])
        uniq = unique_pad_ids([ids], bucket=8)
        local = remap_ids(uniq, ids)
        assert local[1, 0] == -1
        np.testing.assert_array_equal(uniq[local[local >= 0]], ids[ids >= 0])

    def test_scatter_rows_drops_pads(self):
        table = jnp.zeros((4, 2))
        uniq = jnp.asarray([-1, -1, 1, 3])
        rows = jnp.ones((4, 2))
        out = scatter_rows(table, uniq, rows)
        np.testing.assert_allclose(np.asarray(out), [[0, 0], [1, 1], [0, 0], [1, 1]])


class TestCostFlatInTableSize:
    def test_sparse_step_cost_does_not_scale_with_rows(self):
        """Regression guard: the sparse step is O(unique ids) — timing it on
        a 10k-row vs a 100k-row table at fixed batch must stay in the same
        ballpark (a dense update would be ~10x)."""
        B, D, bucket = 256, 32, 512
        lr = 0.5

        def make_step():
            def step(table, accum, uniq, local):
                sub = gather_rows(table, uniq)

                def loss_of(s):
                    return (lookup(s, local) ** 2).mean()

                g = jax.grad(loss_of)(sub)
                new_p, st = rowwise_adagrad_scatter_update(
                    {"t": table}, {"t": g}, {"t": uniq},
                    rowwise_adagrad_init({"t": table}), lr=lr,
                )
                return new_p["t"], st.accum["t"]

            return jax.jit(step, donate_argnums=(0, 1))

        def time_step(N):
            rng = np.random.default_rng(0)
            table = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
            accum = jnp.full((N, 1), 0.1, jnp.float32)
            ids = rng.integers(0, N, size=B)
            uniq = unique_pad_ids([ids], bucket=bucket)
            local = jnp.asarray(remap_ids(uniq, ids))
            uniq_j = jnp.asarray(uniq)
            step = make_step()
            table, accum = step(table, accum, uniq_j, local)  # compile
            jax.block_until_ready(table)
            best = float("inf")
            for _ in range(5):
                t0 = time.perf_counter()
                for _ in range(20):
                    table, accum = step(table, accum, uniq_j, local)
                jax.block_until_ready(table)
                best = min(best, (time.perf_counter() - t0) / 20)
            return best

        t_small = time_step(10_000)
        t_large = time_step(100_000)
        # flat in N up to noise; a dense O(N) update would be ~10x
        assert t_large < t_small * 4 + 1e-4, (t_small, t_large)
