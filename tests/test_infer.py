"""Full-graph inference (repro.infer) + checkpoint export round-trips.

The ISSUE-4 acceptance surface: ``embed_all_nodes`` covers every node in
fixed-shape batches, produces bitwise-identical matrices through the
in-process and multi-process engine backends under a fixed seed (the PR-3
determinism contract), exports/reloads shards through train/checkpoint.py,
and the trainer's evaluate() routes through the new retrieval path with
its former hard-coded knobs exposed as config.
"""
import os
import signal

import jax
import numpy as np
import pytest

from repro.core.model import init_model_params
from repro.graph import DistributedGraphEngine, GraphClient
from repro.infer import embed_all_nodes, export_embeddings, load_embeddings
from repro.train import checkpoint

from conftest import RELS


@pytest.fixture(scope="module")
def ds(toy_ds):
    # shared session dataset + model-config factory live in tests/conftest.py
    return toy_ds


class TestEmbedAllNodes:
    @pytest.mark.quick
    def test_walk_based_covers_every_node_any_batch(self, ds, make_model_cfg):
        g = ds.graph
        cfg = make_model_cfg(g, gnn=False)
        params = init_model_params(jax.random.PRNGKey(0), cfg)
        # walk-based inference is deterministic: chunking must not matter,
        # including a tail chunk (batch does not divide num_nodes)
        e1 = embed_all_nodes(params, cfg, g, g, batch_size=77)
        e2 = embed_all_nodes(params, cfg, g, g, batch_size=g.num_nodes)
        assert e1.shape == (g.num_nodes, 16)
        assert np.array_equal(e1, e2)
        # equals a direct full-table encode
        from repro.core.model import encode_ids

        direct = np.asarray(
            encode_ids(params, cfg, np.arange(g.num_nodes)), dtype=np.float32
        )
        assert np.array_equal(e1, direct)

    @pytest.mark.quick
    def test_gnn_fixed_seed_deterministic(self, ds, make_model_cfg):
        g = ds.graph
        cfg = make_model_cfg(g)
        params = init_model_params(jax.random.PRNGKey(1), cfg)
        eng = DistributedGraphEngine(g, num_partitions=4)
        e1 = embed_all_nodes(params, cfg, eng, g, batch_size=96, seed=11)
        e2 = embed_all_nodes(params, cfg, eng, g, batch_size=96, seed=11)
        assert np.array_equal(e1, e2)
        e3 = embed_all_nodes(params, cfg, eng, g, batch_size=96, seed=12)
        assert not np.array_equal(e1, e3)  # sampling stream actually used

    @pytest.mark.quick
    def test_side_info_values_mode(self, ds, make_model_cfg):
        g = ds.graph
        import dataclasses

        cfg = dataclasses.replace(make_model_cfg(g, side_info=True), slot_mode="values")
        params = init_model_params(jax.random.PRNGKey(2), cfg)
        eng = DistributedGraphEngine(g, num_partitions=2)
        e = embed_all_nodes(params, cfg, eng, g, batch_size=128, seed=0)
        assert e.shape == (g.num_nodes, 16) and np.isfinite(e).all()

    @pytest.mark.mp
    def test_inproc_vs_mp_bitwise_identical(self, ds, make_model_cfg):
        """The acceptance criterion: both engine backends produce the same
        matrix bit for bit under a fixed seed, in fixed-shape batches."""

        def _expired(signum, frame):
            raise TimeoutError("embed mp equivalence exceeded watchdog")

        old = signal.signal(signal.SIGALRM, _expired)
        signal.alarm(120)
        try:
            g = ds.graph
            cfg = make_model_cfg(g)
            params = init_model_params(jax.random.PRNGKey(3), cfg)
            eng = DistributedGraphEngine(g, num_partitions=4)
            e_in = embed_all_nodes(params, cfg, eng, g, batch_size=100, seed=7)
            with GraphClient(g, num_partitions=4, num_workers=2) as client:
                e_mp = embed_all_nodes(
                    params, cfg, client, g, batch_size=100, seed=7
                )
            assert np.array_equal(e_in, e_mp)
        finally:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, old)


class TestExportEmbeddings:
    @pytest.mark.quick
    def test_shard_roundtrip(self, tmp_path):
        emb = np.arange(7 * 3, dtype=np.float32).reshape(7, 3)
        path = export_embeddings(str(tmp_path / "emb"), emb, num_shards=3)
        assert path.endswith(".npz") and os.path.exists(path)
        back = load_embeddings(str(tmp_path / "emb"))
        assert np.array_equal(back, emb)
        # loading via the real on-disk name works too
        assert np.array_equal(load_embeddings(path), emb)

    @pytest.mark.quick
    def test_more_shards_than_rows_clamped(self, tmp_path):
        emb = np.ones((2, 4), np.float32)
        export_embeddings(str(tmp_path / "e"), emb, num_shards=16)
        assert np.array_equal(load_embeddings(str(tmp_path / "e")), emb)

    @pytest.mark.quick
    def test_corrupt_meta_raises(self, tmp_path):
        emb = np.ones((4, 2), np.float32)
        path = export_embeddings(str(tmp_path / "c"), emb, num_shards=2)
        tree = checkpoint.load_dict(path)
        tree["meta"]["num_nodes"] = np.int64(99)
        checkpoint.save(path, tree)
        with pytest.raises(ValueError, match="corrupt"):
            load_embeddings(path)


class TestCheckpointPathNormalization:
    @pytest.mark.quick
    def test_suffixless_roundtrip(self, tmp_path):
        """The historic asymmetry: np.savez silently appends .npz, so
        save(p); load_flat(p) failed for suffix-less paths."""
        tree = {"a": np.arange(3), "b": {"c": np.ones((2, 2))}}
        p = str(tmp_path / "ckpt")  # no suffix
        written = checkpoint.save(p, tree)
        assert written == p + ".npz"
        flat = checkpoint.load_flat(p)
        assert set(flat) == {"a", "b|c"}
        d = checkpoint.load_dict(p)
        assert np.array_equal(d["a"], tree["a"])
        assert np.array_equal(d["b"]["c"], tree["b"]["c"])

    @pytest.mark.quick
    def test_suffixed_roundtrip_unchanged(self, tmp_path):
        p = str(tmp_path / "ckpt.npz")
        assert checkpoint.save(p, {"x": np.zeros(1)}) == p
        assert set(checkpoint.load_flat(p)) == {"x"}


class TestTrainerEvalRouting:
    @pytest.mark.quick
    def test_evaluate_routes_through_retrieval_config(self, ds, make_model_cfg):
        """Satellite: evaluate() uses the new path; method/top_n/max_users
        come from TrainerConfig, and device == bruteforce exactly."""
        from repro.sampling import EgoConfig, PairConfig, PipelineConfig
        from repro.train import Graph4RecTrainer, TrainerConfig
        from repro.walk import WalkConfig

        g = ds.graph
        cfg = make_model_cfg(g)
        pc = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=5),
            pair=PairConfig(win_size=2),
            ego=EgoConfig(relations=list(RELS), fanouts=[4, 3]),
            batch_pairs=64, walks_per_round=32,
        )
        eng = DistributedGraphEngine(g, num_partitions=4)
        results = {}
        for method in ("device", "bruteforce"):
            tr = Graph4RecTrainer(
                ds, eng, cfg, pc,
                TrainerConfig(num_steps=1, log_every=0, eval_method=method,
                              eval_top_k=30, eval_top_n=6, seed=0),
            )
            params = tr.init_params()
            results[method] = tr.evaluate(params)
        assert results["device"] == results["bruteforce"]
        assert "u2i_ndcg" in results["device"]
