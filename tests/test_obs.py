"""Unified telemetry layer tests (repro.obs + its instrumentation).

Pins the contracts the observability PR introduced:

- metrics registry math: counters/gauges, fixed-bucket histogram percentile
  interpolation (exact values, not ranges),
- tracer semantics: per-thread rings, bounded overflow with drop counts,
  nesting, cross-process ingest with clock-offset correction,
- Chrome trace-event export schema (the shape Perfetto loads): "M" metadata
  + "X" complete events, microsecond ts/dur, per-process pid tracks, rid
  args passthrough — and that a disabled run emits nothing,
- trainer integration: a traced in-process run records spans from both the
  step loop and the prefetch thread without enabling attribution; a traced
  mp run shows >= 3 processes on one timeline with client rounds and worker
  serve spans correlated by rid,
- the worker stats conservation law ``shm_replies + pickle_replies ==
  batches`` on both serve paths (slab and pipe-pickle fallback), and the
  diagnostic context (worker_id / rid / stats) riding on EngineWorkerError.
"""
import contextlib
import json
import signal
import threading

import numpy as np
import pytest

from repro.graph import DistributedGraphEngine, GraphClient, TOY, generate
from repro.graph.service import EngineWorkerError
from repro.obs import (
    DEFAULT_NS_BUCKETS,
    Histogram,
    MetricsRegistry,
    Telemetry,
    Tracer,
    chrome_trace,
    span_scope,
    trace_events,
)

RELS = ("u2click2i", "i2click2u")

HARD_TIMEOUT_S = 120


@pytest.fixture
def watchdog():
    """Hard per-test timeout for the mp tests (mirrors test_graph_service)."""

    def _expired(signum, frame):
        raise TimeoutError(f"test exceeded hard {HARD_TIMEOUT_S}s watchdog")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="module")
def ds():
    return generate(TOY, seed=0)


def make_trainer(ds, steps=6, engine_backend="inproc", **cfg_kw):
    from repro.core import Graph4RecConfig, HeteroGNNConfig
    from repro.embedding import EmbeddingConfig
    from repro.sampling import EgoConfig, PairConfig, PipelineConfig
    from repro.train import Graph4RecTrainer, TrainerConfig
    from repro.walk import WalkConfig

    mc = Graph4RecConfig(
        embedding=EmbeddingConfig(num_nodes=ds.graph.num_nodes, dim=16),
        gnn=HeteroGNNConfig(gnn_type="lightgcn", num_relations=2,
                            num_layers=1, dim=16),
        fanouts=(3,),
        relations=RELS,
        loss="inbatch_softmax",
    )
    pc = PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
        pair=PairConfig(win_size=2),
        ego=EgoConfig(relations=list(RELS), fanouts=[3]),
        batch_pairs=64, walks_per_round=16,
    )
    engine = (
        ds.graph if engine_backend == "mp"
        else DistributedGraphEngine(ds.graph, num_partitions=2)
    )
    cfg = TrainerConfig(num_steps=steps, log_every=0, eval_at_end=False,
                        seed=0, engine_backend=engine_backend, **cfg_kw)
    return Graph4RecTrainer(ds, engine, mc, pc, cfg)


# --------------------------------------------------------------- metrics
@pytest.mark.quick
class TestMetrics:
    def test_counter_and_gauge(self):
        reg = MetricsRegistry()
        c = reg.counter("x")
        c.inc()
        c.inc(3)
        assert c.value == 4
        assert reg.counter("x") is c  # get-or-create returns the same object
        g = reg.gauge("q")
        g.set(5)
        g.set(2)
        assert g.value == 2.0
        assert g.max == 5.0

    def test_histogram_pinned_percentiles(self):
        """Exact fixed-bucket interpolation on a hand-checkable ladder."""
        h = Histogram("lat", buckets=[10, 20, 40])
        for v in (5, 15, 30, 100):
            h.observe(v)
        assert h.count == 4
        assert h.sum == 150.0
        # rank(p50) = 2 lands at the top of bucket (10, 20]
        assert h.percentile(50.0) == pytest.approx(20.0)
        # rank(p99) = 3.96 lands in the overflow bucket -> its lower edge
        assert h.percentile(99.0) == pytest.approx(40.0)

    def test_histogram_interpolates_within_bucket(self):
        h = Histogram("lat", buckets=[10, 20, 40])
        h.observe(15)  # sole sample, bucket (10, 20]
        assert h.percentile(50.0) == pytest.approx(15.0)
        # below the first boundary interpolates from 0
        h2 = Histogram("lat2", buckets=[10, 20, 40])
        h2.observe(4)
        assert h2.percentile(50.0) == pytest.approx(5.0)

    def test_histogram_empty_and_bad_buckets(self):
        h = Histogram("lat")
        assert h.percentile(50.0) == 0.0
        assert h.summary() == {"count": 0, "sum": 0.0, "p50": 0.0, "p99": 0.0}
        with pytest.raises(ValueError):
            Histogram("bad", buckets=[20, 10])
        with pytest.raises(ValueError):
            Histogram("bad", buckets=[])

    def test_default_ladder_spans_us_to_50s(self):
        assert list(DEFAULT_NS_BUCKETS) == sorted(DEFAULT_NS_BUCKETS)
        assert DEFAULT_NS_BUCKETS[0] == 1_000  # 1 us in ns
        assert DEFAULT_NS_BUCKETS[-1] == 50_000_000_000  # 50 s in ns

    def test_registry_summary_shape(self):
        reg = MetricsRegistry()
        reg.counter("c").inc()
        reg.gauge("g").set(1.5)
        reg.histogram("h").observe(2_000)
        s = reg.summary()
        assert s["counters"] == {"c": 1}
        assert s["gauges"] == {"g": {"value": 1.5, "max": 1.5}}
        assert s["histograms"]["h"]["count"] == 1


# ---------------------------------------------------------------- tracer
@pytest.mark.quick
class TestTracer:
    def test_span_context_records(self):
        t = Tracer()
        with t.span("work", cat="test", rid=7):
            pass
        [(tid, tname, spans, dropped)] = t.threads()
        assert tid == 1 and dropped == 0
        [(name, cat, t0, dur, args)] = spans
        assert (name, cat) == ("work", "test")
        assert t0 > 0 and dur >= 0
        assert args == {"rid": 7}

    def test_nesting_inner_within_outer(self):
        t = Tracer()
        with t.span("outer"):
            with t.span("inner"):
                pass
        [(_, _, spans, _)] = t.threads()
        by_name = {s[0]: s for s in spans}
        # inner closes first, so it precedes outer in the ring
        assert [s[0] for s in spans] == ["inner", "outer"]
        _, _, it0, idur, _ = by_name["inner"]
        _, _, ot0, odur, _ = by_name["outer"]
        assert ot0 <= it0 and it0 + idur <= ot0 + odur

    def test_ring_overflow_keeps_newest_reports_drops(self):
        t = Tracer(capacity=4)
        for i in range(10):
            t.add_span(f"s{i}", "t", i, 1)
        [(_, _, spans, dropped)] = t.threads()
        assert [s[0] for s in spans] == ["s6", "s7", "s8", "s9"]  # oldest first
        assert dropped == 6
        assert t.dropped_count() == 6
        assert t.span_count() == 4

    def test_per_thread_rings(self):
        t = Tracer()
        t.add_span("main", "t", 0, 1)

        def record():
            t.add_span("other", "t", 0, 1)

        th = threading.Thread(target=record, name="obs-helper")
        th.start()
        th.join()
        got = t.threads()
        assert len(got) == 2
        names = {tname: [s[0] for s in spans] for _, tname, spans, _ in got}
        assert names[threading.current_thread().name] == ["main"]
        assert names["obs-helper"] == ["other"]
        tids = [tid for tid, _, _, _ in got]
        assert len(set(tids)) == 2

    def test_ingest_applies_clock_offset(self):
        t = Tracer()
        t.ingest("graph-worker-0", 4242,
                 [("worker.sample", "worker", 1000, 10, {"rid": 3})],
                 offset_ns=400, dropped=2)
        [(pname, pid, spans, dropped)] = t.foreign()
        assert (pname, pid, dropped) == ("graph-worker-0", 4242, 2)
        assert spans == [("worker.sample", "worker", 600, 10, {"rid": 3})]
        assert t.span_count() == 1
        assert t.dropped_count() == 2

    def test_ingest_negative_offset_shifts_forward(self):
        """A worker whose monotonic clock lags the trainer's has a
        negative offset; correction must shift its spans forward, never
        produce times before the foreign t0."""
        t = Tracer()
        t.ingest("graph-worker-1", 4243,
                 [("worker.sample", "worker", 1000, 10, None)],
                 offset_ns=-400)
        [(_, _, spans, _)] = t.foreign()
        assert spans == [("worker.sample", "worker", 1400, 10, None)]

    def test_ingest_accumulates_rounds_and_drops(self):
        """Repeated stats rounds from one worker each land as their own
        batch; spans and drop counts accumulate instead of clobbering."""
        t = Tracer()
        t.ingest("graph-worker-0", 99, [("a", "w", 10, 1, None)], dropped=2)
        t.ingest("graph-worker-0", 99, [("b", "w", 20, 1, None)], dropped=3)
        batches = t.foreign()
        assert [s[0] for _, _, spans, _ in batches for s in spans] == ["a", "b"]
        assert t.span_count() == 2
        assert t.dropped_count() == 5

    def test_mark_records_instant_events(self):
        t = Tracer()
        t.mark("trainer.fused_fallback", reason="budget")
        t.mark("plain")
        marks = t.marks()
        assert [m[0] for m in marks] == ["trainer.fused_fallback", "plain"]
        name, cat, t0, args = marks[0]
        assert cat == "mark" and t0 > 0 and args == {"reason": "budget"}
        assert marks[1][3] is None

    def test_mark_capacity_bounded(self):
        t = Tracer()
        for i in range(1100):
            t.mark(f"m{i}")
        assert len(t.marks()) == 1024  # oldest kept: marks are rare events

    def test_span_scope_disabled_is_shared_nullcontext(self):
        scope = span_scope(None, "anything", rid=1)
        assert isinstance(scope, contextlib.nullcontext)
        # one shared instance: disabled call sites allocate nothing
        assert span_scope(None, "a") is span_scope(None, "b")
        t = Tracer()
        with span_scope(t, "real", cat="test"):
            pass
        assert t.span_count() == 1


# ---------------------------------------------------------- chrome export
@pytest.mark.quick
class TestChromeExport:
    def _traced(self):
        tel = Telemetry(process_name="trainer")
        tel.tracer.add_span("step", "trainer", 2_500, 1_500, {"i": 0})
        tel.tracer.ingest(
            "graph-worker-0", 777,
            [("worker.sample", "worker", 5_000, 2_000, {"rid": 9})],
        )
        tel.metrics.counter("client.rounds_worker").inc()
        return tel

    def test_schema(self):
        trace = self._traced().chrome_trace()
        assert set(trace) == {"traceEvents", "displayTimeUnit", "otherData"}
        assert trace["displayTimeUnit"] == "ms"
        assert trace["otherData"]["dropped_spans"] == 0
        assert trace["otherData"]["metrics"]["counters"] == {
            "client.rounds_worker": 1
        }
        for ev in trace["traceEvents"]:
            assert ev["ph"] in ("X", "M")
            assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
            if ev["ph"] == "X":
                assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
                assert isinstance(ev["name"], str) and isinstance(ev["cat"], str)
            else:
                assert ev["name"] in ("process_name", "thread_name")
                assert isinstance(ev["args"]["name"], str)

    def test_microsecond_conversion_and_args(self):
        evs = [e for e in trace_events(self._traced().tracer) if e["ph"] == "X"]
        local = next(e for e in evs if e["name"] == "step")
        assert local["ts"] == pytest.approx(2.5)  # 2500 ns -> 2.5 us
        assert local["dur"] == pytest.approx(1.5)
        assert local["args"] == {"i": 0}

    def test_foreign_spans_get_their_own_pid_track(self):
        tel = self._traced()
        evs = tel.chrome_trace()["traceEvents"]
        pids = {e["pid"] for e in evs if e["ph"] == "X"}
        assert 777 in pids and len(pids) == 2
        procs = {
            e["args"]["name"]
            for e in evs if e["ph"] == "M" and e["name"] == "process_name"
        }
        assert procs == {"trainer", "graph-worker-0"}
        # rid rides through to the exported args: the correlation handle
        worker = next(e for e in evs if e["pid"] == 777 and e["ph"] == "X")
        assert worker["args"]["rid"] == 9

    def test_overflow_drop_counts_survive_export(self):
        """Ring overflow on a local thread and reported worker drops both
        surface in otherData.dropped_spans — a truncated trace must say
        so, not pretend it is complete."""
        tel = Telemetry(span_capacity=4)
        for i in range(10):
            tel.tracer.add_span(f"s{i}", "t", i, 1)
        tel.tracer.ingest("graph-worker-0", 777, [], dropped=5)
        trace = tel.chrome_trace()
        assert trace["otherData"]["dropped_spans"] == 6 + 5
        xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
        assert len(xs) == 4  # newest survive

    def test_marks_export_as_instant_events(self):
        tel = Telemetry()
        tel.tracer.mark("health.degraded", reason="worker 0 silent")
        [ev] = [e for e in tel.chrome_trace()["traceEvents"]
                if e["ph"] == "i"]
        assert ev["name"] == "health.degraded"
        assert ev["s"] == "p"  # process-scoped instant line in Perfetto
        assert ev["pid"] == tel.tracer.pid
        assert ev["args"] == {"reason": "worker 0 silent"}
        assert isinstance(ev["ts"], float)

    def test_disabled_run_emits_nothing(self):
        tel = Telemetry()  # never handed to anything
        trace = tel.chrome_trace()
        assert [e["ph"] for e in trace["traceEvents"]] == ["M"]
        assert trace["otherData"]["dropped_spans"] == 0
        assert trace["otherData"]["metrics"]["counters"] == {}

    def test_write_trace_roundtrip(self, tmp_path):
        path = str(tmp_path / "out.trace.json")
        assert self._traced().write_trace(path) == path
        with open(path) as f:
            trace = json.load(f)
        assert any(e["ph"] == "X" for e in trace["traceEvents"])

    def test_text_summary(self):
        tel = self._traced()
        text = tel.text_summary()
        assert "worker.sample" in text
        assert "graph-worker-0" in text
        assert "client.rounds_worker" in text


# ------------------------------------------------------ trainer (inproc)
@pytest.mark.quick
class TestTrainerTelemetry:
    def test_traced_prefetch_run(self, ds):
        tel = Telemetry()
        tr = make_trainer(ds, steps=6, prefetch_batches=2, telemetry=tel)
        res = tr.train()
        # telemetry alone must not switch attribution output on
        assert res.attribution is None
        tracks = tel.tracer.threads()
        assert len(tracks) >= 2  # step loop + prefetch producer
        names = {s[0] for _, _, spans, _ in tracks for s in spans}
        assert {"dispatch", "batch_wait", "sample"} <= names
        snap = tel.metrics.summary()
        assert "prefetch.queue_depth" in snap["gauges"]

    def test_telemetry_plus_attribution_keeps_schema(self, ds):
        tel = Telemetry()
        res = make_trainer(ds, steps=6, prefetch_batches=2, telemetry=tel,
                           attribution=True).train()
        a = res.attribution
        assert a is not None and a["steps"] == 6
        assert {"wall_s", "host_visible_s", "device_residual_s",
                "phases"} <= set(a)
        # the rebased PhaseTimer mirrors each phase into the tracer
        cats = {s[1] for _, _, spans, _ in tel.tracer.threads() for s in spans}
        assert "phase" in cats

    def test_disabled_by_default(self, ds):
        tr = make_trainer(ds, steps=4, prefetch_batches=2)
        assert tr.cfg.telemetry is None
        res = tr.train()
        assert len(res.losses) == 4


# ----------------------------------------------------------- mp pipeline
@pytest.mark.mp
@pytest.mark.usefixtures("watchdog")
class TestMpTelemetry:
    def test_traced_mp_run_correlates_processes(self, ds):
        """The acceptance trace: >= 3 processes (trainer + 2 workers) and
        >= 2 trainer threads on one timeline, worker serve spans joined to
        client rounds by rid."""
        tel = Telemetry()
        tr = make_trainer(
            ds, steps=8, engine_backend="mp", prefetch_batches=2,
            num_engine_workers=2, engine_local_threshold=0, telemetry=tel,
        )
        with tr:
            res = tr.train()
        assert len(res.losses) == 8
        evs = tel.chrome_trace()["traceEvents"]
        xs = [e for e in evs if e["ph"] == "X"]
        trainer_pid = tel.tracer.pid
        pids = {e["pid"] for e in xs}
        assert trainer_pid in pids and len(pids) >= 3
        trainer_tids = {e["tid"] for e in xs if e["pid"] == trainer_pid}
        assert len(trainer_tids) >= 2
        waits = {
            e["args"]["rid"] for e in xs
            if e["pid"] == trainer_pid and e["name"] == "client.wait"
        }
        served = {
            e["args"]["rid"] for e in xs
            if e["pid"] != trainer_pid and e["name"].startswith("worker.")
        }
        assert waits and served
        assert waits & served  # same rounds, seen from both sides
        # client-side round metrics were recorded too
        snap = tel.metrics.summary()
        assert snap["counters"]["client.rounds_worker"] > 0
        assert snap["histograms"]["client.round_latency_ns"]["count"] > 0

    def test_stats_conservation_on_both_reply_paths(self, ds):
        """shm_replies + pickle_replies == batches per worker, with both
        counters exercised: a tiny slab forces the pickle fallback for big
        rounds while small rounds still ride the slab."""
        rng = np.random.default_rng(7)
        big = rng.integers(0, ds.graph.num_nodes, size=200)
        small = rng.integers(0, ds.graph.num_nodes, size=10)
        inproc = DistributedGraphEngine(ds.graph, num_partitions=4)
        with GraphClient(ds.graph, num_partitions=4, num_workers=2,
                         slot_bytes=4096) as c:
            for i in range(4):
                # 200x50 int32 replies (40 kB) overflow the 4 kB slot ->
                # pickle fallback; the request ids still fit -> balanced
                # dispatch, not owner fan-out
                got = c.sample_neighbors(
                    np.random.default_rng(i), big, RELS[0], 50
                )
                ref = inproc.sample_neighbors(
                    np.random.default_rng(i), big, RELS[0], 50
                )
                np.testing.assert_array_equal(got, ref)
                c.sample_neighbors(np.random.default_rng(i), small, RELS[1], 2)
            per = c.worker_stats()
            assert len(per) == 2
            for s in per:
                assert s["shm_replies"] + s["pickle_replies"] == s["batches"]
            assert sum(s["pickle_replies"] for s in per) >= 4
            assert sum(s["shm_replies"] for s in per) >= 1

    def test_worker_error_carries_context(self, ds):
        with GraphClient(ds.graph, num_partitions=2, num_workers=1) as c:
            c.sample_neighbors(np.random.default_rng(0), np.arange(8), RELS[0], 2)
            with pytest.raises(EngineWorkerError, match="KeyError") as ei:
                c.sample_neighbors(
                    np.random.default_rng(0), np.arange(8), "no2such2rel", 2
                )
        err = ei.value
        assert err.worker_id == 0
        assert isinstance(err.rid, int)
        assert err.stats is not None and err.stats["batches"] >= 1
        assert "stats at failure" in str(err)
