"""Throughput-overhaul tests: vectorized engine build, cross-round batch
carry, vectorized slot padding, the prefetching trainer, and the
config-selected Pallas aggregation path."""
import jax
import numpy as np
import pytest

from repro.core import Graph4RecConfig, HeteroGNNConfig
from repro.core.hetero import hetero_forward, init_hetero_params
from repro.embedding import EmbeddingConfig
from repro.embedding.table import _pad_slot_values_loop, pad_slot_values
from repro.graph import DistributedGraphEngine, TOY, generate
from repro.graph.engine import _gather_rows, _gather_rows_loop
from repro.sampling import EgoConfig, PairConfig, PipelineConfig, SamplePipeline
from repro.train import Graph4RecTrainer, TrainerConfig
from repro.walk import WalkConfig

pytestmark = pytest.mark.quick

RELS = ("u2click2i", "i2click2u")


@pytest.fixture(scope="module")
def ds():
    return generate(TOY, seed=0)


class TestVectorizedEngineBuild:
    def test_gather_rows_matches_loop(self, ds):
        for csr in ds.graph.relations.values():
            rows = np.arange(1, ds.graph.num_nodes, 3, dtype=np.int64)
            a_ptr, a_idx = _gather_rows(csr.indptr, csr.indices, rows)
            b_ptr, b_idx = _gather_rows_loop(csr.indptr, csr.indices, rows)
            np.testing.assert_array_equal(a_ptr, b_ptr)
            np.testing.assert_array_equal(a_idx, b_idx)

    def test_gather_rows_all_empty(self):
        indptr = np.zeros(5, dtype=np.int64)
        indices = np.empty(0, dtype=np.int64)
        out_ptr, out_idx = _gather_rows(indptr, indices, np.arange(4, dtype=np.int64))
        np.testing.assert_array_equal(out_ptr, np.zeros(5, dtype=np.int64))
        assert len(out_idx) == 0

    def test_partition_build_equivalence(self, ds):
        fast = DistributedGraphEngine(ds.graph, num_partitions=4, build="vectorized")
        loop = DistributedGraphEngine(ds.graph, num_partitions=4, build="loop")
        for pf, pl in zip(fast.partitions, loop.partitions):
            assert pf.rel_rows.keys() == pl.rel_rows.keys()
            for rel in pf.rel_rows:
                np.testing.assert_array_equal(pf.rel_rows[rel][0], pl.rel_rows[rel][0])
                np.testing.assert_array_equal(pf.rel_rows[rel][1], pl.rel_rows[rel][1])

    def test_sampling_and_stats_equivalence(self, ds):
        """Identical partitions + identical rng stream -> identical samples."""
        fast = DistributedGraphEngine(ds.graph, num_partitions=4, build="vectorized")
        loop = DistributedGraphEngine(ds.graph, num_partitions=4, build="loop")
        nodes = np.random.default_rng(3).integers(0, ds.graph.num_nodes, 64)
        a = fast.sample_neighbors(np.random.default_rng(7), nodes, RELS[0], 5)
        b = loop.sample_neighbors(np.random.default_rng(7), nodes, RELS[0], 5)
        np.testing.assert_array_equal(a, b)
        for f in ("batches", "neighbor_requests", "cross_partition_requests"):
            assert getattr(fast.stats, f) == getattr(loop.stats, f)


class TestBatchCarry:
    def _pipe(self, ds, walks_per_round, batch_pairs, ego=True):
        eng = DistributedGraphEngine(ds.graph, num_partitions=2)
        cfg = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
            pair=PairConfig(win_size=2),
            ego=EgoConfig(relations=list(RELS), fanouts=[3]) if ego else None,
            batch_pairs=batch_pairs, walks_per_round=walks_per_round,
        )
        return SamplePipeline(eng, cfg, seed=0)

    def test_small_rounds_terminate_and_emit(self, ds):
        # 4 walks/round yields far fewer pairs than one 100-pair batch: the
        # seed dropped every round on the floor and looped forever; the carry
        # must accumulate rounds and emit exactly N full batches.
        pipe = self._pipe(ds, walks_per_round=4, batch_pairs=100)
        batches = list(pipe.batches(3))
        assert len(batches) == 3
        for b in batches:
            assert len(b.src_ids) == 100
            assert b.src_ego.levels[0].shape[0] == 100

    def test_no_pair_dropped_across_rounds(self, ds):
        pipe = self._pipe(ds, walks_per_round=4, batch_pairs=64)
        seen_src, seen_dst = [], []
        orig_round = pipe._round

        def recording_round():
            for src, dst, se, de in orig_round():
                seen_src.append(src)
                seen_dst.append(dst)
                yield src, dst, se, de

        pipe._round = recording_round
        batches = list(pipe.batches(4))
        got_src = np.concatenate([b.src_ids for b in batches])
        got_dst = np.concatenate([b.dst_ids for b in batches])
        all_src = np.concatenate(seen_src)
        all_dst = np.concatenate(seen_dst)
        # every emitted pair is the next generated pair, in order: no drops
        np.testing.assert_array_equal(got_src, all_src[: len(got_src)])
        np.testing.assert_array_equal(got_dst, all_dst[: len(got_dst)])
        # and fewer than one batch of generated pairs is still in flight
        assert len(all_src) - len(got_src) < 64 + len(seen_src[-1])

    def test_carried_egos_track_pairs(self, ds):
        pipe = self._pipe(ds, walks_per_round=4, batch_pairs=48)
        for b in pipe.batches(3):
            np.testing.assert_array_equal(b.src_ids, b.src_ego.centers)
            np.testing.assert_array_equal(b.dst_ids, b.dst_ego.centers)

    def test_walk_only_carry(self, ds):
        pipe = self._pipe(ds, walks_per_round=4, batch_pairs=80, ego=False)
        batches = list(pipe.batches(2))
        assert [len(b.src_ids) for b in batches] == [80, 80]
        assert batches[0].src_ego is None


class TestPadSlotValues:
    def _ragged(self, rng, n_nodes=40, vocab=50):
        lens = rng.integers(0, 6, n_nodes)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(lens, out=indptr[1:])
        values = rng.integers(0, vocab, int(indptr[-1]))
        return indptr, values

    def test_matches_loop_reference(self):
        rng = np.random.default_rng(0)
        indptr, values = self._ragged(rng)
        ids = rng.integers(-1, 40, size=200)  # includes PAD ids
        for max_values in (1, 3, 8):
            a = pad_slot_values(indptr, values, ids, max_values)
            b = _pad_slot_values_loop(indptr, values, ids, max_values)
            np.testing.assert_array_equal(a, b)

    def test_all_pad_ids(self):
        rng = np.random.default_rng(1)
        indptr, values = self._ragged(rng)
        out = pad_slot_values(indptr, values, np.full(7, -1), 3)
        assert (out == -1).all()

    def test_2d_ids_flattened(self):
        rng = np.random.default_rng(2)
        indptr, values = self._ragged(rng)
        ids = rng.integers(0, 40, size=(6, 5))
        a = pad_slot_values(indptr, values, ids, 4)
        b = _pad_slot_values_loop(indptr, values, ids, 4)
        np.testing.assert_array_equal(a, b)


def _toy_trainer(ds, **cfg_kw):
    mc = Graph4RecConfig(
        embedding=EmbeddingConfig(num_nodes=ds.graph.num_nodes, dim=16),
        gnn=HeteroGNNConfig(gnn_type="lightgcn", num_relations=2,
                            num_layers=1, dim=16),
        fanouts=(3,),
        relations=RELS,
        loss="inbatch_softmax",
    )
    pc = PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
        pair=PairConfig(win_size=2),
        ego=EgoConfig(relations=list(RELS), fanouts=[3]),
        batch_pairs=64, walks_per_round=16,
    )
    eng = DistributedGraphEngine(ds.graph, num_partitions=2)
    cfg_kw.setdefault("num_steps", 6)
    cfg = TrainerConfig(log_every=0, eval_at_end=False,
                        eval_max_users=32, **cfg_kw)
    return Graph4RecTrainer(ds, eng, mc, pc, cfg)


class TestPrefetchTrainer:
    def test_prefetch_matches_serial(self, ds):
        """Prefetching reorders nothing: identical seeds -> identical losses."""
        serial = _toy_trainer(ds, prefetch_batches=0, sync_every_step=True).train()
        fast = _toy_trainer(ds, prefetch_batches=3).train()
        assert len(serial.losses) == len(fast.losses) == 6
        np.testing.assert_allclose(serial.losses, fast.losses, rtol=1e-5)
        assert serial.pairs_seen == fast.pairs_seen

    def test_producer_error_propagates(self, ds):
        tr = _toy_trainer(ds, prefetch_batches=2)
        tr.pipe_cfg = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
            pair=PairConfig(win_size=2),
            ego=EgoConfig(relations=["nonexistent"], fanouts=[3]),
            batch_pairs=64, walks_per_round=16,
        )
        with pytest.raises(KeyError):
            tr.train()

    def test_producer_error_keeps_original_traceback(self, ds):
        """The consumer re-raises the producer's exception object, so the
        traceback points into the pipeline code that actually failed."""
        import traceback

        from repro.train.trainer import _Prefetcher

        def boom():
            raise ValueError("pipeline exploded")
            yield  # pragma: no cover

        pf = _Prefetcher(boom(), depth=2)
        with pytest.raises(ValueError, match="pipeline exploded") as ei:
            next(pf)
        frames = "".join(traceback.format_tb(ei.value.__traceback__))
        assert "boom" in frames

    def test_dead_producer_without_sentinel_raises_not_hangs(self, ds):
        """A producer that dies without delivering its sentinel (hard crash)
        must surface as an error in the consumer, never a queue hang."""
        from repro.train.trainer import _Prefetcher

        class _CrashingPrefetcher(_Prefetcher):
            def _fill(self, it):  # thread dies before any put
                return

        pf = _CrashingPrefetcher(iter([1, 2]), depth=2)
        with pytest.raises(RuntimeError, match="died without delivering"):
            next(pf)


class TestStagedBatches:
    """The consumer-side H2D stager: one explicit device_put per batch,
    double-buffered so batch k+1's transfer overlaps grad step k."""

    @staticmethod
    def _host_items(n):
        return [({"x": np.full(4, i, np.float32)}, i) for i in range(n)]

    @pytest.mark.parametrize("double_buffer", [False, True])
    def test_order_and_device_residency(self, double_buffer):
        from repro.train.trainer import _staged_batches

        out = list(_staged_batches(iter(self._host_items(5)),
                                   double_buffer=double_buffer))
        assert [npairs for _, npairs in out] == list(range(5))
        for dev, i in out:
            assert isinstance(dev["x"], jax.Array)
            np.testing.assert_array_equal(np.asarray(dev["x"]),
                                          np.full(4, i, np.float32))

    @pytest.mark.parametrize("double_buffer", [False, True])
    @pytest.mark.parametrize("n", [0, 1, 2])
    def test_short_iterators_flush_completely(self, double_buffer, n):
        """0/1/2 items exercise the prime/flush edges of the double buffer."""
        from repro.train.trainer import _staged_batches

        out = list(_staged_batches(iter(self._host_items(n)),
                                   double_buffer=double_buffer))
        assert [npairs for _, npairs in out] == list(range(n))

    def test_double_buffer_stages_one_ahead(self):
        """Before batch k is yielded, batch k+1 has already been pulled and
        its transfer issued — that overlap is the whole point."""
        from repro.train.trainer import _staged_batches

        pulled = []

        def tracking_iter():
            for item in self._host_items(4):
                pulled.append(item[1])
                yield item

        gen = _staged_batches(tracking_iter(), double_buffer=True)
        _, first = next(gen)
        assert first == 0
        assert pulled == [0, 1]  # k+1 staged before k was handed over
        _, second = next(gen)
        assert second == 1
        assert pulled == [0, 1, 2]

    def test_serial_mode_does_not_run_ahead(self):
        """Without prefetching the upstream iterator IS inline sampling;
        pulling early would only reorder work, so the stager must not."""
        from repro.train.trainer import _staged_batches

        pulled = []

        def tracking_iter():
            for item in self._host_items(3):
                pulled.append(item[1])
                yield item

        gen = _staged_batches(tracking_iter(), double_buffer=False)
        next(gen)
        assert pulled == [0]

    @pytest.mark.parametrize("double_buffer", [False, True])
    def test_upstream_error_propagates(self, double_buffer):
        from repro.train.trainer import _staged_batches

        def boom():
            yield {"x": np.zeros(2, np.float32)}, 0
            raise ValueError("producer exploded")

        gen = _staged_batches(boom(), double_buffer=double_buffer)
        with pytest.raises(ValueError, match="producer exploded"):
            list(gen)

    def test_distinct_buffers_per_batch(self):
        """Each staged batch is its own device buffer: donating batch k in
        the grad step must never invalidate the already-staged batch k+1."""
        from repro.train.trainer import _staged_batches

        host = np.arange(4, dtype=np.float32)
        items = [({"x": host}, i) for i in range(3)]  # same host array!
        out = list(_staged_batches(iter(items), double_buffer=True))
        bufs = [dev["x"] for dev, _ in out]
        assert len({id(b) for b in bufs}) == 3
        donate = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
        donate(bufs[0]).block_until_ready()
        np.testing.assert_array_equal(np.asarray(bufs[1]), host)


class TestDonationSafety:
    def test_dense_step_batch_is_reusable(self, ds):
        """The dense step must NOT donate: bag-mode batches alias the
        trainer's shared device-resident slot-count cache, so donating one
        would corrupt every later step."""
        tr = _toy_trainer(ds)
        params = tr.init_params()
        opt_state = tr.opt.init(params)
        pipeline = SamplePipeline(tr.engine, tr.pipe_cfg, seed=0)
        (host, _), = list(tr._host_batches(pipeline, 1))
        dev = jax.device_put(host)
        _, _, loss1 = tr._grad_step(params, opt_state, dev)
        _, _, loss2 = tr._grad_step(params, opt_state, dev)  # reuse is legal
        np.testing.assert_array_equal(np.asarray(loss1), np.asarray(loss2))

    def test_sparse_step_donates_params_not_batch(self, ds):
        """The sparse step donates its float param buffers (reuse fails
        loudly, proving they are actually reclaimed in place). The int32 id
        batch can never alias a float output, so XLA leaves those buffers
        alone — reuse stays legal, which is why the 'not usable' donation
        warning is suppressed rather than fixed."""
        tr = _toy_trainer(ds, sparse_updates=True, sparse_min_rows=0)
        params = tr._copy_params(tr.init_params())
        opt_state = tr._init_sparse_opt_state(params)
        pipeline = SamplePipeline(tr.engine, tr.pipe_cfg, seed=0)
        (host, _), = list(tr._host_batches(pipeline, 1))
        dev = jax.device_put(host)
        old_leaf = next(
            leaf for leaf in jax.tree_util.tree_leaves(params)
            if np.issubdtype(leaf.dtype, np.floating)
        )
        params, opt_state, _ = tr._sparse_step(params, opt_state, dev)
        with pytest.raises(Exception, match="deleted"):
            np.asarray(old_leaf)
        tr._sparse_step(params, opt_state, dev)  # batch reuse is fine


class TestBitwiseBackendEquality:
    """Prefetch + double-buffered staging + async loss drain must be pure
    plumbing: same seed -> bit-identical loss trajectories across backends."""

    def test_serial_vs_prefetch_bitwise_dense(self, ds):
        serial = _toy_trainer(ds, prefetch_batches=0).train()
        fast = _toy_trainer(ds, prefetch_batches=3).train()
        np.testing.assert_array_equal(serial.losses, fast.losses)

    def test_serial_vs_prefetch_bitwise_sparse(self, ds):
        """Same contract through the gather->step->scatter path, where the
        staged batches are additionally donated by the step."""
        serial = _toy_trainer(ds, prefetch_batches=0, sparse_updates=True,
                              sparse_min_rows=0).train()
        fast = _toy_trainer(ds, prefetch_batches=3, sparse_updates=True,
                            sparse_min_rows=0).train()
        np.testing.assert_array_equal(serial.losses, fast.losses)

    def test_async_loss_drain_matches_sync(self, ds):
        """Windowed async readback returns the same values in the same order
        as per-step blocking fetches."""
        sync = _toy_trainer(ds, num_steps=12, sync_every_step=True,
                            loss_fetch_every=0).train()
        windowed = _toy_trainer(ds, num_steps=12, loss_fetch_every=4).train()
        np.testing.assert_array_equal(sync.losses, windowed.losses)


class TestSlotBagMode:
    def test_bag_matches_values_exactly(self, ds):
        """'bag' (count-matrix GEMM) side info == 'values' (padded gather)."""
        import dataclasses

        import jax
        from repro.core import model as model_lib
        from repro.embedding import SlotSpec

        mc_values = Graph4RecConfig(
            embedding=EmbeddingConfig(
                num_nodes=ds.graph.num_nodes, dim=16,
                slots=(SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 2)),
            ),
            gnn=HeteroGNNConfig(gnn_type="lightgcn", num_relations=2,
                                num_layers=1, dim=16),
            fanouts=(3,),
            relations=RELS,
            use_side_info=True,
            slot_mode="values",
        )
        mc_bag = dataclasses.replace(mc_values, slot_mode="bag")
        eng = DistributedGraphEngine(ds.graph, num_partitions=2)
        pc = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
            pair=PairConfig(win_size=2),
            ego=EgoConfig(relations=list(RELS), fanouts=[3]),
            batch_pairs=32, walks_per_round=16,
        )
        batch = next(iter(SamplePipeline(eng, pc, seed=0).batches(1)))
        params = model_lib.init_model_params(jax.random.PRNGKey(0), mc_values)
        dev_v = model_lib.device_batch(ds.graph, batch, mc_values)
        dev_b = model_lib.device_batch(ds.graph, batch, mc_bag)
        assert "slot_counts" in dev_b and dev_b["src"][1] is None
        lv, gv = jax.value_and_grad(model_lib.loss_fn)(params, mc_values, dev_v)
        lb, gb = jax.value_and_grad(model_lib.loss_fn)(params, mc_bag, dev_b)
        np.testing.assert_allclose(float(lv), float(lb), rtol=1e-6)
        for k in gv:
            np.testing.assert_allclose(
                np.asarray(gv[k]), np.asarray(gb[k]), rtol=1e-5, atol=1e-6,
                err_msg=k,
            )


class TestBagVocabGuard:
    """ROADMAP 'sparse slot-count matrices', first step: big-vocab bag slots
    fall back to the 'values' representation instead of materializing an
    O(num_nodes x vocab) count matrix."""

    def _cfg(self, ds, slot_mode, limit=32768):
        import dataclasses as dc

        from repro.embedding import SlotSpec

        return Graph4RecConfig(
            embedding=EmbeddingConfig(
                num_nodes=ds.graph.num_nodes, dim=16,
                slots=(SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 2)),
            ),
            gnn=HeteroGNNConfig(gnn_type="lightgcn", num_relations=2,
                                num_layers=1, dim=16),
            fanouts=(3,), relations=RELS,
            use_side_info=True, slot_mode=slot_mode, bag_vocab_limit=limit,
        )

    def _batch(self, ds):
        eng = DistributedGraphEngine(ds.graph, num_partitions=2)
        pc = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
            pair=PairConfig(win_size=2),
            ego=EgoConfig(relations=list(RELS), fanouts=[3]),
            batch_pairs=32, walks_per_round=16,
        )
        return next(iter(SamplePipeline(eng, pc, seed=0).batches(1)))

    def test_over_limit_slot_falls_back_to_values(self, ds):
        from repro.core import model as model_lib

        # slot vocabs are 64: a limit of 63 demotes both, 0 disables the guard
        cfg = self._cfg(ds, "bag", limit=63)
        assert model_lib.bag_slot_specs(cfg) == ()
        assert len(model_lib.value_slot_specs(cfg)) == 2
        assert model_lib.slot_count_arrays(ds.graph, cfg) == {}
        cfg_off = self._cfg(ds, "bag", limit=0)
        assert len(model_lib.bag_slot_specs(cfg_off)) == 2

    def test_mixed_bag_values_matches_pure_values(self, ds):
        """One slot over the limit, one under: the mixed batch must score
        exactly like the all-values configuration."""
        import dataclasses as dc

        import jax
        from repro.core import model as model_lib
        from repro.embedding import SlotSpec

        base = self._cfg(ds, "values")
        # slot1 gets a big vocab (identical first-64 rows matter only for
        # shape; values data stays in range) and a limit between the two
        big = dc.replace(
            base,
            embedding=dc.replace(
                base.embedding,
                slots=(SlotSpec("slot0", 64, 3), SlotSpec("slot1", 200, 2)),
            ),
        )
        mixed = dc.replace(big, slot_mode="bag", bag_vocab_limit=100)
        assert [s.name for s in model_lib.bag_slot_specs(mixed)] == ["slot0"]
        assert [s.name for s in model_lib.value_slot_specs(mixed)] == ["slot1"]
        batch = self._batch(ds)
        params = model_lib.init_model_params(jax.random.PRNGKey(0), big)
        dev_v = model_lib.device_batch(ds.graph, batch, big)
        dev_m = model_lib.device_batch(ds.graph, batch, mixed)
        assert set(dev_m["slot_counts"]) == {"slot0"}
        assert set(dev_m["src"][1][0]) == {"slot1"}
        lv, gv = jax.value_and_grad(model_lib.loss_fn)(params, big, dev_v)
        lm, gm = jax.value_and_grad(model_lib.loss_fn)(params, mixed, dev_m)
        np.testing.assert_allclose(float(lv), float(lm), rtol=1e-6)
        for k in gv:
            np.testing.assert_allclose(
                np.asarray(gv[k]), np.asarray(gm[k]), rtol=1e-5, atol=1e-6,
                err_msg=k,
            )

    def test_mixed_sparse_batch_matches_pure_values(self, ds):
        """Same equivalence under the gather->step->scatter batch layout."""
        import dataclasses as dc

        import jax
        from repro.core import model as model_lib
        from repro.embedding import SlotSpec, gather_rows

        base = self._cfg(ds, "values")
        big = dc.replace(
            base,
            embedding=dc.replace(
                base.embedding,
                slots=(SlotSpec("slot0", 64, 3), SlotSpec("slot1", 200, 2)),
            ),
        )
        mixed = dc.replace(big, slot_mode="bag", bag_vocab_limit=100)
        batch = self._batch(ds)
        params = model_lib.init_model_params(jax.random.PRNGKey(0), big)
        dev_v = model_lib.device_batch(ds.graph, batch, big)
        dev_m = model_lib.sparse_device_batch(ds.graph, batch, mixed)
        sub = {
            k: gather_rows(params[f"emb/{k}"], v)
            for k, v in dev_m["uniq"].items()
        }
        sub_params = {**params, **{f"emb/{k}": v for k, v in sub.items()}}
        model_batch = {k: v for k, v in dev_m.items() if k != "uniq"}
        lv = model_lib.loss_fn(params, big, dev_v)
        lm = model_lib.loss_fn(sub_params, mixed, model_batch)
        np.testing.assert_allclose(float(lv), float(lm), rtol=1e-6)

    def test_fallback_warns_once(self, ds, caplog):
        import logging

        from repro.core import model as model_lib

        model_lib._bag_fallback_warned.clear()
        cfg = self._cfg(ds, "bag", limit=10)
        with caplog.at_level(logging.WARNING, logger="repro.model"):
            model_lib.bag_slot_specs(cfg)
            model_lib.bag_slot_specs(cfg)
        hits = [r for r in caplog.records if "bag_vocab_limit" in r.getMessage()]
        assert len(hits) == 2  # one per slot, not per call


class TestKernelAggrConfig:
    def test_config_selects_kernel_path(self, ds):
        cfg = HeteroGNNConfig(gnn_type="sage-mean", num_relations=2,
                              num_layers=1, dim=8)
        import jax

        params = init_hetero_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        feats = [
            np.asarray(rng.normal(size=(4, 1, 8)), np.float32),
            np.asarray(rng.normal(size=(4, 6, 8)), np.float32),
        ]
        masks = [np.ones((4, 1), bool), rng.random((4, 6)) > 0.3]
        import dataclasses

        ref = hetero_forward(params, dataclasses.replace(cfg, use_kernel_aggr=False),
                             feats, masks, [3])
        ker = hetero_forward(params, dataclasses.replace(cfg, use_kernel_aggr=True),
                             feats, masks, [3])
        np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                                   rtol=1e-5, atol=1e-5)

    def test_trainer_config_overrides_model_config(self, ds):
        tr = _toy_trainer(ds, use_kernel_aggr=True)
        assert tr.model_cfg.gnn.use_kernel_aggr is True
        tr = _toy_trainer(ds)
        assert tr.model_cfg.gnn.use_kernel_aggr is None
