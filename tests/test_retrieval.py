"""Device-side retrieval correctness (repro.retrieval + core.recall).

The contract under test: the chunked/streaming device top-k paths (lax
reference and Pallas kernel) agree with the numpy brute-force oracle
EXACTLY — same ids, same scores, same tie-breaks — across dtypes, chunk
sizes, and exclude-history masking; the IVF coarse-partition path is exact
when probing every cell and recall-bounded otherwise; and the full recall
evaluation (ICF/UCF/U2I + Recall/Hit/NDCG) is method-invariant.
"""
import numpy as np
import pytest

from repro.core.recall import (
    evaluate_recall, evaluate_recall_bruteforce, ranked_metrics,
)
from repro.retrieval import (
    IVFConfig, IVFIndex, brute_force_topk, chunked_topk, pad_id_rows,
)

pytestmark = pytest.mark.quick


def _data(seed=0, Q=29, I=501, d=16, dtype=np.float32, int_valued=False):
    rng = np.random.default_rng(seed)
    if int_valued:  # exact in f32 regardless of summation order -> real ties
        q = rng.integers(-3, 4, size=(Q, d)).astype(dtype)
        it = rng.integers(-3, 4, size=(I, d)).astype(dtype)
    else:
        q = rng.normal(size=(Q, d)).astype(dtype)
        it = rng.normal(size=(I, d)).astype(dtype)
    ex = np.full((Q, 6), -1, np.int32)
    ex[:, :4] = rng.integers(0, I, size=(Q, 4))
    return q, it, ex


class TestChunkedTopk:
    @pytest.mark.parametrize("chunk", [32, 100, 512, 4096])
    def test_ref_matches_oracle_across_chunks(self, chunk):
        q, it, ex = _data()
        s0, i0 = brute_force_topk(q, it, 25, exclude=ex)
        s1, i1 = chunked_topk(q, it, 25, exclude=ex, item_chunk=chunk)
        assert np.array_equal(i0, i1)
        assert np.array_equal(s0, s1)

    @pytest.mark.parametrize("dtype", [np.float32, np.float16, np.float64])
    def test_exact_across_dtypes(self, dtype):
        # every path casts to f32 before scoring, so f16/f64 inputs give
        # identical results to their f32-cast selves
        q, it, ex = _data(dtype=dtype)
        s0, i0 = brute_force_topk(q, it, 10, exclude=ex)
        s1, i1 = chunked_topk(q, it, 10, exclude=ex, item_chunk=64)
        s2, i2 = chunked_topk(q, it, 10, exclude=ex, item_chunk=64,
                              backend="pallas")
        assert np.array_equal(i0, i1) and np.array_equal(i0, i2)
        assert np.array_equal(s0, s1) and np.array_equal(s0, s2)

    def test_pallas_matches_oracle(self):
        q, it, ex = _data(Q=40, I=700)
        s0, i0 = brute_force_topk(q, it, 33, exclude=ex)
        s1, i1 = chunked_topk(q, it, 33, exclude=ex, item_chunk=128,
                              backend="pallas")
        assert np.array_equal(i0, i1)
        assert np.array_equal(s0, s1)

    def test_tie_break_lower_id_wins(self):
        # int-valued embeddings produce many exact score ties; all paths
        # must resolve them identically (ascending item id)
        q, it, _ = _data(int_valued=True, d=6, I=300)
        s0, i0 = brute_force_topk(q, it, 40)
        for backend, chunk in (("ref", 64), ("ref", 999), ("pallas", 128)):
            s, i = chunked_topk(q, it, 40, item_chunk=chunk, backend=backend)
            assert np.array_equal(i0, i), backend
            assert np.array_equal(s0, s), backend

    def test_query_chunking_exact_with_ragged_tail(self):
        q, it, ex = _data(Q=53)
        _, i0 = brute_force_topk(q, it, 7, exclude=ex)
        _, i1 = chunked_topk(q, it, 7, exclude=ex, item_chunk=128,
                             query_chunk=16)
        assert np.array_equal(i0, i1)

    def test_exclude_all_history_never_recommended(self):
        q, it, _ = _data()
        hist = [np.arange(i % 9) for i in range(len(q))]
        ex = pad_id_rows(hist)
        _, ids = chunked_topk(q, it, 20, exclude=ex, item_chunk=64)
        for row, h in zip(ids, hist):
            assert not set(row.tolist()) & set(h.tolist())

    def test_filler_contract_when_k_exceeds_survivors(self):
        # k > non-excluded items: every path must return (-inf, -1) filler
        # slots — never a real (excluded) id — and stay mutually identical
        rng = np.random.default_rng(2)
        q = rng.normal(size=(4, 8)).astype(np.float32)
        it = rng.normal(size=(10, 8)).astype(np.float32)
        ex = np.tile(np.arange(5, dtype=np.int32), (4, 1))  # half excluded
        s0, i0 = brute_force_topk(q, it, 8, exclude=ex)
        assert np.array_equal(i0[:, 7:], np.full((4, 1), -1))
        assert np.isneginf(s0[:, 7:]).all()
        for backend in ("ref", "pallas"):
            s1, i1 = chunked_topk(q, it, 8, exclude=ex, item_chunk=4,
                                  backend=backend)
            assert np.array_equal(i0, i1), backend
            assert np.array_equal(s0, s1), backend
        idx = IVFIndex.build(it, IVFConfig(nlist=3, nprobe=3, seed=0))
        s2, i2 = idx.search(q, 8, exclude=ex)
        assert np.array_equal(i0, i2)

    def test_k_bounds_validated(self):
        q, it, _ = _data()
        with pytest.raises(ValueError):
            chunked_topk(q, it, 0)
        with pytest.raises(ValueError):
            chunked_topk(q, it, len(it) + 1)

    def test_memory_and_latency_do_not_scale_with_sim_matrix(self):
        """The chunked program's temp footprint is O(chunk), not O(Q·I):
        growing the item table 16x leaves compiled temp bytes unchanged
        (a full-similarity-matrix implementation would grow 16x), and
        latency grows at most ~linearly (the unavoidable item sweep)."""
        import time

        from benchmarks.bench_recall import chunked_temp_bytes

        Q, chunk = 64, 1024
        small, big = 8192, 8192 * 16
        tb_small = chunked_temp_bytes(Q, small, chunk)
        tb_big = chunked_temp_bytes(Q, big, chunk)
        # flat up to scan bookkeeping (a few hundred bytes), nowhere near
        # the 16x growth of a materialized (Q, I) score matrix
        assert abs(tb_big - tb_small) < 16_384, (tb_small, tb_big)
        assert tb_big < Q * big * 4 // 8  # far below a (Q, I) score matrix

        rng = np.random.default_rng(0)
        q = rng.normal(size=(Q, 32)).astype(np.float32)
        t = {}
        for I in (small, big):
            it = rng.normal(size=(I, 32)).astype(np.float32)
            chunked_topk(q, it, 50, item_chunk=chunk)  # warm/compile
            best = 1e9
            for _ in range(3):
                t0 = time.perf_counter()
                chunked_topk(q, it, 50, item_chunk=chunk)
                best = min(best, time.perf_counter() - t0)
            t[I] = best
        assert t[big] / t[small] < 16 * 4  # linear in I, with CPU-noise slack


class TestIVF:
    def test_probe_all_cells_is_exact(self):
        q, it, ex = _data(I=400)
        idx = IVFIndex.build(it, IVFConfig(nlist=13, nprobe=13, seed=0))
        s0, i0 = brute_force_topk(q, it, 21, exclude=ex)
        s1, i1 = idx.search(q, 21, exclude=ex)
        assert np.array_equal(i0, i1)
        # IVF scores come from a per-candidate gathered dot (einsum), not
        # the dense matmul — same math, ulp-level accumulation difference
        np.testing.assert_allclose(s0, s1, rtol=1e-5)

    def test_partial_probe_recall_bounded(self):
        # clustered corpus (the realistic case): queries sit near centroids,
        # so probing a quarter of the cells keeps most of the exact top-k
        rng = np.random.default_rng(3)
        centers = rng.normal(size=(8, 16)).astype(np.float32) * 3
        it = (centers[rng.integers(0, 8, 2000)]
              + rng.normal(size=(2000, 16)).astype(np.float32))
        q = (centers[rng.integers(0, 8, 64)]
             + 0.5 * rng.normal(size=(64, 16)).astype(np.float32))
        idx = IVFIndex.build(it, IVFConfig(nlist=16, nprobe=4, seed=0))
        _, i0 = brute_force_topk(q, it, 20)
        _, i1 = idx.search(q, 20)
        overlap = np.mean([
            len(set(a.tolist()) & set(b.tolist())) / 20 for a, b in zip(i0, i1)
        ])
        assert overlap >= 0.5, overlap

    def test_train_subsample_build(self):
        q, it, _ = _data(I=600)
        idx = IVFIndex.build(
            it, IVFConfig(nlist=8, nprobe=8, train_size=100, seed=0)
        )
        _, i0 = brute_force_topk(q, it, 9)
        _, i1 = idx.search(q, 9)
        assert np.array_equal(i0, i1)  # exhaustive probing stays exact

    def test_hot_cell_spill_bounds_lists_and_stays_exact(self):
        # pathological clustering: every item near one direction -> without
        # balancing one cell would hold nearly the whole table and the
        # padded candidate gather would scale like brute force
        rng = np.random.default_rng(4)
        it = (np.ones((600, 8)) * 3 + rng.normal(size=(600, 8))).astype(np.float32)
        q = rng.normal(size=(16, 8)).astype(np.float32)
        cfg = IVFConfig(nlist=12, nprobe=12, balance_factor=2.0, seed=0)
        idx = IVFIndex.build(it, cfg)
        cap = int(np.ceil(2.0 * 600 / 12))
        assert idx.lists.shape[1] <= cap
        assert np.sort((idx.lists[idx.lists >= 0])).tolist() == list(range(600))
        _, i0 = brute_force_topk(q, it, 11)
        _, i1 = idx.search(q, 11)
        assert np.array_equal(i0, i1)  # exhaustive probing still exact

    def test_exclusion_respected(self):
        q, it, ex = _data(I=300)
        idx = IVFIndex.build(it, IVFConfig(nlist=8, nprobe=8, seed=0))
        _, ids = idx.search(q, 15, exclude=ex)
        for row, exr in zip(ids, ex):
            assert not set(row.tolist()) & set(exr[exr >= 0].tolist())


class TestIVFQuantizedRerank:
    """The rebuilt IVF path: int8 asymmetric shortlist + exact-dot re-rank.

    At ``nprobe == nlist`` the shortlist is sized to the full probe budget,
    so every candidate survives to the exact re-rank and the result must
    match the brute-force oracle id-for-id — int8 quantization may only
    reorder the shortlist, never the final ranking."""

    @pytest.mark.parametrize("dtype", [np.float32, np.float16, np.float64])
    def test_full_probe_exact_across_dtypes(self, dtype):
        q, it, ex = _data(I=420, dtype=dtype)
        idx = IVFIndex.build(it, IVFConfig(nlist=11, nprobe=11, seed=0))
        s0, i0 = brute_force_topk(q, it, 17, exclude=ex)
        s1, i1 = idx.search(q, 17, exclude=ex)
        assert np.array_equal(i0, i1)
        np.testing.assert_allclose(s0, s1, rtol=1e-5)

    def test_tie_break_lower_id_wins_through_rerank(self):
        # int-valued embeddings: many exact score ties, and the re-rank's
        # f32 dots are exact, so scores AND ids must match the oracle
        q, it, _ = _data(int_valued=True, d=6, I=300)
        idx = IVFIndex.build(it, IVFConfig(nlist=7, nprobe=7, seed=0))
        s0, i0 = brute_force_topk(q, it, 40)
        s1, i1 = idx.search(q, 40)
        assert np.array_equal(i0, i1)
        assert np.array_equal(s0, s1)

    def test_host_and_device_rerank_agree(self):
        # keep_exact_device=False (the 10M mode: only int8 codes resident)
        # re-ranks on host from the builder's numpy table; same results
        q, it, ex = _data(I=350)
        dev = IVFIndex.build(it, IVFConfig(nlist=9, nprobe=9, seed=0))
        host = IVFIndex.build(
            it, IVFConfig(nlist=9, nprobe=9, seed=0, keep_exact_device=False)
        )
        sd, idd = dev.search(q, 13, exclude=ex)
        sh, ih = host.search(q, 13, exclude=ex)
        assert np.array_equal(idd, ih)
        np.testing.assert_allclose(sd, sh, rtol=1e-6)

    def test_hier_assign_full_probe_stays_exact(self):
        # hierarchical assignment approximates WHICH cell an item lands in,
        # never whether it lands somewhere — exhaustive probing stays exact
        q, it, ex = _data(I=500)
        idx = IVFIndex.build(
            it, IVFConfig(nlist=16, nprobe=16, seed=0, assign_mode="hier")
        )
        _, i0 = brute_force_topk(q, it, 19, exclude=ex)
        _, i1 = idx.search(q, 19, exclude=ex)
        assert np.array_equal(i0, i1)

    def test_rerank_budget_respected_and_results_valid(self):
        q, it, _ = _data(I=400)
        idx = IVFIndex.build(it, IVFConfig(nlist=10, nprobe=4, rerank=32, seed=0))
        s, i = idx.search(q, 20)
        assert s.shape == (len(q), 20) and i.shape == (len(q), 20)
        ok = i >= 0
        assert np.isfinite(s[ok]).all() and np.isneginf(s[~ok]).all()

    def test_build_deterministic(self):
        # k-means reseed + vectorized spill are pure functions of the seed
        _, it, _ = _data(I=700)
        cfg = IVFConfig(nlist=12, nprobe=4, balance_factor=1.5, seed=0)
        a = IVFIndex.build(it, cfg)
        b = IVFIndex.build(it, cfg)
        assert np.array_equal(a.order, b.order)
        assert np.array_equal(a.offsets, b.offsets)
        assert np.array_equal(a.codes, b.codes)
        assert np.array_equal(a.scales, b.scales)

    def test_spill_rank_rounds_cap_and_permutation(self):
        # pathological input: every item assigned to one hot cell; the
        # vectorized rank-round spill must end with every cell at <= cap,
        # every item placed exactly once, deterministically
        from repro.retrieval.ivf import _spill_hot_cells

        rng = np.random.default_rng(6)
        I, nlist, d = 400, 10, 8
        norm = rng.normal(size=(I, d)).astype(np.float32)
        norm /= np.linalg.norm(norm, axis=1, keepdims=True)
        cent = rng.normal(size=(nlist, d)).astype(np.float32)
        cent /= np.linalg.norm(cent, axis=1, keepdims=True)
        assign = np.zeros(I, dtype=np.int64)
        out = _spill_hot_cells(norm, cent, assign, cap=40)
        counts = np.bincount(out, minlength=nlist)
        assert counts.max() <= 40
        assert counts.sum() == I
        assert np.array_equal(out, _spill_hot_cells(norm, cent, assign, cap=40))

    def test_config_validation(self):
        it = np.random.default_rng(0).normal(size=(64, 8)).astype(np.float32)
        with pytest.raises(ValueError, match="rerank"):
            IVFIndex.build(it, IVFConfig(nlist=4, rerank=-1))
        with pytest.raises(ValueError, match="assign_mode"):
            IVFIndex.build(it, IVFConfig(nlist=4, assign_mode="fast"))
        with pytest.raises(ValueError, match="backend"):
            IVFIndex.build(it, IVFConfig(nlist=4, backend="cuda"))


class TestIVFDeviceResidency:
    """Device residency contract: build() uploads the table once; search()
    only ever transfers queries/exclusions in and (Q, k) results out."""

    def test_search_under_disallow_transfer_guard(self):
        import jax

        q, it, ex = _data(I=800)
        idx = IVFIndex.build(it, IVFConfig(nlist=8, nprobe=3, seed=0))
        warm = idx.search(q, 12, exclude=ex)  # compile outside the guard
        with jax.transfer_guard("disallow"):  # implicit transfers -> error
            s, i = idx.search(q, 12, exclude=ex)
        assert np.array_equal(warm[1], i)
        assert np.array_equal(warm[0], s)

    def test_search_uploads_only_query_sized_arrays(self, monkeypatch):
        import jax

        q, it, ex = _data(I=1200)
        idx = IVFIndex.build(it, IVFConfig(nlist=16, nprobe=4, seed=0))
        idx.search(q, 9, exclude=ex)  # warm: jit cached, residency done
        real = jax.device_put
        put_bytes = []

        def spy(x, *args, **kwargs):
            put_bytes.append(getattr(x, "nbytes", 0))
            return real(x, *args, **kwargs)

        monkeypatch.setattr(jax, "device_put", spy)
        idx.search(q, 9, exclude=ex)
        assert put_bytes, "spy saw no uploads at all"
        # nothing bigger than the query/exclusion batch — in particular
        # never the codes, scales, or exact item table
        assert max(put_bytes) <= max(q.nbytes, ex.nbytes), put_bytes

    def test_chunked_topk_table_cached_across_calls(self, monkeypatch):
        import jax

        q, it, ex = _data(I=2000)
        chunked_topk(q, it, 10, exclude=ex, item_chunk=256)  # populates cache
        real = jax.device_put
        put_bytes = []

        def spy(x, *args, **kwargs):
            put_bytes.append(getattr(x, "nbytes", 0))
            return real(x, *args, **kwargs)

        monkeypatch.setattr(jax, "device_put", spy)
        chunked_topk(q, it, 10, exclude=ex, item_chunk=256)
        assert put_bytes and max(put_bytes) <= max(q.nbytes, ex.nbytes)


class TestRankedMetrics:
    def test_closed_form_values(self):
        # rec hits truth at ranks 0 and 2 of 4; |truth| = 3
        rec = np.array([[7, 1, 9, 2]])
        truth = [{7, 9, 5}]
        m = ranked_metrics(rec, truth, top_k=4)
        assert m["recall"] == pytest.approx(2 / 3)
        assert m["hit"] == 1.0
        dcg = 1 / np.log2(2) + 1 / np.log2(4)
        idcg = 1 / np.log2(2) + 1 / np.log2(3) + 1 / np.log2(4)
        assert m["ndcg"] == pytest.approx(dcg / idcg)

    def test_perfect_and_zero(self):
        rec = np.array([[3, 1], [5, 6]])
        assert ranked_metrics(rec, [{3, 1}, {5, 6}], 2) == {
            "recall": 1.0, "hit": 1.0, "ndcg": 1.0,
        }
        m = ranked_metrics(rec, [{9}, {9}], 2)
        assert m == {"recall": 0.0, "hit": 0.0, "ndcg": 0.0}

    def test_pad_ids_never_count(self):
        m = ranked_metrics(np.array([[-1, -1, 4]]), [{4}], 3)
        assert m["hit"] == 1.0 and m["recall"] == 1.0
        # -1 at ranks 0-1 pushed the hit to rank 2 -> discounted NDCG
        assert m["ndcg"] == pytest.approx((1 / np.log2(4)) / (1 / np.log2(2)))


class TestEvaluateRecall:
    def _pairs(self, seed=5, U=80, I=160):
        rng = np.random.default_rng(seed)
        ue = rng.normal(size=(U, 12)).astype(np.float32)
        ie = rng.normal(size=(I, 12)).astype(np.float32)
        train = np.stack([rng.integers(0, U, 500), rng.integers(0, I, 500)], 1)
        evalp = np.stack([rng.integers(0, U, 120), rng.integers(0, I, 120)], 1)
        return ue, ie, train, evalp

    def test_device_equals_oracle_all_strategies(self):
        ue, ie, train, evalp = self._pairs()
        kw = dict(top_k=20, top_n=8, item_chunk=64, user_chunk=17)
        a = evaluate_recall_bruteforce(ue, ie, train, evalp, **kw)
        b = evaluate_recall(ue, ie, train, evalp, method="device", **kw)
        assert a == b
        assert set(a) == {
            f"{s}{m}" for s in ("icf", "ucf", "u2i")
            for m in ("", "_hit", "_ndcg")
        }

    def test_method_invariant_when_topk_covers_catalog(self):
        # top_k == num_items forces filler slots for every user with
        # history; held-out items that also appear in train history make
        # miscounted fillers visible in the metrics
        rng = np.random.default_rng(11)
        U, I = 6, 8
        ue = rng.normal(size=(U, 4)).astype(np.float32)
        ie = rng.normal(size=(I, 4)).astype(np.float32)
        train = np.stack([np.arange(U), rng.integers(0, I, U)], 1)
        evalp = np.concatenate([train[:3], np.stack(
            [np.arange(U), rng.integers(0, I, U)], 1)])  # overlap w/ history
        kw = dict(top_k=I, top_n=I, item_chunk=4)
        a = evaluate_recall_bruteforce(ue, ie, train, evalp, **kw)
        b = evaluate_recall(ue, ie, train, evalp, method="device", **kw)
        assert a == b

    def test_pallas_backend_equals_oracle(self):
        ue, ie, train, evalp = self._pairs(seed=7, U=40, I=90)
        kw = dict(top_k=15, top_n=5, item_chunk=32)
        a = evaluate_recall_bruteforce(ue, ie, train, evalp, **kw)
        b = evaluate_recall(ue, ie, train, evalp, method="device",
                            backend="pallas", **kw)
        assert a == b

    def test_ivf_method_bounded(self):
        ue, ie, train, evalp = self._pairs(seed=9)
        out = evaluate_recall(ue, ie, train, evalp, top_k=20, method="ivf",
                              ivf=IVFConfig(nlist=8, nprobe=8))
        for v in out.values():
            assert 0.0 <= v <= 1.0

    def test_no_subsampling_by_default_and_cap_respected(self):
        ue, ie, train, evalp = self._pairs()
        full = evaluate_recall(ue, ie, train, evalp, top_k=10,
                               strategies=("u2i",))
        capped = evaluate_recall(ue, ie, train, evalp, top_k=10,
                                 strategies=("u2i",), max_users=5, seed=1)
        assert set(full) == set(capped)  # same shape, different user pools
        # determinism: same call twice is identical
        again = evaluate_recall(ue, ie, train, evalp, top_k=10,
                                strategies=("u2i",))
        assert full == again

    def test_strategy_subset_only_computes_requested(self):
        ue, ie, train, evalp = self._pairs()
        out = evaluate_recall(ue, ie, train, evalp, strategies=("u2i",))
        assert set(out) == {"u2i", "u2i_hit", "u2i_ndcg"}

    def test_empty_eval_users(self):
        ue, ie, train, _ = self._pairs()
        out = evaluate_recall(ue, ie, train, np.empty((0, 2), np.int64))
        assert all(v == 0.0 for v in out.values())

    def test_trained_checkpoint_embeddings_method_invariant(
        self, toy_ds, trained_embeddings
    ):
        """On the shared trained-checkpoint fixture (tests/conftest.py) the
        device path still matches the oracle exactly — realistic embedding
        geometry, not just random gaussians."""
        ue, ie, train = trained_embeddings
        evalp = toy_ds.val_pairs
        kw = dict(top_k=20, top_n=8, item_chunk=64)
        a = evaluate_recall_bruteforce(ue, ie, train, evalp, **kw)
        b = evaluate_recall(ue, ie, train, evalp, method="device", **kw)
        assert a == b


class TestChunkSizeValidation:
    """Non-positive chunk widths used to be silently accepted (clamped or
    looped over nothing); they now raise ValueError at the API boundary."""

    def test_ivf_rejects_nonpositive_chunks_and_probes(self):
        it = np.random.default_rng(0).normal(size=(64, 8)).astype(np.float32)
        with pytest.raises(ValueError, match="assign_chunk"):
            IVFIndex.build(it, IVFConfig(nlist=4, assign_chunk=0))
        with pytest.raises(ValueError, match="assign_chunk"):
            IVFIndex.build(it, IVFConfig(nlist=4, assign_chunk=-5))
        with pytest.raises(ValueError, match="nlist"):
            IVFIndex.build(it, IVFConfig(nlist=0))
        with pytest.raises(ValueError, match="nprobe"):
            IVFIndex.build(it, IVFConfig(nlist=4, nprobe=0))
        idx = IVFIndex.build(it, IVFConfig(nlist=4, nprobe=2))
        with pytest.raises(ValueError, match="nprobe"):
            idx.search(it[:3], 5, nprobe=0)
        # custom positive chunk width stays exact
        idx2 = IVFIndex.build(it, IVFConfig(nlist=4, nprobe=4, assign_chunk=7))
        s, i = idx2.search(it[:3], 5)
        s0, i0 = IVFIndex.build(it, IVFConfig(nlist=4, nprobe=4)).search(it[:3], 5)
        assert np.array_equal(i, i0)

    def test_embed_all_nodes_rejects_nonpositive_batch(self, toy_ds, make_model_cfg):
        import jax

        from repro.core.model import init_model_params
        from repro.infer import embed_all_nodes

        g = toy_ds.graph
        cfg = make_model_cfg(g, gnn=False)
        params = init_model_params(jax.random.PRNGKey(0), cfg)
        for bad in (0, -1):
            with pytest.raises(ValueError, match="batch_size"):
                embed_all_nodes(params, cfg, g, g, batch_size=bad)

    def test_chunked_topk_rejects_nonpositive_chunks(self):
        q, it, _ = _data()
        with pytest.raises(ValueError, match="item_chunk"):
            chunked_topk(q, it, 5, item_chunk=0)
        with pytest.raises(ValueError, match="query_chunk"):
            chunked_topk(q, it, 5, query_chunk=-1)
