"""Batched serving engine tests."""
import jax
import numpy as np

from repro.configs import get_arch
from repro.serve import BatchedServer, ServeConfig
import pytest

pytestmark = pytest.mark.quick


def test_generate_batches_and_shapes():
    spec = get_arch("smollm-135m", reduced=True)
    params = spec.init_params(jax.random.PRNGKey(0))
    srv = BatchedServer(spec, params, ServeConfig(batch_size=3, max_new_tokens=5,
                                                  cache_len=32))
    prompts = [[1, 2, 3], [4], [5, 6], [7, 8, 9, 10]]  # 4 requests, batch 3
    outs = srv.generate(prompts)
    assert len(outs) == 4
    assert all(len(o) == 5 for o in outs)
    assert all(0 <= t < spec.lm.vocab_padded for o in outs for t in o)


def test_greedy_deterministic():
    spec = get_arch("qwen2-0.5b", reduced=True)
    params = spec.init_params(jax.random.PRNGKey(1))
    srv = BatchedServer(spec, params, ServeConfig(batch_size=2, max_new_tokens=4,
                                                  cache_len=16))
    a = srv.generate([[1, 2], [3, 4]])
    b = srv.generate([[1, 2], [3, 4]])
    assert a == b


def test_eos_stops_row():
    spec = get_arch("smollm-135m", reduced=True)
    params = spec.init_params(jax.random.PRNGKey(0))
    srv = BatchedServer(spec, params, ServeConfig(batch_size=2, max_new_tokens=8,
                                                  cache_len=32))
    base = srv.generate([[1, 2]])[0]
    eos = base[0]  # force eos = first generated token
    srv2 = BatchedServer(spec, params, ServeConfig(batch_size=2, max_new_tokens=8,
                                                   cache_len=32, eos_id=eos))
    out = srv2.generate([[1, 2]])[0]
    assert out[0] == eos and len(out) == 1
