"""Run-health guardrail tests (repro.obs.health / memory + bench gate).

Pins the contracts of the guardrails PR:

- HealthMonitor: stall detection on synthetic clocks (tiny timeouts, no
  real multi-second sleeps), NaN/Inf and EWMA-divergence loss gates on
  synthetic streams, fault re-raise from ``beat``/``check``, one flight
  record per run, worker-silence degradation through a scripted client,
- the flight-record dump schema CI asserts: ``health.json`` (reason,
  ages, loss tail), ``stacks.txt`` (faulthandler markers — thread *names*
  are not printed, so assertions stay generic), ``trace.json``
  (Perfetto-loadable when telemetry is wired),
- monitoring is a no-op on the training stream: a monitored run's losses
  are bitwise identical to an unmonitored one,
- memory accounting: live-array probe, per-phase high-water peaks, the
  trainer's phase samples, and the measured fused-table footprint feeding
  ``fused_eligibility(measured_bytes=...)``,
- the perf-regression gate (benchmarks/regression.py): direction-aware
  classification, intersection-only comparison, tolerance overrides,
  value-free fingerprints, baseline suppression, and exit codes,
- telemetry satellites: serve-path spans/counters and IVF introspection
  counters leave results bitwise unchanged,
- GraphClient.heartbeat answers for every live worker and goes quiet
  after close.
"""
import gc
import json
import math
import os
import signal
import time

import numpy as np
import pytest

from repro.graph import DistributedGraphEngine, GraphClient, TOY, generate
from repro.obs import (
    HealthConfig,
    HealthMonitor,
    LossAnomalyError,
    MemoryAccountant,
    RunStalledError,
    Telemetry,
    device_memory_stats,
    live_array_bytes,
    memory_snapshot,
)

RELS = ("u2click2i", "i2click2u")

HARD_TIMEOUT_S = 120


@pytest.fixture
def watchdog():
    """Hard per-test timeout for the mp tests (mirrors test_graph_service)."""

    def _expired(signum, frame):
        raise TimeoutError(f"test exceeded hard {HARD_TIMEOUT_S}s watchdog")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="module")
def ds():
    return generate(TOY, seed=0)


def make_trainer(ds, steps=6, engine_backend="inproc", **cfg_kw):
    from repro.core import Graph4RecConfig, HeteroGNNConfig
    from repro.embedding import EmbeddingConfig
    from repro.sampling import EgoConfig, PairConfig, PipelineConfig
    from repro.train import Graph4RecTrainer, TrainerConfig
    from repro.walk import WalkConfig

    mc = Graph4RecConfig(
        embedding=EmbeddingConfig(num_nodes=ds.graph.num_nodes, dim=16),
        gnn=HeteroGNNConfig(gnn_type="lightgcn", num_relations=2,
                            num_layers=1, dim=16),
        fanouts=(3,),
        relations=RELS,
        loss="inbatch_softmax",
    )
    pc = PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
        pair=PairConfig(win_size=2),
        ego=EgoConfig(relations=list(RELS), fanouts=[3]),
        batch_pairs=64, walks_per_round=16,
    )
    engine = (
        ds.graph if engine_backend == "mp"
        else DistributedGraphEngine(ds.graph, num_partitions=2)
    )
    cfg = TrainerConfig(num_steps=steps, log_every=0, eval_at_end=False,
                        seed=0, engine_backend=engine_backend, **cfg_kw)
    return Graph4RecTrainer(ds, engine, mc, pc, cfg)


def fast_cfg(tmp_path, **kw):
    """A monitor config with millisecond clocks (no real waits) that
    flight-records into the test's tmp dir."""
    base = dict(
        stall_timeout_s=0.05, poll_interval_s=0.01, worker_heartbeat_s=0.0,
        flightrec_dir=str(tmp_path / "flightrec"),
    )
    base.update(kw)
    return HealthConfig(**base)


def wait_for(predicate, timeout_s=10.0):
    deadline = time.monotonic() + timeout_s
    while not predicate():
        if time.monotonic() > deadline:
            return False
        time.sleep(0.005)
    return True


# ----------------------------------------------------------------- stalls
@pytest.mark.quick
class TestStallWatchdog:
    def test_stall_dumps_and_arms_fault(self, tmp_path):
        tel = Telemetry()
        with tel.tracer.span("warmup", cat="test"):
            pass
        mon = HealthMonitor(fast_cfg(tmp_path), telemetry=tel)
        mon.start()
        try:
            assert wait_for(lambda: mon.fault is not None)
        finally:
            mon.stop()
        assert isinstance(mon.fault, RunStalledError)
        assert "stall_timeout_s=0.05" in str(mon.fault)
        # the training thread surfaces the fault on its next touchpoint
        with pytest.raises(RunStalledError):
            mon.check()
        with pytest.raises(RunStalledError):
            mon.beat(0)
        assert tel.metrics.summary()["counters"]["health.stalls"] == 1

    def test_flight_record_schema(self, tmp_path):
        """The dump layout the CI trace-smoke job asserts."""
        tel = Telemetry()
        with tel.tracer.span("step", cat="trainer"):
            pass
        mon = HealthMonitor(fast_cfg(tmp_path), telemetry=tel)
        mon.observe_losses([0.5, 0.25])
        mon.start()
        assert wait_for(lambda: mon.fault is not None)
        mon.stop()
        rec = mon.fault.flightrec
        assert rec is not None and os.path.isdir(rec)
        assert os.path.basename(rec).startswith(f"{os.getpid()}-00-")
        assert os.path.basename(rec).endswith("-stall")
        with open(os.path.join(rec, "health.json")) as f:
            health = json.load(f)
        assert health["reason"] == "stall"
        assert health["losses_tail"] == [0.5, 0.25]
        assert health["beat_age_s"] >= 0.05
        assert health["context"]["alive_age_s"] >= 0.05
        assert health["metrics"]["counters"]["health.stalls"] == 1
        with open(os.path.join(rec, "trace.json")) as f:
            trace = json.load(f)
        names = {e.get("name") for e in trace["traceEvents"]}
        assert "step" in names  # the Perfetto snapshot is loadable + real
        with open(os.path.join(rec, "stacks.txt")) as f:
            stacks = f.read()
        # faulthandler prints thread ids, not names: assert on the frame
        # markers every dump carries
        assert "Thread" in stacks and "File" in stacks

    def test_one_dump_per_run_and_watchdog_exits(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path))
        mon.start()
        thread = mon._thread
        assert wait_for(lambda: mon.fault is not None)
        # the watchdog retires itself after arming (one dump per run)
        assert wait_for(lambda: not thread.is_alive())
        root = str(tmp_path / "flightrec")
        assert len(os.listdir(root)) == 1
        mon.stop()

    def test_beats_and_pulses_keep_it_alive(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path, stall_timeout_s=0.1,
                                     poll_interval_s=0.02))
        mon.start()
        try:
            deadline = time.monotonic() + 0.3
            step = 0
            while time.monotonic() < deadline:
                mon.beat(step)
                mon.pulse()
                step += 1
                time.sleep(0.02)
            assert mon.fault is None
            mon.check()  # does not raise
        finally:
            mon.stop()
        assert not os.path.exists(str(tmp_path / "flightrec"))

    def test_start_stop_idempotent(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path, stall_timeout_s=60.0))
        mon.start()
        first = mon._thread
        mon.start()
        assert mon._thread is first
        mon.stop()
        mon.stop()
        assert mon._thread is None

    def test_no_telemetry_still_dumps(self, tmp_path):
        """Health without tracing: no trace.json, everything else intact."""
        mon = HealthMonitor(fast_cfg(tmp_path))
        mon.start()
        assert wait_for(lambda: mon.fault is not None)
        mon.stop()
        rec = mon.fault.flightrec
        assert sorted(os.listdir(rec)) == ["health.json", "stacks.txt"]
        with open(os.path.join(rec, "health.json")) as f:
            assert "metrics" not in json.load(f)


# ----------------------------------------------------------- loss anomaly
@pytest.mark.quick
class TestLossAnomaly:
    def test_nan_fails_immediately(self, tmp_path):
        tel = Telemetry()
        mon = HealthMonitor(fast_cfg(tmp_path), telemetry=tel)
        mon.observe_losses([0.9, 0.8])
        with pytest.raises(LossAnomalyError, match="non-finite") as ei:
            mon.observe_losses([0.7, float("nan")])
        rec = ei.value.flightrec
        assert rec is not None and rec.endswith("-loss-anomaly")
        with open(os.path.join(rec, "health.json")) as f:
            health = json.load(f)
        assert health["reason"] == "loss-anomaly"
        tail = health["losses_tail"]
        assert tail[:3] == [0.9, 0.8, 0.7] and math.isnan(tail[3])
        assert tel.metrics.summary()["counters"]["health.loss_anomalies"] == 1
        # the fault is sticky: the step loop dies on its next beat
        with pytest.raises(LossAnomalyError):
            mon.beat(3)

    def test_inf_fails_too(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path))
        with pytest.raises(LossAnomalyError, match="non-finite"):
            mon.observe_losses([float("inf")])

    def test_nan_check_off_is_permissive(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path, nan_check=False))
        mon.observe_losses([0.5, float("nan"), float("inf"), 0.4])
        assert mon.fault is None

    def test_divergence_after_window(self, tmp_path):
        mon = HealthMonitor(
            fast_cfg(tmp_path, divergence_window=8, divergence_zmax=6.0)
        )
        # a stable-but-noisy stream trains the EWMA without tripping it
        stream = [1.0 + 0.01 * ((-1) ** i) for i in range(20)]
        mon.observe_losses(stream)
        assert mon.fault is None
        with pytest.raises(LossAnomalyError, match="diverged"):
            mon.observe_losses([50.0])

    def test_no_divergence_within_window(self, tmp_path):
        """The first `window` observations never z-score: a cold EWMA has
        no business rejecting the warmup losses."""
        mon = HealthMonitor(
            fast_cfg(tmp_path, divergence_window=8, divergence_zmax=6.0)
        )
        mon.observe_losses([1.0, 1.0, 1.0, 900.0])  # wild, but pre-window
        assert mon.fault is None

    def test_realistic_decay_stays_healthy(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path, divergence_window=16))
        rng = np.random.default_rng(0)
        steps = np.arange(200)
        losses = 2.0 * np.exp(-steps / 80.0) + 0.1 + rng.normal(0, 0.02, 200)
        mon.observe_losses(losses)
        assert mon.fault is None

    def test_divergence_window_zero_disables(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path, divergence_window=0))
        mon.observe_losses([1.0] * 50 + [1e9])
        assert mon.fault is None
        with pytest.raises(LossAnomalyError):  # NaN gate stays armed
            mon.observe_losses([float("nan")])

    def test_loss_tail_bounded(self, tmp_path):
        mon = HealthMonitor(fast_cfg(tmp_path, divergence_window=0,
                                     loss_tail=16))
        mon.observe_losses(np.linspace(1.0, 0.5, 100))
        assert len(mon._loss_tail) == 16


# -------------------------------------------------------- worker liveness
class _ScriptedClient:
    """A GraphClient stand-in whose heartbeat answers are scripted."""

    def __init__(self, alive):
        self.alive = dict(alive)
        self.calls = 0
        self._last_stats = {0: {"batches": 7}}
        self._dead = {}

    def heartbeat(self, timeout=5.0):
        self.calls += 1
        return dict(self.alive)


@pytest.mark.quick
class TestWorkerLiveness:
    def test_silent_worker_marks_degraded_not_fatal(self, tmp_path):
        tel = Telemetry()
        client = _ScriptedClient({0: False, 1: True})
        cfg = fast_cfg(tmp_path, stall_timeout_s=60.0, poll_interval_s=0.01,
                       worker_heartbeat_s=0.02, worker_silent_rounds=2)
        mon = HealthMonitor(cfg, telemetry=tel, client=client)
        mon.start()
        try:
            assert wait_for(lambda: mon.degraded)
        finally:
            mon.stop()
        assert client.calls >= 2
        mon.check()  # degraded is a warning state, never a fault
        snap = tel.metrics.summary()
        assert snap["counters"]["health.worker_silent"] == 1
        assert snap["gauges"]["health.degraded"]["value"] == 1.0
        marks = [name for name, _, _, _ in tel.tracer.marks()]
        assert "health.degraded" in marks
        # the silent worker's streak and the healthy worker's reset
        assert mon._silent[0] >= 2 and mon._silent[1] == 0
        # degraded state rides into any later flight record
        rec = mon.dump("test")
        with open(os.path.join(rec, "health.json")) as f:
            health = json.load(f)
        assert health["degraded"] is True
        assert health["workers"]["last_stats"]["0"]["batches"] == 7
        assert health["workers"]["silent_rounds"]["0"] >= 2

    def test_heartbeat_errors_are_not_health_events(self, tmp_path):
        class Exploding:
            calls = 0

            def heartbeat(self, timeout=5.0):
                self.calls += 1
                raise RuntimeError("client racing shutdown")

        client = Exploding()
        cfg = fast_cfg(tmp_path, stall_timeout_s=60.0, poll_interval_s=0.01,
                       worker_heartbeat_s=0.02)
        mon = HealthMonitor(cfg, client=client)
        mon.start()
        try:
            assert wait_for(lambda: client.calls >= 2)
        finally:
            mon.stop()
        assert mon.fault is None and not mon.degraded


@pytest.mark.mp
@pytest.mark.usefixtures("watchdog")
class TestGraphClientHeartbeat:
    def test_heartbeat_live_and_closed(self, ds):
        with GraphClient(ds.graph, num_partitions=2, num_workers=2) as c:
            alive = c.heartbeat(timeout=10.0)
            assert alive == {0: True, 1: True}
            # the heartbeat rides the stats op: last_stats is now warm,
            # so a flight record would carry real per-worker counters
            assert set(c._last_stats) == {0, 1}
            again = c.heartbeat(timeout=10.0)
            assert again == {0: True, 1: True}
        assert c.heartbeat() == {}  # closed client: quiet, not an error


# ------------------------------------------------------ trainer integration
@pytest.mark.quick
class TestTrainerGuardrails:
    def test_monitored_run_is_bitwise_noop(self, ds, tmp_path):
        """The headline contract: guardrails on != numbers change."""
        plain = make_trainer(ds, steps=8, prefetch_batches=2).train()
        guarded = make_trainer(
            ds, steps=8, prefetch_batches=2,
            health=fast_cfg(tmp_path, stall_timeout_s=600.0),
        ).train()
        np.testing.assert_array_equal(
            np.asarray(plain.losses), np.asarray(guarded.losses)
        )
        traced = make_trainer(
            ds, steps=8, prefetch_batches=2, telemetry=Telemetry(),
            health=fast_cfg(tmp_path, stall_timeout_s=600.0),
        ).train()
        np.testing.assert_array_equal(
            np.asarray(plain.losses), np.asarray(traced.losses)
        )
        assert not os.path.exists(str(tmp_path / "flightrec"))

    def test_monitor_lifecycle_and_loss_feed(self, ds, tmp_path):
        tr = make_trainer(ds, steps=8, prefetch_batches=2,
                          health=fast_cfg(tmp_path, stall_timeout_s=600.0))
        res = tr.train()
        mon = tr._health_monitor
        assert mon is not None
        assert mon._thread is None  # stopped in the run's finally
        assert mon.fault is None
        # every drained loss reached the anomaly gate
        assert mon._loss_tail[-1] == float(res.losses[-1])
        assert mon._last_step == 7

    def test_off_by_default(self, ds):
        tr = make_trainer(ds, steps=4, prefetch_batches=2)
        tr.train()
        assert tr.cfg.health is None and tr._health_monitor is None

    def test_memory_phases_sampled(self, ds):
        tel = Telemetry()
        tr = make_trainer(ds, steps=6, prefetch_batches=2, telemetry=tel)
        tr.train()
        mem = tr._memory
        assert mem is not None
        assert {"tables", "steady"} <= set(mem.peaks)
        assert all(v > 0 for v in mem.peaks.values())
        gauges = tel.metrics.summary()["gauges"]
        assert gauges["memory.tables_bytes"]["max"] > 0
        assert gauges["memory.steady_bytes"]["max"] > 0


# -------------------------------------------------------- memory accounting
@pytest.mark.quick
class TestMemoryAccounting:
    def test_live_array_probe_sees_new_arrays(self):
        import jax.numpy as jnp

        gc.collect()
        base = live_array_bytes()
        x = jnp.arange(65536, dtype=jnp.int32)
        x.block_until_ready()
        assert live_array_bytes() >= base + x.nbytes
        assert live_array_bytes() >= 0

    def test_device_stats_gated(self):
        stats = device_memory_stats()  # {} on the CPU backend — never raises
        assert isinstance(stats, dict)
        for per_dev in stats.values():
            assert all(isinstance(v, int) for v in per_dev.values())

    def test_accountant_peaks_and_summary(self):
        from repro.obs import MetricsRegistry

        reg = MetricsRegistry()
        acc = MemoryAccountant(reg)
        n1 = acc.sample("build")
        with acc.scope("steady"):
            pass
        assert acc.peaks["build"] == n1 >= 0
        assert "steady" in acc.peaks
        s = acc.summary()
        assert set(s) == {"phase_peak_bytes", "live_array_bytes",
                          "device_stats"}
        assert s["phase_peak_bytes"] == acc.peaks
        assert reg.summary()["gauges"]["memory.build_bytes"]["value"] == n1

    def test_peak_is_high_water(self):
        acc = MemoryAccountant()
        acc.peaks["p"] = 10**15  # pretend an earlier sample was larger
        acc.sample("p")
        assert acc.peaks["p"] == 10**15

    def test_snapshot_shape(self):
        snap = memory_snapshot()
        assert set(snap) == {"live_array_bytes", "device_stats"}


# -------------------------------------------------- fused measured budget
@pytest.mark.quick
class TestFusedMeasuredBudget:
    def _graph_and_cfg(self):
        from repro.graph.hetero_graph import HeteroGraph
        from repro.sampling import PairConfig, PipelineConfig
        from repro.walk import WalkConfig

        src = np.repeat(np.arange(6), 5)
        dst = np.tile(np.arange(5), 6)
        g = HeteroGraph.from_edges(
            {"u": 6, "i": 5}, {"u2click2i": (src, dst)}, symmetry=True
        )
        pc = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=5),
            pair=PairConfig(win_size=2), batch_pairs=32, walks_per_round=16,
        )
        return g, pc

    def test_device_table_bytes_measures_resident_arrays(self):
        from repro.sampling.fused import FusedSampler

        g, pc = self._graph_and_cfg()
        fs = FusedSampler(g, pc)
        measured = fs.device_table_bytes()
        # at least adjacency + degree rows are resident
        assert measured >= fs._adj.nbytes + fs._deg.nbytes > 0

    def test_eligibility_on_measured_bytes(self):
        from repro.sampling.fused import FusedConfig, fused_eligibility

        g, pc = self._graph_and_cfg()
        ok, reason = fused_eligibility(g, pc)
        assert ok and "(estimated)" in reason
        ok, reason = fused_eligibility(g, pc, measured_bytes=1024)
        assert ok and "(measured)" in reason
        ok, reason = fused_eligibility(
            g, pc, fused=FusedConfig(budget_mb=0.0001),
            measured_bytes=1 << 20,
        )
        assert not ok and "(measured)" in reason and "budget" in reason

    def test_trainer_plan_carries_measured_bytes(self, ds):
        tr = make_trainer(ds, steps=4, prefetch_batches=0,
                          sampling_backend="fused")
        res = tr.train()
        assert res.plan["sampling"] == "fused"
        measured = res.plan["fused_measured_bytes"]
        assert isinstance(measured, int) and measured > 0

    def test_host_plan_has_no_measured_bytes(self, ds):
        res = make_trainer(ds, steps=4, prefetch_batches=0,
                           sampling_backend="host").train()
        assert res.plan["fused_measured_bytes"] is None


# ------------------------------------------------------- regression gate
@pytest.mark.quick
class TestRegressionGate:
    def test_classify_directions(self):
        from benchmarks.regression import (
            HIGHER_BETTER, LOWER_BETTER, classify,
        )

        assert classify("chunked_qps") == HIGHER_BETTER
        assert classify("pairs_per_sec_prefetch") == HIGHER_BETTER
        assert classify("speedup_auto") == HIGHER_BETTER
        assert classify("ivf_recall_at_k") == HIGHER_BETTER
        assert classify("ivf_build_s") == LOWER_BETTER
        assert classify("per_call_us") == LOWER_BETTER
        assert classify("wall_s_traced") == LOWER_BETTER
        assert classify("round_latency_ns") == LOWER_BETTER
        # config/count leaves are out of scope for the gate
        for leaf in ("steps", "nlist", "nprobe", "chunked_temp_bytes",
                     "dataset", "quick", "item_chunk", "num_workers",
                     "trace_events", "fused_measured_bytes"):
            assert classify(leaf) is None, leaf

    def test_flatten_numeric_leaves(self):
        from benchmarks.regression import flatten

        got = flatten({"a": {"b": 1, "flag": True, "s": "text"},
                       "c": 2.5, "d": {"e": {"f": 3}}})
        assert got == {"a.b": 1.0, "c": 2.5, "d.e.f": 3.0}

    def test_compare_is_direction_aware(self):
        from benchmarks.regression import compare

        committed = {"pipeline": {"pairs_per_sec_prefetch": 1000.0,
                                  "wall_s": 2.0}}
        assert compare(committed, committed) == []
        # higher-better falling beyond the band is a finding; rising never
        fell = {"pipeline": {"pairs_per_sec_prefetch": 400.0, "wall_s": 2.0}}
        [f] = compare(committed, fell)
        assert f["metric"] == "pipeline.pairs_per_sec_prefetch"
        assert f["direction"] == "higher-better"
        assert "fell" in f["message"]
        rose = {"pipeline": {"pairs_per_sec_prefetch": 5000.0, "wall_s": 2.0}}
        assert compare(committed, rose) == []
        # lower-better is the mirror image
        slow = {"pipeline": {"pairs_per_sec_prefetch": 1000.0, "wall_s": 3.5}}
        [f] = compare(committed, slow)
        assert f["direction"] == "lower-better" and "rose" in f["message"]
        fast = {"pipeline": {"pairs_per_sec_prefetch": 1000.0, "wall_s": 0.5}}
        assert compare(committed, fast) == []

    def test_compare_intersection_only(self):
        from benchmarks.regression import compare

        committed = {"retrieval": {"I10000": {"ivf_qps": 100.0,
                                              "seed_qps": 50.0}}}
        fresh = {"retrieval": {"I10000": {"ivf_qps": 90.0}},
                 "extra": {"other_qps": 1.0}}
        assert compare(committed, fresh) == []  # 0.9x is inside the band

    def test_tolerance_override_for_recall(self):
        from benchmarks.regression import compare, tolerance_for

        assert tolerance_for("retrieval.I10000.ivf_recall_at_k") == 0.10
        assert tolerance_for("pipeline.wall_s") == 0.5
        committed = {"retrieval": {"ivf_recall_at_k": 1.0}}
        [f] = compare(committed, {"retrieval": {"ivf_recall_at_k": 0.85}})
        assert f["tolerance"] == 0.10
        assert compare(committed,
                       {"retrieval": {"ivf_recall_at_k": 0.95}}) == []

    def test_fingerprint_is_value_free(self):
        from benchmarks.regression import compare, fingerprint

        committed = {"p": {"wall_s": 2.0}}
        [a] = compare(committed, {"p": {"wall_s": 4.0}})
        [b] = compare(committed, {"p": {"wall_s": 40.0}})
        assert fingerprint(a) == fingerprint(b) == "lower-better:p.wall_s"

    def test_baseline_roundtrip(self, tmp_path):
        from benchmarks.regression import (
            compare, load_baseline, write_baseline,
        )

        path = str(tmp_path / "bench_baseline.json")
        assert load_baseline(path) == set()
        findings = compare({"p": {"wall_s": 2.0}}, {"p": {"wall_s": 4.0}})
        write_baseline(findings, path)
        assert load_baseline(path) == {"lower-better:p.wall_s"}

    def test_main_exit_codes(self, tmp_path, capsys):
        from benchmarks.regression import main

        committed = {"pipeline": {"pairs_per_sec_prefetch": 1000.0,
                                  "wall_s": 2.0, "steps": 64}}
        cpath = tmp_path / "BENCH.json"
        cpath.write_text(json.dumps(committed))
        bpath = str(tmp_path / "baseline.json")

        def run(fresh):
            fpath = tmp_path / "fresh.json"
            fpath.write_text(json.dumps(fresh))
            return main(["--against", str(cpath), "--compare", str(fpath),
                         "--baseline", bpath])

        assert run(committed) == 0
        assert "2 direction-aware metrics compared" in capsys.readouterr().out
        bad = {"pipeline": {"pairs_per_sec_prefetch": 100.0, "wall_s": 2.0}}
        assert run(bad) == 1
        assert "REGRESSION" in capsys.readouterr().out
        # --write-baseline accepts today's findings; the rerun passes
        fpath = tmp_path / "fresh.json"
        fpath.write_text(json.dumps(bad))
        assert main(["--against", str(cpath), "--compare", str(fpath),
                     "--baseline", bpath, "--write-baseline"]) == 0
        assert run(bad) == 0
        assert "(1 baselined)" in capsys.readouterr().out
        # recovery makes the stale fingerprint harmless
        assert run(committed) == 0
        # no committed benchmarks at all is its own failure mode
        assert main(["--against", str(tmp_path / "missing.json"),
                     "--compare", str(cpath), "--baseline", bpath]) == 2

    def test_committed_benchmarks_have_gated_metrics(self):
        """The real committed JSONs must expose direction-aware leaves —
        otherwise the gate silently compares nothing."""
        from benchmarks.regression import classify, flatten, load_committed

        committed = load_committed(["BENCH_throughput.json",
                                    "BENCH_recall.json"])
        assert committed, "committed benchmark JSONs missing from the repo"
        gated = [p for p in flatten(committed)
                 if classify(p.rsplit(".", 1)[-1]) is not None]
        assert len(gated) >= 10


# ---------------------------------------------------- telemetry satellites
@pytest.mark.quick
class TestServeTelemetry:
    def test_serve_spans_and_metrics(self):
        import jax

        from repro.configs import get_arch
        from repro.serve import BatchedServer, ServeConfig

        spec = get_arch("smollm-135m", reduced=True)
        params = spec.init_params(jax.random.PRNGKey(0))
        cfg = ServeConfig(batch_size=2, max_new_tokens=3, cache_len=32)
        tel = Telemetry()
        srv = BatchedServer(spec, params, cfg, telemetry=tel)
        prompts = [[1, 2], [3], [4, 5, 6]]  # 3 requests -> 2 batches
        outs = srv.generate(prompts)
        assert BatchedServer(spec, params, cfg).generate(prompts) == outs
        snap = tel.metrics.summary()
        assert snap["counters"]["serve.requests"] == 3
        assert snap["histograms"]["serve.request_ns"]["count"] == 3
        assert snap["gauges"]["serve.queue_depth"]["max"] == 3.0
        assert snap["gauges"]["serve.queue_depth"]["value"] == 0.0
        spans = [s for _, _, ss, _ in tel.tracer.threads() for s in ss]
        batches = [s for s in spans if s[0] == "serve.batch"]
        assert len(batches) == 2
        assert sum(s[4]["requests"] for s in batches) == 3
        assert all(s[1] == "serve" for s in batches)


@pytest.mark.quick
class TestIVFTelemetry:
    def test_ivf_counters_leave_results_unchanged(self):
        from repro.core.recall import evaluate_recall
        from repro.retrieval.ivf import IVFConfig, IVFIndex

        rng = np.random.default_rng(3)
        U, I = 30, 80
        ue = rng.normal(size=(U, 12)).astype(np.float32)
        ie = rng.normal(size=(I, 12)).astype(np.float32)
        train = np.stack([rng.integers(0, U, 400), rng.integers(0, I, 400)], 1)
        evalp = np.stack([rng.integers(0, U, 90), rng.integers(0, I, 90)], 1)
        kw = dict(top_k=20, method="ivf", strategies=("u2i",),
                  ivf=IVFConfig(nlist=8, nprobe=4, balance_factor=2.0))
        tel = Telemetry()
        counted = evaluate_recall(ue, ie, train, evalp, telemetry=tel, **kw)
        plain = evaluate_recall(ue, ie, train, evalp, **kw)
        assert counted == plain  # introspection never changes retrieval
        counters = tel.metrics.summary()["counters"]
        # u2i searches each held-out user with history exactly once,
        # probing nprobe cells per user
        n_users = len(set(evalp[:, 0].tolist()) & set(train[:, 0].tolist()))
        assert counters["ivf.cells_probed"] == n_users * 4
        # candidates_scored counts the true CSR list lengths actually
        # gathered (not the padded budget): pin it against a direct search
        # of the same unique users — the count is a sum over queries, so
        # user order is irrelevant, and exclusion/k never change it
        from repro.core.recall import _normalize

        item_idx = IVFIndex.build(_normalize(ie), kw["ivf"])
        users = np.fromiter(
            sorted(set(evalp[:, 0].tolist()) & set(train[:, 0].tolist())),
            np.int64,
        )
        item_idx.search(_normalize(ue)[users], kw["top_k"])
        assert counters["ivf.candidates_scored"] == item_idx.last_candidates_scored
        assert 0 < counters["ivf.candidates_scored"] <= (
            n_users * item_idx.candidates_per_query
        )
        # spill accounting covers both the item and the user index
        both = sum(
            IVFIndex.build(e, kw["ivf"]).spilled_items for e in (ie, ue)
        )
        assert counters["ivf.spill_events"] == both >= 0

    def test_spilled_items_counted_on_build(self):
        from repro.retrieval.ivf import IVFConfig, IVFIndex

        rng = np.random.default_rng(0)
        # one dense cluster + noise: the hot cell must spill under a cap
        pts = np.concatenate([
            rng.normal(0, 0.01, size=(200, 8)),
            rng.normal(5, 1.0, size=(40, 8)),
        ]).astype(np.float32)
        capped = IVFIndex.build(pts, IVFConfig(nlist=16, balance_factor=1.0))
        uncapped = IVFIndex.build(pts, IVFConfig(nlist=16, balance_factor=0.0))
        assert capped.spilled_items > 0
        assert uncapped.spilled_items == 0
