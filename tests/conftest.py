import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches run on the single real CPU device; only launch/dryrun.py (run
# as its own process) forces 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
