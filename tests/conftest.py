import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches run on the single real CPU device; only launch/dryrun.py (run
# as its own process) forces 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# The relation pair every recsys test drives (u--click-->i and its reverse).
RELS = ("u2click2i", "i2click2u")


@pytest.fixture(scope="session")
def toy_ds():
    """The shared tiny synthetic dataset (TOY spec, seed 0).

    Session-scoped: generation costs ~a second and the graph is read-only
    in every consumer, so walk/sampling, infer, retrieval, system and fused
    tests all share one instance instead of regenerating per module.
    """
    from repro.graph import TOY, generate

    return generate(TOY, seed=0)


@pytest.fixture(scope="session")
def toy_ds_alt():
    """Second TOY instance (seed 1) for tests that want an independent
    graph (e.g. the mp graph-service suite)."""
    from repro.graph import TOY, generate

    return generate(TOY, seed=1)


@pytest.fixture(scope="session")
def make_model_cfg():
    """Factory for the small Graph4RecConfig the serving-layer tests share
    (previously copy-pasted as ``_model_cfg`` in test_infer and friends)."""
    from repro.core import Graph4RecConfig, HeteroGNNConfig
    from repro.embedding import EmbeddingConfig, SlotSpec

    def _make(g, gnn=True, side_info=False, dim=16, slot_mode="bag",
              loss="inbatch_softmax"):
        slots = (
            (SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 3))
            if side_info else ()
        )
        return Graph4RecConfig(
            embedding=EmbeddingConfig(num_nodes=g.num_nodes, dim=dim, slots=slots),
            gnn=HeteroGNNConfig(gnn_type="lightgcn", num_relations=2,
                                num_layers=2, dim=dim) if gnn else None,
            fanouts=(4, 3) if gnn else (),
            relations=RELS,
            use_side_info=side_info,
            slot_mode=slot_mode,
            loss=loss,
        )

    return _make


@pytest.fixture(scope="session")
def trained_embeddings(toy_ds, make_model_cfg):
    """A small trained checkpoint's (user_emb, item_emb) matrices.

    Shared by retrieval/recall tests that only need *plausible* trained
    embeddings, so each module stops training its own throwaway model.
    Returns (user_emb, item_emb, train_pairs) as float32/int64 arrays.
    """
    import jax

    from repro.core.model import init_model_params
    from repro.graph import DistributedGraphEngine
    from repro.infer import embed_all_nodes

    g = toy_ds.graph
    cfg = make_model_cfg(g, gnn=False)
    params = init_model_params(jax.random.PRNGKey(42), cfg)
    eng = DistributedGraphEngine(g, num_partitions=2)
    all_emb = embed_all_nodes(params, cfg, eng, g, batch_size=256, seed=3)
    user_emb = all_emb[: toy_ds.num_users]
    item_emb = all_emb[toy_ds.num_users : toy_ds.num_users + toy_ds.num_items]
    train_pairs = np.concatenate(
        [np.stack([u, i], 1) for (u, i) in toy_ds.train_edges.values()], axis=0
    )
    return user_emb, item_emb, train_pairs
