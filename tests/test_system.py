"""End-to-end behaviour tests: the full Graph4Rec pipeline (walk -> ego ->
pair -> GNN -> loss -> recall) on a synthetic multi-behavior graph."""
import os

import jax
import numpy as np
import pytest

from repro.core import Graph4RecConfig, HeteroGNNConfig
from repro.embedding import EmbeddingConfig, SlotSpec
from repro.graph import DistributedGraphEngine, TOY, generate
from repro.sampling import EgoConfig, PairConfig, PipelineConfig
from repro.train import Graph4RecTrainer, TrainerConfig, checkpoint
from repro.walk import WalkConfig

RELS = ("u2click2i", "i2click2u")


def build_trainer(ds, gnn_type="lightgcn", walk_based=False, steps=30,
                  use_side_info=False, loss="inbatch_softmax", seed=0):
    g = ds.graph
    slots = (
        (SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 3))
        if use_side_info else ()
    )
    mc = Graph4RecConfig(
        embedding=EmbeddingConfig(num_nodes=g.num_nodes, dim=32, slots=slots),
        gnn=None if walk_based else HeteroGNNConfig(
            gnn_type=gnn_type, num_relations=2, num_layers=2, dim=32),
        fanouts=() if walk_based else (4, 3),
        relations=RELS,
        use_side_info=use_side_info,
        loss=loss,
    )
    pc = PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
        pair=PairConfig(win_size=2,
                        neg_mode="random" if loss == "neg_sampling" else "inbatch"),
        ego=None if walk_based else EgoConfig(relations=list(RELS), fanouts=[4, 3]),
        batch_pairs=128, walks_per_round=48,
    )
    eng = DistributedGraphEngine(g, num_partitions=4)
    return Graph4RecTrainer(
        ds, eng, mc, pc,
        TrainerConfig(num_steps=steps, log_every=0, eval_max_users=96, seed=seed,
                      sparse_lr=1.0),
    )


@pytest.fixture(scope="module")
def ds():
    return generate(TOY, seed=0)


class TestEndToEnd:
    def test_gnn_training_beats_random_init(self, ds):
        tr = build_trainer(ds, "lightgcn", steps=60)
        params0 = tr.init_params()
        before = tr.evaluate(params0)
        res = tr.train(params0)
        after = res.eval_history[-1]
        # batch losses are noisy; compare window means
        assert np.mean(res.losses[-10:]) < np.mean(res.losses[:10])
        assert after["u2i"] > before["u2i"], (before, after)

    @pytest.mark.quick
    def test_walk_based_training_runs(self, ds):
        tr = build_trainer(ds, walk_based=True, steps=40)
        res = tr.train()
        assert np.isfinite(res.losses).all()
        assert np.mean(res.losses[-10:]) < np.mean(res.losses[:10])
        assert res.pairs_seen == 40 * 128

    def test_side_info_pipeline(self, ds):
        tr = build_trainer(ds, "sage-mean", steps=10, use_side_info=True)
        res = tr.train()
        assert np.isfinite(res.losses).all()

    def test_neg_sampling_loss_mode(self, ds):
        tr = build_trainer(ds, walk_based=True, steps=5, loss="neg_sampling")
        res = tr.train()
        assert np.isfinite(res.losses).all()


class TestWarmStart:
    def test_warm_start_inherits_and_improves_start(self, ds):
        """Paper §3.6: pre-train walk-based embeddings, inherit into the GNN."""
        walk_tr = build_trainer(ds, walk_based=True, steps=60)
        walk_res = walk_tr.train()

        gnn_tr = build_trainer(ds, "lightgcn", steps=1)
        cold = gnn_tr.init_params()
        warm = dict(cold)
        warm["emb/node"] = walk_res.params["emb/node"]
        cold_eval = gnn_tr.evaluate(cold)
        warm_eval = gnn_tr.evaluate(warm)
        assert warm_eval["u2i"] >= cold_eval["u2i"]


class TestCheckpoint:
    def test_roundtrip(self, ds, tmp_path):
        tr = build_trainer(ds, "gin", steps=2)
        res = tr.train()
        path = os.path.join(tmp_path, "ckpt.npz")
        checkpoint.save(path, res.params)
        loaded = checkpoint.load_dict(path)
        for k, v in res.params.items():
            np.testing.assert_array_equal(np.asarray(v), loaded[k])

    def test_eval_deterministic_after_reload(self, ds, tmp_path):
        tr = build_trainer(ds, "lightgcn", steps=3)
        res = tr.train()
        path = os.path.join(tmp_path, "ckpt.npz")
        checkpoint.save(path, res.params)
        loaded = checkpoint.load_dict(path)
        ev1 = tr.evaluate(res.params)
        ev2 = tr.evaluate({k: np.asarray(v) for k, v in loaded.items()})
        assert ev1 == ev2


@pytest.mark.quick
def test_every_test_module_has_a_quick_test():
    """Quick-marker audit: `make test-fast` must touch every subsystem, so
    each test module carries at least one @pytest.mark.quick (or module
    pytestmark) — new test files fail here until they add one."""
    import pathlib

    missing = []
    for p in sorted(pathlib.Path(__file__).parent.glob("test_*.py")):
        text = p.read_text()
        # an actual marker, not just the word "quick" in prose
        if "pytest.mark.quick" not in text and "pytestmark" not in text:
            missing.append(p.name)
    assert not missing, f"test files without a quick marker: {missing}"
