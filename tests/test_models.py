"""Per-arch smoke tests (reduced configs: <=2 layers of the same family,
d_model<=512, <=4 experts) + model-level consistency properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_arch
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import qwen2_vl as VLM
from repro.models.mamba2 import (
    Mamba2Config, init_mamba2, init_mamba_cache, mamba2_decode_step,
    mamba2_forward,
)

KEY = jax.random.PRNGKey(0)


def make_batch(spec, B=2, S=64):
    if spec.kind == "whisper":
        return {
            "audio_embeds": jnp.ones(
                (B, spec.whisper.n_audio_frames, spec.d_model), jnp.float32) * 0.01,
            "tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    if spec.kind == "vlm":
        return {
            "tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
            "patch_embeds": jnp.ones((B, spec.n_patches, spec.d_model), jnp.float32) * 0.01,
        }
    return {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }


@pytest.mark.parametrize("arch_id", ARCH_IDS)
class TestArchSmoke:
    def test_train_step(self, arch_id):
        """One forward/train step on CPU: output shapes + no NaNs."""
        spec = get_arch(arch_id, reduced=True)
        params = spec.init_params(KEY)
        batch = make_batch(spec)
        loss = jax.jit(spec.make_train_loss())(params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss)), arch_id

    def test_grad_step_updates_params(self, arch_id):
        from repro.train import optimizer as opt_lib

        spec = get_arch(arch_id, reduced=True)
        opt = opt_lib.adam(1e-3)
        params = spec.init_params(KEY)
        opt_state = opt.init(params)
        step = jax.jit(spec.make_train_step(opt))
        batch = make_batch(spec)
        new_params, _, loss = step(params, opt_state, batch)
        assert np.isfinite(float(loss))
        # at least the embedding table must have moved
        before = np.asarray(jax.tree_util.tree_leaves(params)[0])
        after = np.asarray(jax.tree_util.tree_leaves(new_params)[0])
        assert not np.array_equal(before, after)

    def test_decode_step_shapes(self, arch_id):
        spec = get_arch(arch_id, reduced=True)
        params = spec.init_params(KEY)
        B = 2
        if spec.kind == "whisper":
            from repro.models import whisper as W

            audio = jnp.ones((B, spec.whisper.n_audio_frames, spec.d_model),
                             jnp.float32) * 0.01
            cache = W.init_cache(params, spec.whisper, audio, 16)
            vocab = spec.whisper.vocab_padded
        else:
            cache = T.init_cache(spec.lm, B, 16)
            vocab = spec.lm.vocab_padded
        serve = jax.jit(spec.make_serve_step())
        logits, cache = serve(params, cache, {"token": jnp.zeros((B, 1), jnp.int32)})
        logits2, _ = serve(params, cache, {"token": jnp.ones((B, 1), jnp.int32)})
        assert logits.shape == (B, vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert np.isfinite(np.asarray(logits2, np.float32)).all()

    def test_prefill_last_logits(self, arch_id):
        spec = get_arch(arch_id, reduced=True)
        params = spec.init_params(KEY)
        batch = make_batch(spec)
        out = jax.jit(spec.make_prefill())(params, batch)
        assert out.shape[0] == 2
        assert np.isfinite(np.asarray(out, np.float32)).all()


class TestDecodeConsistency:
    """Step-by-step decode must reproduce the full forward (teacher forcing)."""

    @pytest.mark.parametrize("arch_id", [
        "smollm-135m", "qwen2-0.5b", "starcoder2-7b", "deepseek-coder-33b",
        "mamba2-1.3b", "jamba-v0.1-52b", "mixtral-8x22b", "olmoe-1b-7b",
    ])
    def test_forward_vs_decode(self, arch_id):
        spec = get_arch(arch_id, reduced=True)
        cfg = spec.lm
        if cfg.moe is not None:
            # capacity drops are GShard semantics; disable for exactness
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
            )
        params = spec.init_params(jax.random.PRNGKey(1))
        B, S = 2, 32
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
        full, _ = jax.jit(lambda p, t: T.forward(p, cfg, t))(params, toks)
        cache = T.init_cache(cfg, B, S)
        step = jax.jit(lambda p, c, t: T.decode_step(p, cfg, c, t))
        outs = []
        for i in range(S):
            lg, cache = step(params, cache, toks[:, i : i + 1])
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        rel = float(jnp.max(jnp.abs(dec - full))) / (
            float(jnp.max(jnp.abs(full))) + 1e-9
        )
        assert rel < 2e-2, (arch_id, rel)

    @pytest.mark.quick
    def test_sliding_window_ring_cache(self):
        """Ring cache (SWA) must match full forward with window mask."""
        spec = get_arch("starcoder2-7b", reduced=True)
        cfg = spec.lm  # sliding_window=16
        params = spec.init_params(jax.random.PRNGKey(3))
        B, S = 1, 48  # 3x the window
        toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
        full, _ = jax.jit(lambda p, t: T.forward(p, cfg, t))(params, toks)
        cache = T.init_cache(cfg, B, cfg.sliding_window)  # ring of 16
        assert cache["layers"][0]["k"].shape[2] == cfg.sliding_window
        step = jax.jit(lambda p, c, t: T.decode_step(p, cfg, c, t))
        outs = []
        for i in range(S):
            lg, cache = step(params, cache, toks[:, i : i + 1])
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
        assert rel < 2e-2, rel

    def test_unrolled_equals_scan(self):
        """scan_layers=False (dry-run probes) is numerically identical."""
        for arch_id in ("smollm-135m", "mamba2-1.3b", "olmoe-1b-7b"):
            spec = get_arch(arch_id, reduced=True)
            params = spec.init_params(jax.random.PRNGKey(5))
            toks = jax.random.randint(jax.random.PRNGKey(6), (2, 32), 0, spec.lm.vocab)
            a, _ = jax.jit(lambda p, t: T.forward(p, spec.lm, t))(params, toks)
            cfg_u = dataclasses.replace(spec.lm, scan_layers=False)
            b, _ = jax.jit(lambda p, t: T.forward(p, cfg_u, t))(params, toks)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestMamba2:
    CFG = Mamba2Config(d_model=64, d_state=16, headdim=16, expand=2, chunk=8)

    def test_chunk_boundaries_invisible(self):
        """Different chunk sizes must give identical outputs (SSD exactness)."""
        p = init_mamba2(KEY, self.CFG, jnp.float32)
        u = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
        outs = []
        for chunk in (4, 8, 16, 32):
            cfg = dataclasses.replace(self.CFG, chunk=chunk)
            outs.append(np.asarray(mamba2_forward(p, cfg, u)))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=1e-4)

    def test_forward_matches_stepwise(self):
        p = init_mamba2(KEY, self.CFG, jnp.float32)
        u = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 64))
        full = np.asarray(mamba2_forward(p, self.CFG, u))
        cache = init_mamba_cache(self.CFG, 2, jnp.float32)
        outs = []
        for i in range(16):
            y, cache = mamba2_decode_step(p, self.CFG, cache, u[:, i : i + 1])
            outs.append(np.asarray(y))
        dec = np.concatenate(outs, axis=1)
        np.testing.assert_allclose(dec, full, atol=1e-4)

    def test_state_decay_bounded(self):
        """For zero input the SSM state decays (A negative)."""
        p = init_mamba2(KEY, self.CFG, jnp.float32)
        cache = init_mamba_cache(self.CFG, 1, jnp.float32)
        cache = {**cache, "ssm": jnp.ones_like(cache["ssm"])}
        u = jnp.zeros((1, 1, 64))
        _, c2 = mamba2_decode_step(p, self.CFG, cache, u)
        assert float(jnp.abs(c2["ssm"]).max()) <= 1.0 + 1e-5


class TestRoPE:
    def test_mrope_text_degenerates_to_rope(self):
        """Equal (t,h,w) coordinates == standard RoPE (paper property)."""
        B, S, H, hd = 2, 16, 2, 32
        x = jax.random.normal(KEY, (B, S, H, hd))
        pos1 = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        cos1, sin1 = L.rope_cos_sin(pos1, hd, 10000.0)
        pos3 = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3))
        cos3, sin3 = L.rope_cos_sin(pos3, hd, 10000.0, mrope_sections=(4, 6, 6))
        np.testing.assert_allclose(np.asarray(cos1), np.asarray(cos3), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(L.apply_rope(x, cos1, sin1)),
            np.asarray(L.apply_rope(x, cos3, sin3)), atol=1e-6,
        )

    def test_rope_preserves_norm(self):
        x = jax.random.normal(KEY, (1, 8, 1, 64))
        pos = jnp.arange(8)[None]
        cos, sin = L.rope_cos_sin(pos, 64, 10000.0)
        y = L.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
        )

    def test_mrope_positions_layout(self):
        pos = VLM.mrope_positions(1, 24, 16, (4, 4), image_start=1)
        pos = np.asarray(pos[0])
        # text prefix: all three equal
        assert (pos[0] == pos[0, 0]).all()
        # image span: temporal frozen
        assert (pos[1:17, 0] == 1).all()
        # spatial ids walk the 4x4 grid
        assert pos[1, 1] == 1 and pos[1, 2] == 1
        assert pos[6, 1] == 1 + 1 and pos[6, 2] == 1 + 1  # patch 5 -> (1,1)
        # post-image text resumes and is strictly increasing
        assert (np.diff(pos[17:, 0]) == 1).all()


class TestMoECapacity:
    def test_capacity_drops_bounded(self):
        """Dropped tokens ride the residual; output stays finite and close."""
        from repro.models.moe import MoEConfig, init_moe, moe_forward

        cfg_tight = MoEConfig(d_model=32, d_ff=64, num_experts=4, top_k=2,
                              capacity_factor=0.5, group_size=32)
        cfg_loose = dataclasses.replace(cfg_tight, capacity_factor=8.0)
        p = init_moe(KEY, cfg_tight, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 32, 32))
        y_tight, aux_t = moe_forward(p, cfg_tight, x)
        y_loose, aux_l = moe_forward(p, cfg_loose, x)
        assert np.isfinite(np.asarray(y_tight)).all()
        # tight capacity zeroes some tokens' expert output
        assert float(jnp.abs(y_tight).sum()) < float(jnp.abs(y_loose).sum())
        assert float(aux_t) >= 1.0 - 1e-3  # Switch aux lower bound E*Σf·P >= 1
