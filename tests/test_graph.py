"""Heterogeneous graph structure + distributed engine tests."""
import numpy as np
import pytest

pytestmark = pytest.mark.quick

from repro.graph import (
    CSR, DistributedGraphEngine, HeteroGraph, Relation, TOY, generate,
)


def toy_graph():
    return HeteroGraph.from_edges(
        node_counts={"u": 3, "i": 4},
        edges={"u2click2i": (np.array([0, 0, 1, 2]), np.array([0, 1, 2, 3]))},
        symmetry=True,
    )


class TestRelation:
    def test_parse_triple(self):
        r = Relation.parse("u2click2i")
        assert (r.src_type, r.etype, r.dst_type) == ("u", "click", "i")

    def test_parse_homogeneous(self):
        r = Relation.parse("u2u")
        assert (r.src_type, r.dst_type) == ("u", "u")

    def test_reverse_name(self):
        assert Relation.parse("u2buy2i").reverse_name == "i2buy2u"

    def test_bad_relation(self):
        with pytest.raises(ValueError):
            Relation.parse("u2a2b2c")


class TestHeteroGraph:
    def test_symmetry_adds_reverse(self):
        g = toy_graph()
        assert "i2click2u" in g.relations
        # reverse edges mirror forward ones
        fwd = g.relations["u2click2i"]
        rev = g.relations["i2click2u"]
        assert fwd.num_edges == rev.num_edges == 4

    def test_global_id_ranges(self):
        g = toy_graph()
        assert g.node_type_ranges["u"] == (0, 3)
        assert g.node_type_ranges["i"] == (3, 4)
        assert g.num_nodes == 7
        assert g.node_type_of(0) == "u"
        assert g.node_type_of(4) == "i"

    def test_adjacency(self):
        g = toy_graph()
        # user 0 clicked items 0,1 -> global 3,4
        assert sorted(g.relations["u2click2i"].neighbors(0).tolist()) == [3, 4]
        # item 2 (global 5) was clicked by user 1
        assert g.relations["i2click2u"].neighbors(5).tolist() == [1]

    def test_sample_neighbors_validity(self):
        g = toy_graph()
        rng = np.random.default_rng(0)
        nodes = np.array([0, 1, 2, 6])
        out = g.sample_neighbors(rng, nodes, "u2click2i", 5)
        assert out.shape == (4, 5)
        for row, node in zip(out, nodes):
            nbrs = set(g.relations["u2click2i"].neighbors(node).tolist())
            for x in row:
                assert (x == -1 and not nbrs) or x in nbrs

    def test_sample_no_neighbors_pads(self):
        g = toy_graph()
        rng = np.random.default_rng(0)
        out = g.sample_neighbors(rng, np.array([3]), "u2click2i", 3)
        assert (out == -1).all()  # items have no u2click2i out-edges

    def test_padded_adjacency(self):
        g = toy_graph()
        adj, deg = g.padded_adjacency("u2click2i", max_degree=3)
        assert adj.shape == (7, 3)
        assert deg[0] == 2 and deg[3] == 0
        assert set(adj[0][: deg[0]].tolist()) == {3, 4}


class TestGenerator:
    def test_toy_dataset(self):
        ds = generate(TOY, seed=0)
        g = ds.graph
        assert g.num_nodes == TOY.num_users + TOY.num_items
        assert "u2click2i" in g.relations and "i2click2u" in g.relations
        assert len(ds.val_pairs) > 0 and len(ds.test_pairs) > 0
        # all eval pairs in range
        assert ds.val_pairs[:, 0].max() < TOY.num_users
        assert ds.val_pairs[:, 1].max() < TOY.num_items
        # side info slots exist and are cluster-correlated
        assert "slot0" in g.slots

    def test_deterministic(self):
        a = generate(TOY, seed=3)
        b = generate(TOY, seed=3)
        assert a.graph.num_edges == b.graph.num_edges
        np.testing.assert_array_equal(a.val_pairs, b.val_pairs)


class TestDistributedEngine:
    def test_matches_graph_adjacency(self):
        ds = generate(TOY, seed=1)
        eng = DistributedGraphEngine(ds.graph, num_partitions=4)
        rng = np.random.default_rng(0)
        nodes = np.arange(0, 60, 7)
        out = eng.sample_neighbors(rng, nodes, "u2click2i", 4)
        for row, node in zip(out, nodes):
            nbrs = set(ds.graph.relations["u2click2i"].neighbors(node).tolist())
            for x in row:
                assert (x == -1 and not nbrs) or x in nbrs

    def test_stats_count_cross_partition(self):
        ds = generate(TOY, seed=1)
        eng = DistributedGraphEngine(ds.graph, num_partitions=4, client_part=0)
        rng = np.random.default_rng(0)
        eng.sample_neighbors(rng, np.arange(40), "u2click2i", 2)
        assert eng.stats.neighbor_requests == 40
        # ids 1,2,3 mod 4 != 0 -> 30 of 40 are remote
        assert eng.stats.cross_partition_requests == 30
