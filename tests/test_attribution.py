"""Per-step time attribution + adaptive backend selection tests.

Covers the PhaseTimer (ring-buffer accounting, summary math), the
calibrated execution plan (explicit settings win; cheap samplers degrade
to serial; auto runs are bitwise-identical to explicitly-configured ones),
and the committed BENCH_throughput.json regression pins — the three
end-to-end ratios this PR flips stay pinned by the committed numbers, not
by re-timing on (noisy) CI machines.
"""
import json
import os

import numpy as np
import pytest

from repro.core import Graph4RecConfig, HeteroGNNConfig
from repro.embedding import EmbeddingConfig
from repro.graph import DistributedGraphEngine, TOY, generate
from repro.sampling import EgoConfig, PairConfig, PipelineConfig
from repro.train import Graph4RecTrainer, TrainerConfig
from repro.train.attribution import (
    PHASES,
    PhaseTimer,
    measure_handoff_overhead,
    median,
    phase_scope,
)
from repro.walk import WalkConfig

pytestmark = pytest.mark.quick

RELS = ("u2click2i", "i2click2u")

_JSON_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_throughput.json"
)


@pytest.fixture(scope="module")
def ds():
    return generate(TOY, seed=0)


def make_trainer(ds, gnn=True, steps=6, **cfg_kw):
    mc = Graph4RecConfig(
        embedding=EmbeddingConfig(num_nodes=ds.graph.num_nodes, dim=16),
        gnn=HeteroGNNConfig(gnn_type="lightgcn", num_relations=2,
                            num_layers=1, dim=16) if gnn else None,
        fanouts=(3,) if gnn else (),
        relations=RELS,
        loss="inbatch_softmax",
    )
    pc = PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
        pair=PairConfig(win_size=2),
        ego=EgoConfig(relations=list(RELS), fanouts=[3]) if gnn else None,
        batch_pairs=64, walks_per_round=16,
    )
    eng = DistributedGraphEngine(ds.graph, num_partitions=2)
    cfg = TrainerConfig(num_steps=steps, log_every=0, eval_at_end=False,
                        seed=0, **cfg_kw)
    return Graph4RecTrainer(ds, eng, mc, pc, cfg)


class TestPhaseTimer:
    def test_add_and_total(self):
        t = PhaseTimer()
        for _ in range(3):
            t.add("h2d", 0.5)
        assert t.total("h2d") == pytest.approx(1.5)
        assert t.total("sample") == 0.0

    def test_ring_extrapolates_by_count(self):
        """Past capacity, the retained window is scaled by count: N equal
        durations total N*d no matter how small the ring is."""
        t = PhaseTimer(capacity=4)
        for _ in range(10):
            t.add("dispatch", 0.1)
        assert t.total("dispatch") == pytest.approx(1.0)

    def test_phase_context_records_duration(self):
        t = PhaseTimer()
        with t.phase("sample"):
            pass
        s = t.summary()
        assert s["phases"]["sample"]["count"] == 1
        assert s["phases"]["sample"]["total_s"] >= 0.0

    def test_summary_accounting(self):
        t = PhaseTimer()
        t.add("sample", 0.2)      # producer side
        t.add("batch_wait", 0.1)  # consumer side from here down
        t.add("h2d", 0.2)
        t.add("dispatch", 0.3)
        t.add("loss_fetch", 0.1)
        s = t.summary(wall_s=1.0, steps=10)
        assert s["host_visible_s"] == pytest.approx(0.7)
        assert s["device_residual_s"] == pytest.approx(0.3)
        assert s["wall_us_per_step"] == pytest.approx(1e5)
        assert s["phases"]["sample"]["frac_of_wall"] == pytest.approx(0.2)
        assert set(s["phases"]) <= set(PHASES)

    def test_phase_scope_nullcontext(self):
        with phase_scope(None, "sample"):
            pass
        t = PhaseTimer()
        with phase_scope(t, None):
            pass
        assert all(t.total(p) == 0.0 for p in PHASES)
        with phase_scope(t, "h2d"):
            pass
        assert t.summary()["phases"]["h2d"]["count"] == 1

    def test_handoff_probe_and_median(self):
        per_item = measure_handoff_overhead(items=64)
        assert 0.0 < per_item < 0.1  # a queue handoff is micro-, not deci-s
        assert median([3.0, 1.0, 2.0]) == 2.0
        assert median([4.0, 1.0, 2.0, 3.0]) == 2.5
        with pytest.raises(ValueError):
            median([])


class TestExecutionPlan:
    def test_explicit_settings_never_calibrate(self, ds):
        tr = make_trainer(ds, steps=40, prefetch_batches=3,
                          auto_backend=True)
        res = tr.train()
        assert res.plan["calibrated"] is False
        assert res.plan["prefetch"] == 3
        assert res.plan["sampling"] == "host"

    def test_short_run_uses_legacy_default(self, ds):
        tr = make_trainer(ds, steps=6)  # < calibrate_min_steps
        res = tr.train()
        assert res.plan["calibrated"] is False
        assert res.plan["prefetch"] == 2  # legacy depth
        assert "too short" in res.plan["reason"]

    def test_auto_backend_off_uses_legacy_default(self, ds):
        tr = make_trainer(ds, steps=40, auto_backend=False)
        res = tr.train()
        assert res.plan["calibrated"] is False
        assert res.plan["prefetch"] == 2

    def test_calibration_produces_measurements(self, ds):
        tr = make_trainer(ds, steps=36, calibrate_min_steps=32)
        res = tr.train()
        assert res.plan["calibrated"] is True
        m = res.plan["measurements"]
        assert m["host_batch_s"] > 0 and m["step_s"] > 0
        assert m["handoff_s"] > 0
        assert res.plan["prefetch"] in (0, 2)
        # the plan is cached: a second train() must not recalibrate
        assert tr._plan is res.plan or tr._plan == res.plan

    def test_cheap_sampler_degrades_to_serial(self, ds, monkeypatch):
        """The walk-based 0.85x regression case: when the measured host cost
        is too small for the overlap to beat the handoff, auto picks the
        serial loop. Measurements are injected so the decision rule is
        tested deterministically, not via wall clocks."""
        tr = make_trainer(ds, gnn=False, steps=36)
        monkeypatch.setattr(
            Graph4RecTrainer, "_calibrate",
            lambda self, params: {
                "host_batch_s": 1e-4, "step_s": 5e-4, "handoff_s": 2e-4,
            },
        )
        plan = tr._resolve_plan(tr.init_params())
        assert plan["calibrated"] is True
        assert plan["prefetch"] == 0
        assert "serial" in plan["reason"]

    def test_expensive_both_sides_picks_prefetch(self, ds, monkeypatch):
        tr = make_trainer(ds, steps=36)
        monkeypatch.setattr(
            Graph4RecTrainer, "_calibrate",
            lambda self, params: {
                "host_batch_s": 5e-3, "step_s": 5e-3, "handoff_s": 5e-5,
            },
        )
        plan = tr._resolve_plan(tr.init_params())
        assert plan["prefetch"] == 2
        assert "prefetch" in plan["reason"]

    def test_auto_sampling_picks_fused_when_faster(self, ds, monkeypatch):
        tr = make_trainer(ds, steps=36, sampling_backend="auto")
        monkeypatch.setattr(
            Graph4RecTrainer, "_calibrate",
            lambda self, params: {
                "host_batch_s": 5e-3, "step_s": 5e-3, "handoff_s": 5e-5,
                "fused_step_s": 1e-3,
            },
        )
        # _calibrate is mocked, so build the fused step the way the real
        # calibration would have
        ok, _ = tr._build_fused()
        assert ok
        plan = tr._resolve_plan(tr.init_params())
        assert plan["sampling"] == "fused"
        assert plan["prefetch"] == 0

    def test_auto_run_matches_explicit_run_bitwise(self, ds):
        """Calibration must not perturb the training stream: an auto run's
        loss trajectory is bit-identical to an explicit run configured the
        way the plan resolved."""
        auto = make_trainer(ds, steps=36, calibrate_min_steps=32)
        res_auto = auto.train()
        assert res_auto.plan["calibrated"] is True
        explicit = make_trainer(
            ds, steps=36, prefetch_batches=res_auto.plan["prefetch"],
            auto_backend=False,
        )
        res_exp = explicit.train()
        np.testing.assert_array_equal(res_auto.losses, res_exp.losses)

    def test_walk_based_auto_matches_serial_bitwise(self, ds):
        """Whatever the plan picks for the cheap walk-based sampler, the
        result is the serial stream, bit for bit."""
        auto = make_trainer(ds, gnn=False, steps=36, calibrate_min_steps=32)
        res_auto = auto.train()
        serial = make_trainer(ds, gnn=False, steps=36, prefetch_batches=0,
                              auto_backend=False)
        res_serial = serial.train()
        np.testing.assert_array_equal(res_auto.losses, res_serial.losses)


class TestAttributionInTrainer:
    def test_attribution_off_by_default(self, ds):
        res = make_trainer(ds, steps=4).train()
        assert res.attribution is None

    def test_attribution_summary_shape(self, ds):
        res = make_trainer(ds, steps=6, attribution=True,
                           prefetch_batches=2).train()
        a = res.attribution
        assert a["steps"] == 6
        assert a["wall_s"] > 0
        for phase in ("sample", "assemble", "batch_wait", "h2d", "dispatch"):
            assert a["phases"][phase]["count"] > 0, phase
        assert a["host_visible_s"] <= a["wall_s"] + 1e-6

    def test_attribution_serial_mode(self, ds):
        res = make_trainer(ds, steps=6, attribution=True,
                           prefetch_batches=0).train()
        assert res.attribution["phases"]["dispatch"]["count"] == 6

    def test_attribution_fused_mode(self, ds):
        res = make_trainer(ds, steps=6, attribution=True,
                           sampling_backend="fused").train()
        a = res.attribution
        assert a["phases"]["dispatch"]["count"] == 6
        # fused mode bypasses the host pipeline and the stager entirely
        assert "sample" not in a["phases"]
        assert "h2d" not in a["phases"]


class TestCommittedBenchmarkPins:
    """Regression pins on the committed BENCH_throughput.json: the ratios
    this PR's tentpole flipped must stay flipped in the committed numbers.
    (CI re-times nothing — shared-runner wall clocks are noise; the bench
    is rerun and the JSON recommitted whenever the pipeline changes.)"""

    @pytest.fixture(scope="class")
    def bench(self):
        with open(_JSON_PATH) as f:
            return json.load(f)

    def test_attribution_section_covers_backend_matrix(self, bench):
        attr = bench["step_attribution"]
        combos = [k for k in attr if "/" in k]
        assert len(combos) >= 4, combos
        engines = {c.split("/")[0] for c in combos}
        modes = {c.split("/")[1] for c in combos}
        assert {"inproc", "mp"} <= engines
        assert {"serial", "prefetch", "fused"} <= modes
        for c in combos:
            entry = attr[c]
            assert entry["phases"], c
            assert entry["wall_s"] > 0
            assert entry["steps"] > 0

    def test_mp_pipeline_no_longer_a_regression(self, bench):
        assert bench["engine_service"]["pipeline_mp_speedup"] >= 1.0

    def test_fused_pipeline_speedup(self, bench):
        assert bench["walk_fusion"]["pipeline_fused_speedup"] >= 1.5

    def test_walk_based_auto_not_slower_than_serial(self, bench):
        assert bench["pipeline/walk-based"]["speedup_auto"] >= 1.0


_RECALL_JSON_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_recall.json"
)


class TestCommittedRecallPins:
    """Regression pins on the committed BENCH_recall.json: the ANN rebuild
    ("IVF is slower than brute force at every scale") must stay flipped.
    Same contract as the throughput pins — the committed numbers are the
    record, CI never re-times."""

    @pytest.fixture(scope="class")
    def retrieval(self):
        with open(_RECALL_JSON_PATH) as f:
            return json.load(f)["retrieval"]

    def test_ivf_beats_chunked_at_serving_scale(self, retrieval):
        # 100k up: the index must pay for itself (10k sits below the
        # crossover deliberately — docs/retrieval.md)
        for arm_key in ("I100000", "I1000000", "I10000000"):
            arm = retrieval[arm_key]
            assert arm["ivf_qps"] > arm["chunked_qps"], (arm_key, arm)
            assert arm["ivf_speedup_median_vs_chunked"] > 1.0, (arm_key, arm)

    def test_1m_acceptance_10x_at_recall_95(self, retrieval):
        arm = retrieval["I1000000"]
        assert arm["ivf_qps"] >= 10 * arm["chunked_qps"], arm
        assert arm["ivf_recall_at_k"] >= 0.95, arm

    def test_10m_arm_memory_shape(self, retrieval):
        # the arm whose existence forced int8 codes + host re-rank: list
        # width stays bounded (balance cap), recall stays usable
        arm = retrieval["I10000000"]
        assert arm["ivf_recall_at_k"] >= 0.95, arm
        assert arm["ivf_lpad"] <= 1.5 * 10_000_000 / arm["ivf_nlist"], arm

    def test_crossover_arm_recorded(self, retrieval):
        # the honest small-table answer is "use chunked_topk"; keep the
        # arm that documents where the line is
        assert "I10000" in retrieval
        assert retrieval["I10000"]["ivf_recall_at_k"] >= 0.90
