"""Random-walk + ego/pair sampling pipeline tests (paper §3.2-3.4, §3.6)."""
import numpy as np
import pytest

pytestmark = pytest.mark.quick

from repro.graph import DistributedGraphEngine, TOY, generate
from repro.sampling import (
    EgoConfig, PAD, PairConfig, PipelineConfig, SamplePipeline,
    sample_ego_batch, window_pairs, pairs_to_nodes,
)
from repro.walk import MetapathWalker, WalkConfig, parse_metapath


@pytest.fixture(scope="module")
def ds():
    return generate(TOY, seed=0)


class TestMetapath:
    def test_parse(self):
        assert parse_metapath("u2click2i - i2click2u") == ["u2click2i", "i2click2u"]

    def test_parse_type_mismatch(self):
        with pytest.raises(ValueError):
            parse_metapath("u2click2i - u2click2i")

    def test_walk_follows_relations(self, ds):
        cfg = WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6)
        walker = MetapathWalker(ds.graph, cfg)
        rng = np.random.default_rng(0)
        starts = walker.start_nodes(rng, 0, 16)
        paths = walker.walk(rng, starts, 0)
        assert paths.shape == (16, 6)
        rels = ["u2click2i", "i2click2u"]
        for row in paths:
            for step in range(1, 6):
                if row[step] == PAD:
                    continue
                rel = ds.graph.relations[rels[(step - 1) % 2]]
                assert row[step] in rel.neighbors(row[step - 1])

    def test_walk_alternates_types(self, ds):
        cfg = WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=5)
        walker = MetapathWalker(ds.graph, cfg)
        rng = np.random.default_rng(1)
        paths = walker.walk(rng, walker.start_nodes(rng, 0, 8), 0)
        nu = TOY.num_users
        for row in paths:
            for step, node in enumerate(row):
                if node == PAD:
                    continue
                expected = "u" if step % 2 == 0 else "i"
                got = "u" if node < nu else "i"
                assert got == expected

    def test_pad_after_dead_end(self, ds):
        cfg = WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=8)
        walker = MetapathWalker(ds.graph, cfg)
        rng = np.random.default_rng(2)
        paths = walker.generate(rng, 32)
        for row in paths:
            seen_pad = False
            for x in row:
                if x == PAD:
                    seen_pad = True
                else:
                    assert not seen_pad  # PAD only as suffix


class TestEgo:
    def test_level_widths(self, ds):
        cfg = EgoConfig(relations=["u2click2i", "i2click2u"], fanouts=[3, 2])
        rng = np.random.default_rng(0)
        ego = sample_ego_batch(rng, ds.graph, np.arange(5), cfg)
        assert ego.levels[0].shape == (5, 1)
        assert ego.levels[1].shape == (5, 2 * 3)
        assert ego.levels[2].shape == (5, 6 * 2 * 2)
        assert cfg.level_width(2) == 24

    def test_relation_slices_are_neighbors(self, ds):
        cfg = EgoConfig(relations=["u2click2i", "i2click2u"], fanouts=[4])
        rng = np.random.default_rng(0)
        centers = np.arange(8)
        ego = sample_ego_batch(rng, ds.graph, centers, cfg)
        lvl = ego.levels[1].reshape(8, 1, 2, 4)
        for b, c in enumerate(centers):
            for ri, rel in enumerate(cfg.relations):
                nbrs = set(ds.graph.relations[rel].neighbors(c).tolist())
                for x in lvl[b, 0, ri]:
                    assert (x == PAD and not nbrs) or x in nbrs

    def test_pad_propagates(self, ds):
        # a center with no neighbors under the relation -> all levels PAD
        cfg = EgoConfig(relations=["u2click2i"], fanouts=[2, 2])
        rng = np.random.default_rng(0)
        item_node = np.array([TOY.num_users])  # items have no u2click2i edges
        ego = sample_ego_batch(rng, ds.graph, item_node, cfg)
        assert (ego.levels[1] == PAD).all()
        assert (ego.levels[2] == PAD).all()


class TestPairs:
    def test_window_pairs(self):
        paths = np.array([[1, 2, 3, PAD]])
        pairs = window_pairs(paths, win_size=2)
        got = {(r[1], r[2]) for r in pairs}
        assert got == {(0, 1), (1, 0), (1, 2), (2, 1), (0, 2), (2, 0)}

    def test_window_respects_pad(self):
        paths = np.array([[1, PAD, 3]])
        pairs = window_pairs(paths, win_size=2)
        for r in pairs:
            assert paths[r[0], r[1]] != PAD and paths[r[0], r[2]] != PAD


class TestPipelineOrders:
    """RQ5: ego-first does O(L) ego samplings, pair-first O(wL)."""

    def _run(self, ds, order):
        eng = DistributedGraphEngine(ds.graph, num_partitions=4)
        cfg = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
            pair=PairConfig(win_size=2),
            ego=EgoConfig(relations=["u2click2i", "i2click2u"], fanouts=[3]),
            order=order, batch_pairs=64, walks_per_round=16,
        )
        pipe = SamplePipeline(eng, cfg, seed=0)
        batches = list(pipe.batches(3))
        return pipe, batches

    def test_batches_fixed_size(self, ds):
        _, batches = self._run(ds, "walk_ego_pair")
        for b in batches:
            assert len(b.src_ids) == 64
            assert b.src_ego.levels[0].shape[0] == 64

    def test_ego_first_cheaper(self, ds):
        pipe_fast, _ = self._run(ds, "walk_ego_pair")
        pipe_slow, _ = self._run(ds, "walk_pair_ego")
        assert pipe_fast.ego_sampling_ops < pipe_slow.ego_sampling_ops

    def test_pair_endpoints_match_ego_centers(self, ds):
        _, batches = self._run(ds, "walk_ego_pair")
        for b in batches:
            np.testing.assert_array_equal(b.src_ids, b.src_ego.centers)
            np.testing.assert_array_equal(b.dst_ids, b.dst_ego.centers)

    def test_random_negative_mode(self, ds):
        eng = DistributedGraphEngine(ds.graph, num_partitions=2)
        cfg = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
            pair=PairConfig(win_size=2, neg_mode="random", num_negatives=3),
            ego=EgoConfig(relations=["u2click2i", "i2click2u"], fanouts=[2]),
            batch_pairs=32, walks_per_round=16,
        )
        pipe = SamplePipeline(eng, cfg, seed=0)
        b = next(iter(pipe.batches(1)))
        assert b.neg_ids.shape == (32, 3)
        assert b.neg_ego.levels[0].shape[0] == 32 * 3
