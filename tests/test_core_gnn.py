"""GNN zoo + relation-wise aggregation (Eq. 3) + loss tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.quick

from repro.core import gnn as G
from repro.core.hetero import HeteroGNNConfig, hetero_forward, init_hetero_params
from repro.core import loss as loss_lib


KEY = jax.random.PRNGKey(0)


def rand_inputs(B=2, W=3, F=4, d=16, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    h_self = jax.random.normal(k1, (B, W, d))
    h_nbr = jax.random.normal(k2, (B, W, F, d))
    mask = jax.random.bernoulli(k3, 0.7, (B, W, F))
    return h_self, h_nbr, mask


class TestZoo:
    @pytest.mark.parametrize("gnn_type", G.GNN_TYPES)
    def test_shapes_and_finite(self, gnn_type):
        h_self, h_nbr, mask = rand_inputs()
        p = G.init_layer(KEY, gnn_type, 16)
        out = G.apply_layer(p, gnn_type, h_self, h_nbr, mask)
        assert out.shape == (2, 3, 16)
        assert np.isfinite(np.asarray(out)).all()

    def test_lightgcn_parameter_free(self):
        assert G.init_layer(KEY, "lightgcn", 16) == {}

    def test_lightgcn_is_masked_mean(self):
        h_self, h_nbr, mask = rand_inputs()
        out = G.apply_layer({}, "lightgcn", h_self, h_nbr, mask)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(G.masked_mean(h_nbr, mask)), rtol=1e-6
        )

    @pytest.mark.parametrize("gnn_type", G.GNN_TYPES)
    def test_all_pad_neighbors_no_nan(self, gnn_type):
        h_self, h_nbr, _ = rand_inputs()
        mask = jnp.zeros((2, 3, 4), bool)
        p = G.init_layer(KEY, gnn_type, 16)
        out = G.apply_layer(p, gnn_type, h_self, h_nbr, mask)
        assert np.isfinite(np.asarray(out)).all()

    def test_masked_mean_ignores_invalid(self):
        h = jnp.ones((1, 1, 3, 4)) * jnp.array([1.0, 100.0, 100.0])[None, None, :, None]
        mask = jnp.array([[[True, False, False]]])
        out = G.masked_mean(h, mask)
        np.testing.assert_allclose(np.asarray(out), 1.0)

    def test_kernel_aggregation_matches(self):
        h_self, h_nbr, mask = rand_inputs()
        ref = G.masked_mean(h_nbr, mask)
        G.use_kernel_aggregation(True)
        try:
            got = G.masked_mean(h_nbr, mask)
        finally:
            G.use_kernel_aggregation(False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


class TestHetero:
    def make(self, gnn_type="lightgcn", agg="uniform", alpha=0.15):
        cfg = HeteroGNNConfig(
            gnn_type=gnn_type, num_relations=2, num_layers=2, dim=8,
            alpha=alpha, relation_agg=agg,
        )
        params = init_hetero_params(KEY, cfg)
        return cfg, params

    def feats(self, cfg, B=3, seed=0):
        R, d = cfg.num_relations, cfg.dim
        fanouts = [2, 2]
        widths = [1]
        for f in fanouts:
            widths.append(widths[-1] * R * f)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(widths))
        feats = [jax.random.normal(k, (B, w, d)) for k, w in zip(keys, widths)]
        masks = [jnp.ones((B, w), bool) for w in widths]
        return feats, masks, fanouts

    def test_output_shape(self):
        cfg, params = self.make()
        feats, masks, fanouts = self.feats(cfg)
        out = hetero_forward(params, cfg, feats, masks, fanouts)
        assert out.shape == (3, 8)

    def test_alpha_one_returns_h0(self):
        """α=1 disables propagation entirely (pure residual)."""
        cfg, params = self.make(alpha=1.0)
        feats, masks, fanouts = self.feats(cfg)
        out = hetero_forward(params, cfg, feats, masks, fanouts)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(feats[0][:, 0, :]), atol=1e-6
        )

    def test_gatne_attention_differs_from_uniform(self):
        cfg_u, params_u = self.make(agg="uniform")
        cfg_g, params_g = self.make(agg="gatne")
        feats, masks, fanouts = self.feats(cfg_u)
        out_u = hetero_forward(params_u, cfg_u, feats, masks, fanouts)
        # gatne params include attention weights
        assert "att/W" in params_g and "att/w" in params_g
        out_g = hetero_forward(params_g, cfg_g, feats, masks, fanouts)
        assert not np.allclose(np.asarray(out_u), np.asarray(out_g))

    @pytest.mark.parametrize("gnn_type", ["gcn", "sage-mean", "gat", "gin", "ngcf"])
    def test_all_zoo_members_compose(self, gnn_type):
        cfg, params = self.make(gnn_type=gnn_type)
        feats, masks, fanouts = self.feats(cfg)
        out = hetero_forward(params, cfg, feats, masks, fanouts)
        assert np.isfinite(np.asarray(out)).all()


class TestLosses:
    def test_eq2_prefers_aligned_pairs(self):
        k = jax.random.PRNGKey(0)
        h = jax.random.normal(k, (8, 16))
        neg = jax.random.normal(jax.random.PRNGKey(1), (8, 5, 16))
        aligned = loss_lib.neg_sampling_loss(h, h, neg)
        shuffled = loss_lib.neg_sampling_loss(h, jnp.roll(h, 1, axis=0), neg)
        assert float(aligned) < float(shuffled)

    def test_inbatch_softmax_minimum_at_identity(self):
        h = jnp.eye(8) * 10.0
        loss_id = loss_lib.inbatch_softmax_loss(h, h)
        loss_mix = loss_lib.inbatch_softmax_loss(h, jnp.roll(h, 1, axis=0))
        assert float(loss_id) < float(loss_mix)

    def test_inbatch_kernel_matches_jnp(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        hs = jax.random.normal(k1, (64, 32))
        hd = jax.random.normal(k2, (64, 32))
        a = loss_lib.inbatch_softmax_loss(hs, hd, use_kernel=False)
        b = loss_lib.inbatch_softmax_loss(hs, hd, use_kernel=True)
        np.testing.assert_allclose(float(a), float(b), rtol=1e-5)

    def test_inbatch_sigmoid_finite_grad(self):
        hs = jax.random.normal(jax.random.PRNGKey(3), (16, 8))
        g = jax.grad(lambda a: loss_lib.inbatch_sigmoid_loss(a, a))(hs)
        assert np.isfinite(np.asarray(g)).all()
