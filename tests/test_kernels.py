"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True on CPU — the exact program that lowers to TPU Mosaic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape)
    return x.astype(dtype)


TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


@pytest.mark.quick
class TestSegAggr:
    @pytest.mark.parametrize("mode", ["mean", "sum", "max"])
    @pytest.mark.parametrize("shape", [(8, 4, 128), (37, 6, 130), (1, 1, 8), (64, 32, 256)])
    def test_matches_ref(self, mode, shape):
        x = rand(0, shape, jnp.float32)
        mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.6, shape[:2])
        got = ops.seg_aggr(x, mask, mode=mode)
        want = ref.seg_aggr_ref(x, mask, mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = rand(2, (16, 8, 64), dtype)
        mask = jax.random.bernoulli(jax.random.PRNGKey(3), 0.5, (16, 8))
        got = ops.seg_aggr(x, mask, mode="mean")
        want = ref.seg_aggr_ref(x, mask, "mean")
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=TOL[dtype],
        )

    def test_all_invalid_rows_zero(self):
        x = rand(4, (8, 4, 32), jnp.float32)
        mask = jnp.zeros((8, 4), bool)
        for mode in ("mean", "sum", "max"):
            got = ops.seg_aggr(x, mask, mode=mode)
            np.testing.assert_allclose(np.asarray(got), 0.0)


class TestInbatchLoss:
    @pytest.mark.parametrize("P,d", [(16, 8), (100, 48), (128, 64), (257, 32)])
    def test_matches_ref(self, P, d):
        hs = rand(5, (P, d), jnp.float32)
        hd = rand(6, (P, d), jnp.float32)
        got = ops.inbatch_loss(hs, hd, 1.0)
        want = ref.inbatch_loss_ref(hs, hd, 1.0)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    @pytest.mark.parametrize("temp", [0.5, 1.0, 4.0])
    def test_temperature(self, temp):
        hs = rand(7, (64, 16), jnp.float32)
        hd = rand(8, (64, 16), jnp.float32)
        got = ops.inbatch_loss(hs, hd, temp)
        want = ref.inbatch_loss_ref(hs, hd, temp)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_custom_vjp_matches_autodiff_of_ref(self):
        hs = rand(9, (32, 16), jnp.float32)
        hd = rand(10, (32, 16), jnp.float32)
        g_kernel = jax.grad(lambda a, b: ops.inbatch_loss(a, b, 1.0), (0, 1))(hs, hd)
        g_ref = jax.grad(lambda a, b: ref.inbatch_loss_ref(a, b, 1.0), (0, 1))(hs, hd)
        for gk, gr in zip(g_kernel, g_ref):
            np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-6)

    def test_inside_jit_and_grad(self):
        hs = rand(11, (64, 8), jnp.float32)

        @jax.jit
        def step(a, b):
            return jax.value_and_grad(lambda x: ops.inbatch_loss(x, b, 1.0))(a)

        loss, g = step(hs, hs)
        assert np.isfinite(float(loss)) and np.isfinite(np.asarray(g)).all()


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,K,hd", [(256, 4, 2, 64), (128, 8, 8, 32),
                                          (512, 4, 1, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, S, H, K, hd, causal):
        q = rand(1, (2, S, H, hd), jnp.float32)
        k = rand(2, (2, S, K, hd), jnp.float32)
        v = rand(3, (2, S, K, hd), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    @pytest.mark.parametrize("window", [32, 64, 128])
    def test_sliding_window(self, window):
        S = 256
        q = rand(4, (1, S, 4, 64), jnp.float32)
        k = rand(5, (1, S, 2, 64), jnp.float32)
        v = rand(6, (1, S, 2, 64), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, window=window)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_bf16(self):
        q = rand(7, (1, 128, 2, 64), jnp.bfloat16)
        k = rand(8, (1, 128, 2, 64), jnp.bfloat16)
        v = rand(9, (1, 128, 2, 64), jnp.bfloat16)
        got = ops.flash_attention(q, k, v, causal=True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
        )

    def test_chunked_jnp_matches_ref(self):
        """The XLA chunked path (models/layers.py) against the same oracle."""
        from repro.models.layers import chunked_gqa_attention

        q = rand(10, (2, 256, 4, 32), jnp.float32)
        k = rand(11, (2, 256, 2, 32), jnp.float32)
        v = rand(12, (2, 256, 2, 32), jnp.float32)
        for window in (None, 64):
            got = chunked_gqa_attention(q, k, v, True, window, block_q=64)
            want = ref.attention_ref(q, k, v, causal=True, window=window)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
        # unrolled variant (dry-run probes) identical
        got_u = chunked_gqa_attention(q, k, v, True, None, block_q=64, unroll=True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got_u), np.asarray(want), atol=2e-5)


@pytest.mark.quick
class TestTopkOracle:
    """chunked_topk_pallas against its dense pure-jnp oracle (P003 pair)."""

    @pytest.mark.parametrize("Q,I,k", [(16, 100, 10), (130, 300, 25)])
    def test_matches_ref(self, Q, I, k):
        from repro.kernels.topk import chunked_topk_pallas

        q = rand(20, (Q, 32), jnp.float32)
        it = rand(21, (I, 32), jnp.float32)
        ex = jax.random.randint(jax.random.PRNGKey(22), (Q, 5), -1, I)
        s0, i0 = ref.chunked_topk_ref(q, it, k, exclude=ex)
        s1, i1 = chunked_topk_pallas(
            q, it, k, exclude=ex, item_chunk=64, tile_q=32, interpret=True
        )
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))

    def test_no_exclude(self):
        from repro.kernels.topk import chunked_topk_pallas

        q = rand(23, (8, 16), jnp.float32)
        it = rand(24, (50, 16), jnp.float32)
        s0, i0 = ref.chunked_topk_ref(q, it, 7)
        s1, i1 = chunked_topk_pallas(q, it, 7, item_chunk=16, interpret=True)
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


@pytest.mark.quick
class TestIVFListTopkOracle:
    """ivf_list_topk_pallas against its CSR gather-then-score oracle (P003
    pair): random ragged lists, exact-tie flats, and shortlist > candidate
    filler. interpret=True exercises the same DMA/merge program the TPU
    path compiles."""

    def _case(self, seed, Q, P, d, lpad, rows):
        rng = np.random.default_rng(seed)
        codes = rng.integers(-127, 128, size=(rows + lpad, d)).astype(np.int8)
        scales = rng.uniform(0.5, 2.0, size=(rows + lpad, 1)).astype(np.float32)
        q = rng.normal(size=(Q, d)).astype(np.float32)
        starts = rng.integers(0, rows, size=(Q, P)).astype(np.int32)
        lens = rng.integers(0, lpad + 1, size=(Q, P)).astype(np.int32)
        # device arrays: the ref is the jitted production path, not a numpy fn
        return tuple(jax.device_put(a) for a in (q, codes, scales, starts, lens))

    @pytest.mark.parametrize("Q,P,lpad,shortlist", [(7, 3, 24, 16), (16, 5, 40, 64)])
    def test_matches_ref(self, Q, P, lpad, shortlist):
        from repro.kernels.ivf import ivf_list_topk_pallas

        q, codes, scales, starts, lens = self._case(40 + Q, Q, P, 16, lpad, 300)
        s0, r0 = ref.ivf_list_topk_ref(
            q, codes, scales, starts, lens, lpad=lpad, shortlist=shortlist
        )
        s1, r1 = ivf_list_topk_pallas(
            q, codes, scales, starts, lens,
            lpad=lpad, shortlist=shortlist, interpret=True,
        )
        # dots accumulate in different orders (DMA'd block vs gathered
        # rows): ulp-level score drift, identical candidate rows
        np.testing.assert_allclose(
            np.asarray(s0), np.asarray(s1), rtol=2e-5, atol=1e-4
        )
        np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))

    def test_tie_order_matches_flat_probe_order(self):
        # all-equal scores: both paths must keep the flat (probe, within-
        # list) order — the shared contract the exact re-rank builds on
        from repro.kernels.ivf import ivf_list_topk_pallas

        Q, P, d, lpad, rows = 4, 3, 8, 10, 60
        codes = jax.device_put(np.ones((rows + lpad, d), np.int8))
        scales = jax.device_put(np.ones((rows + lpad, 1), np.float32))
        q = jax.device_put(np.ones((Q, d), np.float32))
        rng = np.random.default_rng(9)
        starts = jax.device_put(rng.integers(0, rows, size=(Q, P)).astype(np.int32))
        lens = jax.device_put(rng.integers(1, lpad + 1, size=(Q, P)).astype(np.int32))
        s0, r0 = ref.ivf_list_topk_ref(
            q, codes, scales, starts, lens, lpad=lpad, shortlist=12
        )
        s1, r1 = ivf_list_topk_pallas(
            q, codes, scales, starts, lens,
            lpad=lpad, shortlist=12, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))

    def test_filler_when_shortlist_exceeds_candidates(self):
        from repro.kernels.ivf import ivf_list_topk_pallas

        q, codes, scales, starts, _ = self._case(77, 3, 2, 8, 6, 50)
        # 4 candidates < shortlist 10
        lens = jax.device_put(np.full((3, 2), 2, np.int32))
        s0, r0 = ref.ivf_list_topk_ref(
            q, codes, scales, starts, lens, lpad=6, shortlist=10
        )
        s1, r1 = ivf_list_topk_pallas(
            q, codes, scales, starts, lens,
            lpad=6, shortlist=10, interpret=True,
        )
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
        assert np.isneginf(np.asarray(s1)[:, 4:]).all()
        assert (np.asarray(r1)[:, 4:] == -1).all()


@pytest.mark.quick
class TestRowAdagradOracle:
    """row_adagrad_scatter_pallas against its oracle (P003 pair): distinct
    real ids, PADs first, untouched rows pass through."""

    def test_matches_ref(self):
        from repro.kernels.row_adagrad import row_adagrad_scatter_pallas

        N, D, bucket = 64, 16, 12
        table = rand(30, (N, D), jnp.float32)
        accum = jnp.full((N, 1), 0.1, jnp.float32)
        g = rand(31, (bucket, D), jnp.float32)
        real = np.array([3, 9, 17, 40, 63], np.int32)
        ids = jnp.asarray(
            np.concatenate([np.full(bucket - len(real), -1, np.int32), real])
        )
        t0, a0 = ref.row_adagrad_scatter_ref(table, accum, ids, g, lr=0.2)
        t1, a1 = row_adagrad_scatter_pallas(
            table, accum, ids, g, lr=0.2, interpret=True
        )
        np.testing.assert_allclose(np.asarray(t0), np.asarray(t1), atol=1e-6)
        np.testing.assert_allclose(np.asarray(a0), np.asarray(a1), atol=1e-6)
        # rows not named in ids are bitwise untouched
        untouched = np.setdiff1d(np.arange(N), real)
        np.testing.assert_array_equal(
            np.asarray(t1)[untouched], np.asarray(table)[untouched]
        )
