"""Fused-vs-host sampling conformance suite (ISSUE-5 acceptance).

The contract under test: the fused on-device pipeline (sampling/fused.py —
walk, window pairs, ego gathers as one jitted program) produces the SAME
pair and ego distributions as the host ``MetapathWalker`` +
``SamplePipeline`` path. Where shapes allow the comparison is exact (support
set equality, PAD propagation, slot tables bitwise); elsewhere it is
distributional — a two-sample chi-square bound over large fixed-seed draws —
across homogeneous and multi-metapath configs, PAD/degree-0 nodes, and both
'values'/'bag' slot modes. The trainer-facing surface is covered too:
batch structure identical to ``device_batch``, end-to-end training with
``sampling_backend="fused"`` statistically matching the host loss
trajectory, and the memory-eligibility fallback.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import Graph4RecConfig
from repro.core import model as model_lib
from repro.embedding import EmbeddingConfig, SlotSpec
from repro.graph import DistributedGraphEngine
from repro.graph.hetero_graph import HeteroGraph
from repro.sampling import (
    EgoConfig, PairConfig, PipelineConfig, SamplePipeline, sample_ego_batch,
    window_positions,
)
from repro.sampling.fused import (
    FusedConfig, FusedSampler, fused_device_bytes, fused_eligibility,
)
from repro.train import Graph4RecTrainer, TrainerConfig
from repro.walk import WalkConfig

from conftest import RELS

PAD = -1

# chi-square homogeneity bound: stat <= dof + SLACK * sqrt(2 * dof) under
# H0 (mean dof, variance 2*dof); 6 sigma keeps fixed-seed runs deterministic
# while still catching any real distribution shift.
CHI2_SLACK = 6.0


def chi2_two_sample(counts_a, counts_b) -> bool:
    """Two-sample chi-square homogeneity test on aligned count vectors."""
    a = np.asarray(counts_a, dtype=np.float64)
    b = np.asarray(counts_b, dtype=np.float64)
    keep = (a + b) > 0
    a, b = a[keep], b[keep]
    na, nb = a.sum(), b.sum()
    stat = np.sum((np.sqrt(nb / na) * a - np.sqrt(na / nb) * b) ** 2 / (a + b))
    dof = max(len(a) - 1, 1)
    return stat <= dof + CHI2_SLACK * np.sqrt(2.0 * dof)


def dense_bipartite(n_u=6, n_i=5, drop=()):
    """Small dense u<->i graph; ``drop`` lists user ids left edge-less."""
    src, dst = [], []
    for u in range(n_u):
        if u in drop:
            continue
        for i in range(n_i):
            src.append(u)
            dst.append(i)
    return HeteroGraph.from_edges(
        {"u": n_u, "i": n_i},
        {"u2click2i": (np.array(src), np.array(dst))},
        symmetry=True,
    )


def pipe_cfg(metapaths=("u2click2i - i2click2u",), walk_len=5, win=2,
             ego=None, batch_pairs=64, neg_mode="inbatch"):
    return PipelineConfig(
        walk=WalkConfig(metapaths=list(metapaths), walk_len=walk_len),
        pair=PairConfig(win_size=win, neg_mode=neg_mode, num_negatives=3),
        ego=ego, batch_pairs=batch_pairs, walks_per_round=32,
    )


def host_pair_counts(graph, pc, num_batches, seed, num_nodes):
    eng = DistributedGraphEngine(graph, num_partitions=2)
    pipe = SamplePipeline(eng, pc, seed=seed)
    counts = np.zeros(num_nodes * num_nodes, np.int64)
    for b in pipe.batches(num_batches):
        np.add.at(counts, b.src_ids * num_nodes + b.dst_ids, 1)
    return counts


def fused_pair_counts(fs, pc, num_batches, seed, num_nodes):
    sample = jax.jit(fs.sample)
    keys = jax.random.split(jax.random.PRNGKey(seed), num_batches)
    counts = np.zeros(num_nodes * num_nodes, np.int64)
    for i in range(num_batches):
        batch = sample(keys[i])
        if "shared" in batch:  # shared-tower layout: gather level-0 centers
            centers = batch["shared"][0][0][:, 0]
            src = centers[batch["src_sel"]]
            dst = centers[batch["dst_sel"]]
        else:
            src, dst = batch["src"][0], batch["dst"][0]
            if fs.ego is not None:  # GNN layout: level 0 carries the centers
                src, dst = src[0][:, 0], dst[0][:, 0]
        src, dst = np.asarray(src), np.asarray(dst)
        ok = src >= 0
        np.add.at(counts, src[ok] * num_nodes + dst[ok], 1)
    return counts


# ---------------------------------------------------------------- pairs
@pytest.mark.quick
class TestPairConformance:
    def test_support_set_equality(self):
        """Exact contract: on a dense tiny graph both backends emit exactly
        the same SET of (src, dst) pairs once sampling saturates."""
        g = dense_bipartite()
        pc = pipe_cfg(batch_pairs=64)
        host = host_pair_counts(g, pc, 40, seed=0, num_nodes=g.num_nodes)
        fs = FusedSampler(g, pc)
        fused = fused_pair_counts(fs, pc, 40, seed=0, num_nodes=g.num_nodes)
        assert set(np.flatnonzero(host)) == set(np.flatnonzero(fused))

    def test_pair_distribution_matches(self):
        g = dense_bipartite()
        pc = pipe_cfg(batch_pairs=64)
        host = host_pair_counts(g, pc, 120, seed=1, num_nodes=g.num_nodes)
        fs = FusedSampler(g, pc)
        fused = fused_pair_counts(fs, pc, 120, seed=2, num_nodes=g.num_nodes)
        assert chi2_two_sample(host, fused)

    def test_pair_distribution_multi_metapath(self):
        """Two metapaths with different start types: the mixture must match
        (host splits walks round-robin, fused draws per walk)."""
        g = dense_bipartite()
        pc = pipe_cfg(
            metapaths=("u2click2i - i2click2u", "i2click2u - u2click2i"),
            batch_pairs=64,
        )
        host = host_pair_counts(g, pc, 120, seed=3, num_nodes=g.num_nodes)
        fs = FusedSampler(g, pc)
        fused = fused_pair_counts(fs, pc, 120, seed=4, num_nodes=g.num_nodes)
        assert chi2_two_sample(host, fused)

    def test_pair_distribution_with_dead_ends(self):
        """PAD handling: users without edges never appear, and the walk's
        PAD suffix does not skew the surviving pair distribution."""
        g = dense_bipartite(n_u=7, drop=(2, 5))
        pc = pipe_cfg(batch_pairs=64)
        host = host_pair_counts(g, pc, 120, seed=5, num_nodes=g.num_nodes)
        fs = FusedSampler(g, pc)
        fused = fused_pair_counts(fs, pc, 120, seed=6, num_nodes=g.num_nodes)
        for dead in (2, 5):
            assert fused.reshape(g.num_nodes, -1)[dead].sum() == 0
            assert fused.reshape(g.num_nodes, -1)[:, dead].sum() == 0
        assert chi2_two_sample(host, fused)

    def test_window_positions_match_host_pairs(self):
        """The fused static position table enumerates exactly the host
        window: every host (src_col, dst_col) pair and no more."""
        pos = {tuple(p) for p in window_positions(6, 2)}
        from repro.sampling import window_pairs

        paths = np.arange(6)[None, :]  # all-valid path
        host = {(int(r[1]), int(r[2])) for r in window_pairs(paths, 2)}
        assert pos == host


# ------------------------------------------------------------------ ego
@pytest.mark.quick
class TestEgoConformance:
    def _counts(self, children, vocab):
        c = np.zeros(vocab + 1, np.int64)  # last slot counts PAD
        ch = np.asarray(children).reshape(-1)
        np.add.at(c, np.where(ch >= 0, ch, vocab), 1)
        return c

    @pytest.mark.parametrize("order", ["walk_ego_pair", "walk_pair_ego"])
    def test_child_distribution_per_center(self, order):
        g = dense_bipartite()
        ego = EgoConfig(relations=list(RELS), fanouts=[3, 2])
        pc = dataclasses.replace(pipe_cfg(ego=ego), order=order)
        fs = FusedSampler(g, pc)
        centers = np.arange(g.num_nodes, dtype=np.int64)
        rng = np.random.default_rng(0)
        reps = 60
        host_children = [
            sample_ego_batch(rng, g, centers, ego).levels[1] for _ in range(reps)
        ]
        ego_fn = jax.jit(fs._ego_levels)
        keys = jax.random.split(jax.random.PRNGKey(1), reps)
        fused_children = [
            np.asarray(ego_fn(keys[i], jax.numpy.asarray(centers))[1])
            for i in range(reps)
        ]
        R, F = len(RELS), 3
        hc = np.stack(host_children).reshape(reps, len(centers), R, F)
        fc = np.stack(fused_children).reshape(reps, len(centers), R, F)
        for v in centers:
            for ri in range(R):
                assert chi2_two_sample(
                    self._counts(hc[:, v, ri], g.num_nodes),
                    self._counts(fc[:, v, ri], g.num_nodes),
                ), (v, ri)

    @pytest.mark.parametrize("order", ["walk_ego_pair", "walk_pair_ego"])
    def test_all_dead_round_emits_pad_pairs(self, order):
        """A round where no walk can take a single step (every start has
        degree 0) must emit all-PAD pairs in BOTH ego orders — never a
        real-node center paired against a PAD side."""
        g = dense_bipartite(n_u=4, n_i=3, drop=(0, 1, 2, 3))  # edgeless
        ego = EgoConfig(relations=list(RELS), fanouts=[2])
        pc = dataclasses.replace(pipe_cfg(ego=ego, batch_pairs=16), order=order)
        fs = FusedSampler(g, pc)
        batch = jax.jit(fs.sample)(jax.random.PRNGKey(0))
        if "shared" in batch:  # walk_ego_pair: towers themselves are PAD
            levels, _ = batch["shared"]
            for l in levels:
                assert (np.asarray(l) == PAD).all(), order
        else:
            for part in ("src", "dst"):
                levels, _ = batch[part]
                for l in levels:
                    assert (np.asarray(l) == PAD).all(), (order, part)

    def test_degree0_and_pad_centers_propagate_pad(self):
        g = dense_bipartite(n_u=6, drop=(3,))
        ego = EgoConfig(relations=["u2click2i"], fanouts=[2, 2])
        pc = pipe_cfg(ego=ego)
        fs = FusedSampler(g, pc)
        centers = jax.numpy.asarray(np.array([3, PAD, 6], np.int64))  # dead u, PAD, item
        levels = jax.jit(fs._ego_levels)(jax.random.PRNGKey(0), centers)
        # u=3 has no edges, PAD is PAD, items have no u2click2i out-edges
        assert (np.asarray(levels[1]) == PAD).all()
        assert (np.asarray(levels[2]) == PAD).all()
        # identical to the host sampler's handling
        host = sample_ego_batch(
            np.random.default_rng(0), g, np.array([3, 6]), ego
        )
        assert (host.levels[1] == PAD).all() and (host.levels[2] == PAD).all()

    def test_level_widths_match_host(self, toy_ds):
        g = toy_ds.graph
        ego = EgoConfig(relations=list(RELS), fanouts=[4, 3])
        fs = FusedSampler(g, pipe_cfg(ego=ego, walk_len=6))
        centers = jax.numpy.arange(7)
        levels = jax.jit(fs._ego_levels)(jax.random.PRNGKey(0), centers)
        host = sample_ego_batch(
            np.random.default_rng(0), g, np.arange(7), ego
        )
        assert [tuple(np.asarray(l).shape) for l in levels] == [
            tuple(l.shape) for l in host.levels
        ]


# ------------------------------------------------------------ slot modes
@pytest.mark.quick
class TestSlotConformance:
    def _graph_cfgs(self, toy_ds, slot_mode):
        g = toy_ds.graph
        slots = (SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 3))
        mc = Graph4RecConfig(
            embedding=EmbeddingConfig(num_nodes=g.num_nodes, dim=16, slots=slots),
            gnn=None, relations=RELS, use_side_info=True, slot_mode=slot_mode,
        )
        return g, mc

    def test_values_mode_slot_tables_bitwise(self, toy_ds):
        g, mc = self._graph_cfgs(toy_ds, "values")
        vspecs = model_lib.value_slot_specs(mc)
        fs = FusedSampler(g, pipe_cfg(), value_slots=vspecs)
        ids = np.array([0, 5, PAD, g.num_nodes - 1, 17], np.int64)
        got = fs._slot_values(jax.numpy.asarray(ids))
        want = model_lib._slots_for_ids(g, ids, vspecs)
        for name in want:
            np.testing.assert_array_equal(np.asarray(got[name]), want[name])

    def test_bag_mode_count_matrices_bitwise(self, toy_ds):
        g, mc = self._graph_cfgs(toy_ds, "bag")
        bspecs = model_lib.bag_slot_specs(mc)
        fs = FusedSampler(g, pipe_cfg(), bag_slots=bspecs)
        want = model_lib.slot_count_arrays(g, mc)
        assert set(fs._bag_counts) == set(want)
        for name in want:
            np.testing.assert_array_equal(
                np.asarray(fs._bag_counts[name]), np.asarray(want[name])
            )

    def _gnn_cfgs(self, toy_ds, slot_mode):
        g = toy_ds.graph
        slots = (SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 3))
        mc = Graph4RecConfig(
            embedding=EmbeddingConfig(num_nodes=g.num_nodes, dim=16, slots=slots),
            gnn=model_lib.HeteroGNNConfig(
                gnn_type="lightgcn", num_relations=2, num_layers=2, dim=16
            ),
            fanouts=(3, 2), relations=RELS,
            use_side_info=True, slot_mode=slot_mode,
        )
        return g, mc

    @pytest.mark.parametrize("slot_mode", ["values", "bag"])
    def test_batch_structure_matches_device_batch(self, toy_ds, slot_mode):
        """The fused batch is pytree-compatible with ``device_batch`` (same
        keys, same part layouts, same shapes) so loss_fn runs unchanged.
        walk_ego_pair uses the shared-tower layout instead, covered by
        ``test_shared_tower_layout_and_loss_equivalence``."""
        g, mc = self._gnn_cfgs(toy_ds, slot_mode)
        ego = EgoConfig(relations=list(RELS), fanouts=[3, 2])
        pc = dataclasses.replace(
            pipe_cfg(ego=ego, batch_pairs=32), order="walk_pair_ego"
        )
        bspecs = model_lib.bag_slot_specs(mc)
        vspecs = model_lib.value_slot_specs(mc)
        fs = FusedSampler(g, pc, value_slots=vspecs, bag_slots=bspecs)
        fused = jax.jit(fs.sample)(jax.random.PRNGKey(0))

        eng = DistributedGraphEngine(g, num_partitions=2)
        host_batch = next(iter(SamplePipeline(eng, pc, seed=0).batches(1)))
        host = model_lib.device_batch(g, host_batch, mc)
        assert set(fused) == set(host)
        f_struct = jax.tree_util.tree_structure(fused)
        h_struct = jax.tree_util.tree_structure(host)
        assert f_struct == h_struct
        for fl, hl in zip(jax.tree_util.tree_leaves(fused),
                          jax.tree_util.tree_leaves(host)):
            assert fl.shape == hl.shape, (fl.shape, hl.shape)
        # and the model consumes it
        params = model_lib.init_model_params(jax.random.PRNGKey(1), mc)
        assert np.isfinite(float(model_lib.loss_fn(params, mc, fused)))

    @pytest.mark.parametrize("slot_mode", ["values", "bag"])
    def test_shared_tower_layout_and_loss_equivalence(self, toy_ds, slot_mode):
        """walk_ego_pair emits ONE ego tower per (walk, position) plus pair
        index vectors; the loss over the shared layout is bitwise identical
        to the loss over the equivalent gathered-tower batch (per-tower
        encoder compute is row-independent)."""
        g, mc = self._gnn_cfgs(toy_ds, slot_mode)
        ego = EgoConfig(relations=list(RELS), fanouts=[3, 2])
        pc = pipe_cfg(ego=ego, batch_pairs=32)  # default order=walk_ego_pair
        bspecs = model_lib.bag_slot_specs(mc)
        vspecs = model_lib.value_slot_specs(mc)
        fs = FusedSampler(g, pc, value_slots=vspecs, bag_slots=bspecs)
        fused = jax.jit(fs.sample)(jax.random.PRNGKey(0))
        assert {"shared", "src_sel", "dst_sel"} <= set(fused)
        W, L = fs.num_walks, pc.walk.walk_len
        levels, slots = fused["shared"]
        assert levels[0].shape[0] == W * L
        for sel in (fused["src_sel"], fused["dst_sel"]):
            arr = np.asarray(sel)
            assert arr.shape == (32,)
            assert ((arr >= 0) & (arr < W * L)).all()

        # gathered-tower equivalent batch (the pre-optimization layout)
        gathered = {k: v for k, v in fused.items()
                    if k not in ("shared", "src_sel", "dst_sel")}
        for name in ("src", "dst"):
            sel = fused[f"{name}_sel"]
            glv = [l[sel] for l in levels]
            gsl = ([{k: v[sel] for k, v in s.items()} for s in slots]
                   if slots is not None else None)
            gathered[name] = (glv, gsl)
        params = model_lib.init_model_params(jax.random.PRNGKey(1), mc)
        got = model_lib.loss_fn(params, mc, fused)
        want = model_lib.loss_fn(params, mc, gathered)
        assert np.isfinite(float(got))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------- end to end
class TestFusedTraining:
    def _trainer(self, toy_ds, backend, steps=60, **cfg_kw):
        g = toy_ds.graph
        mc = Graph4RecConfig(
            embedding=EmbeddingConfig(num_nodes=g.num_nodes, dim=16),
            gnn=model_lib.HeteroGNNConfig(
                gnn_type="lightgcn", num_relations=2, num_layers=2, dim=16
            ),
            fanouts=(4, 3), relations=RELS,
        )
        pc = pipe_cfg(
            ego=EgoConfig(relations=list(RELS), fanouts=[4, 3]),
            walk_len=6, batch_pairs=128,
        )
        eng = DistributedGraphEngine(g, num_partitions=2)
        return Graph4RecTrainer(
            toy_ds, eng, mc, pc,
            TrainerConfig(num_steps=steps, log_every=0, eval_at_end=False,
                          sparse_lr=1.0, seed=0, sampling_backend=backend,
                          **cfg_kw),
        )

    def test_loss_trajectory_statistically_matches_host(self, toy_ds):
        """Acceptance: fused end-to-end training tracks the host pipeline.
        Same model/seed, independent sampling streams — the tail-window
        mean losses must agree within the run-to-run noise scale."""
        tails = {}
        for backend in ("host", "fused"):
            res = self._trainer(toy_ds, backend, steps=80).train()
            assert len(res.losses) == 80
            assert np.isfinite(res.losses).all()
            tails[backend] = np.asarray(res.losses[-20:])
        scale = max(tails["host"].std(), tails["fused"].std(), 1e-3)
        assert abs(tails["host"].mean() - tails["fused"].mean()) < 6 * scale

    @pytest.mark.quick
    def test_fused_deterministic_per_seed(self, toy_ds):
        r1 = self._trainer(toy_ds, "fused", steps=8).train()
        r2 = self._trainer(toy_ds, "fused", steps=8).train()
        assert r1.losses == r2.losses
        assert r1.pairs_seen == 8 * 128

    @pytest.mark.quick
    def test_over_budget_falls_back_to_host(self, toy_ds, caplog):
        tr = self._trainer(toy_ds, "fused", steps=3, fused_budget_mb=0.0001)
        assert tr._fused_sampler is None  # fell back
        res = tr.train()
        assert len(res.losses) == 3
        ok, why = fused_eligibility(
            toy_ds.graph, tr.pipe_cfg,
            fused=FusedConfig(budget_mb=0.0001),
        )
        assert not ok and "budget" in why

    @pytest.mark.quick
    def test_eligibility_accounts_tables(self, toy_ds):
        pc = pipe_cfg(ego=EgoConfig(relations=list(RELS), fanouts=[2]))
        n = fused_device_bytes(toy_ds.graph, pc, max_degree=8)
        # 2 relations x (8+1) int32 per node
        assert n == 2 * toy_ds.graph.num_nodes * 9 * 4
        ok, _ = fused_eligibility(toy_ds.graph, pc)
        assert ok

    @pytest.mark.quick
    def test_unknown_backend_raises(self, toy_ds):
        with pytest.raises(ValueError, match="sampling_backend"):
            self._trainer(toy_ds, "device")

    @pytest.mark.quick
    def test_random_negative_mode(self, toy_ds):
        g = toy_ds.graph
        pc = pipe_cfg(neg_mode="random", batch_pairs=32)
        fs = FusedSampler(g, pc)
        batch = jax.jit(fs.sample)(jax.random.PRNGKey(0))
        neg_ids = np.asarray(batch["neg"][0])
        assert neg_ids.shape == (32 * 3,)
        assert ((neg_ids >= 0) & (neg_ids < g.num_nodes)).all()


# ------------------------------------------------------------- kernel
@pytest.mark.quick
class TestWindowPairKernel:
    @pytest.mark.parametrize("B,L,win", [(1, 4, 2), (7, 6, 2), (33, 5, 4)])
    def test_kernel_matches_ref(self, B, L, win):
        from repro.kernels import ops, ref

        rng = np.random.default_rng(B * L + win)
        paths = rng.integers(0, 50, size=(B, L))
        for b in range(B):  # random PAD suffixes, incl. all-PAD rows
            cut = rng.integers(0, L + 1)
            paths[b, cut:] = PAD
        pos = window_positions(L, win)
        s_k, d_k = ops.window_pair_ids(jax.numpy.asarray(paths), pos)
        s_r, d_r = ref.window_pair_ids_ref(jax.numpy.asarray(paths), pos)
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))

    def test_kernel_vs_host_window_pairs(self):
        from repro.kernels import ops
        from repro.sampling import window_pairs

        rng = np.random.default_rng(0)
        paths = rng.integers(0, 9, size=(12, 6))
        paths[paths % 4 == 0] = PAD  # interior PADs too (adversarial)
        pos = window_positions(6, 2)
        s, d = ops.window_pair_ids(jax.numpy.asarray(paths), pos)
        s, d = np.asarray(s), np.asarray(d)
        got = {
            (r, int(pos[p, 0]), int(pos[p, 1]))
            for r in range(12) for p in range(len(pos)) if s[r, p] != PAD
        }
        want = {tuple(map(int, row)) for row in window_pairs(paths, 2)}
        assert got == want


# --------------------------------------------------- adjacency determinism
@pytest.mark.quick
class TestAdjacencySeedStability:
    """padded_adjacency's hub-row subsample is keyed by [seed, node id]
    (the partition_rng spawn-key idiom), never the node id alone: same-seed
    builds are bitwise identical AND the caller's seed reaches every draw."""

    def _hub_graph(self):
        return dense_bipartite(n_u=8, n_i=6)

    def test_same_seed_bitwise_identical(self):
        g = self._hub_graph()
        a1, d1 = g.padded_adjacency("u2click2i", 3, seed=7)
        a2, d2 = g.padded_adjacency("u2click2i", 3, seed=7)
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(d1, d2)

    def test_seed_reaches_the_subsample(self):
        g = self._hub_graph()
        hubs = np.flatnonzero(np.asarray(g.degrees("u2click2i")) > 3)
        assert hubs.size, "fixture must exercise hub-row truncation"
        a1, _ = g.padded_adjacency("u2click2i", 3, seed=0)
        a2, _ = g.padded_adjacency("u2click2i", 3, seed=1)
        assert not np.array_equal(a1[hubs], a2[hubs])

    def test_same_seed_fused_builds_share_tables(self):
        """Two FusedSampler builds with the same seed hold identical device
        adjacency — the regression that id-keyed default_rng(v) used to mask
        (stable per-build but unreachable from TrainerConfig.seed)."""
        g = self._hub_graph()
        pc = pipe_cfg()
        fused = FusedConfig(max_degree=3)
        f1 = FusedSampler(g, pc, fused=fused, seed=3)
        f2 = FusedSampler(g, pc, fused=fused, seed=3)
        np.testing.assert_array_equal(np.asarray(f1._adj), np.asarray(f2._adj))
        np.testing.assert_array_equal(np.asarray(f1._deg), np.asarray(f2._deg))
        f3 = FusedSampler(g, pc, fused=fused, seed=4)
        assert not np.array_equal(np.asarray(f1._adj), np.asarray(f3._adj))
