"""repro.lint suite: golden findings per rule family on fixture snippets,
suppression and baseline mechanics, cleanliness of the real repo, and the
runtime transfer-guard sanitizer the static pass is paired with.

Fixture files are written under tmp_path at their *repo-relative* paths
(e.g. ``src/repro/train/trainer.py``) so the hot-path / kernel / test glob
classifiers fire exactly as they do on the real tree.
"""
import pathlib
import textwrap

import numpy as np
import pytest

from repro.lint import core
from repro.lint.core import load_baseline, new_findings, run_lint, write_baseline

pytestmark = pytest.mark.quick

REPO = pathlib.Path(__file__).resolve().parents[1]


def lint_snippet(tmp_path, rel, source):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return run_lint(tmp_path, paths=(rel,))


def rule_ids(findings):
    return sorted(f.rule for f in findings)


# ------------------------------------------------------------- determinism
class TestDeterminismRules:
    def test_d001_entropy_seed(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np
            rng = np.random.default_rng()
            """)
        assert rule_ids(got) == ["D001"]

    def test_d002_id_only_seed(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np

            def subsample(v):
                return np.random.default_rng(v).integers(0, 10)
            """)
        assert rule_ids(got) == ["D002"]
        assert "partition_rng" in got[0].hint

    def test_d002_spawn_key_idiom_clean(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np

            def subsample(seed, v):
                return np.random.default_rng([seed, int(v)]).integers(0, 10)
            """)
        assert got == []

    def test_d003_global_state(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np

            def f(x):
                np.random.shuffle(x)
            """)
        assert rule_ids(got) == ["D003"]

    def test_d004_constant_prngkey_library_only(self, tmp_path):
        src = """\
            import jax

            def init():
                return jax.random.PRNGKey(0)
            """
        assert rule_ids(lint_snippet(tmp_path, "src/repro/foo.py", src)) == ["D004"]
        # constant keys are the norm in tests
        assert lint_snippet(tmp_path, "tests/test_foo.py", src) == []

    def test_d005_key_reuse(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import jax

            def f(key):
                a = jax.random.normal(key, (2,))
                b = jax.random.uniform(key, (2,))
                return a + b
            """)
        assert rule_ids(got) == ["D005"]
        assert got[0].line == 5  # the second consumer is the violation

    def test_d005_split_and_fold_in_clean(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import jax

            def g(key):
                k1, k2 = jax.random.split(key)
                return jax.random.normal(k1, (2,)) + jax.random.uniform(k2, (2,))

            def h(key, n):
                outs = []
                for i in range(n):
                    k = jax.random.fold_in(key, i)
                    outs.append(jax.random.normal(k, (2,)))
                return outs
            """)
        assert got == []

    def test_d005_loop_carried_reuse_caught(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import jax

            def f(key, n):
                outs = []
                for i in range(n):
                    outs.append(jax.random.normal(key, (2,)))
                return outs
            """)
        assert rule_ids(got) == ["D005"]


# ---------------------------------------------------------------- host sync
_HOT_SNIPPET = """\
    import jax
    import jax.numpy as jnp
    import numpy as np

    def step(loss, xs):
        a = float(loss)
        b = loss.item()
        c = np.asarray(loss)
        jax.block_until_ready(loss)
        d = jnp.asarray(xs)
        return a, b, c, d
    """


class TestHostSyncRules:
    def test_hot_path_module_flagged(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/train/trainer.py", _HOT_SNIPPET)
        assert rule_ids(got) == ["H001", "H001", "H001", "H001", "H002"]

    def test_service_glob_is_hot(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/graph/service/worker.py", """\
            import jax

            def f(x):
                return float(x)
            """)
        assert rule_ids(got) == ["H001"]

    def test_non_hot_module_clean(self, tmp_path):
        assert lint_snippet(tmp_path, "src/repro/models/foo.py", _HOT_SNIPPET) == []

    def test_retrieval_and_infer_globs_are_hot(self, tmp_path):
        # the serving path joined HOT_PATH_GLOBS with the ANN rebuild: a
        # per-call host sync or re-upload there is the "IVF loses to brute
        # force" class of bug, so the same rules fire
        for rel in ("src/repro/retrieval/myindex.py", "src/repro/infer/myserve.py"):
            got = lint_snippet(tmp_path, rel, _HOT_SNIPPET)
            assert rule_ids(got) == ["H001", "H001", "H001", "H001", "H002"], rel

    def test_h002_hint_names_device_put(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/sampling/fused.py", """\
            import jax
            import jax.numpy as jnp

            def build(x):
                return jnp.asarray(x)
            """)
        assert rule_ids(got) == ["H002"]
        assert "device_put" in got[0].hint


# ------------------------------------------------------------------- pallas
class TestPallasRules:
    def test_p001_underived_grid(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/kernels/mykern.py", """\
            from jax.experimental import pallas as pl

            def launch(kern, x):
                B = x.shape[0]
                return pl.pallas_call(kern, grid=(B // 8,))(x)
            """)
        assert rule_ids(got) == ["P001"]

    def test_p001_divisibility_assert_clean(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/kernels/mykern.py", """\
            from jax.experimental import pallas as pl

            def launch(kern, x):
                B = x.shape[0]
                assert B % 8 == 0
                return pl.pallas_call(kern, grid=(B // 8,))(x)
            """)
        assert got == []

    def test_p001_ceil_pad_idiom_clean(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/kernels/mykern.py", """\
            from jax.experimental import pallas as pl

            def launch(kern, x):
                B = x.shape[0]
                Bp = -(-B // 8) * 8
                return pl.pallas_call(kern, grid=(Bp // 8,))(x)
            """)
        assert got == []

    def test_p002_alias_index_out_of_range(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/kernels/mykern.py", """\
            import jax
            from jax.experimental import pallas as pl

            def launch(kern, x, y):
                return pl.pallas_call(
                    kern,
                    grid=(4,),
                    out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype)],
                    input_output_aliases={5: 0},
                )(x, y)
            """)
        assert "P002" in rule_ids(got)

    def test_p003_kernel_without_ref_oracle(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/kernels/mykern.py", """\
            def foo_pallas(x):
                return x
            """)
        assert rule_ids(got) == ["P003"]

    def test_p003_ref_oracle_satisfies(self, tmp_path):
        ref = tmp_path / "src/repro/kernels/ref.py"
        ref.parent.mkdir(parents=True, exist_ok=True)
        ref.write_text("def foo_ref(x):\n    return x\n")
        got = lint_snippet(tmp_path, "src/repro/kernels/mykern.py", """\
            def foo_pallas(x):
                return x
            """)
        assert got == []

    def test_p004_pallas_call_outside_kernels(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/models/bar.py", """\
            from jax.experimental import pallas as pl

            def f(kern, x):
                return pl.pallas_call(kern, grid=(1,))(x)
            """)
        assert rule_ids(got) == ["P004"]


# ---------------------------------------------------------------- lifecycle
class TestLifecycleRules:
    def test_l001_unnamed_spawn(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import threading

            def start(fn):
                t = threading.Thread(target=fn, daemon=True)
                t.start()
                return t
            """)
        assert rule_ids(got) == ["L001"]

    def test_l002_join_timeout_without_aliveness(self, tmp_path):
        # exactly the silent-shutdown shape fixed in train/trainer.py
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            class Prefetcher:
                def close(self):
                    self._thread.join(timeout=5.0)
            """)
        assert rule_ids(got) == ["L002"]

    def test_l002_aliveness_check_clean(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            class Prefetcher:
                def close(self):
                    self._thread.join(timeout=5.0)
                    if self._thread.is_alive():
                        print("producer still running")
            """)
        assert got == []

    def test_l003_bare_acquire_release(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import threading

            _lock = threading.Lock()

            def f():
                _lock.acquire()
                try:
                    pass
                finally:
                    _lock.release()
            """)
        assert rule_ids(got) == ["L003", "L003"]

    def test_l003_with_statement_clean(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import threading

            _lock = threading.Lock()

            def f():
                with _lock:
                    pass
            """)
        assert got == []

    def test_l004_shm_create_without_finalizer(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            from multiprocessing import shared_memory

            def build():
                return shared_memory.SharedMemory(create=True, size=64)
            """)
        assert rule_ids(got) == ["L004"]

    def test_l004_finalizer_satisfies(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import weakref
            from multiprocessing import shared_memory

            def _unlink(name):
                pass

            def build():
                seg = shared_memory.SharedMemory(create=True, size=64)
                weakref.finalize(seg, _unlink, seg.name)
                return seg
            """)
        assert got == []


# ------------------------------------------------------------ observability
class TestObservabilityRules:
    WALLCLOCK = """\
        import time

        def measure():
            t0 = time.time()
            return time.time() - t0
        """

    def test_o001_wall_clock_in_hot_path(self, tmp_path):
        got = lint_snippet(
            tmp_path, "src/repro/train/trainer.py", self.WALLCLOCK
        )
        assert rule_ids(got) == ["O001", "O001"]

    def test_o001_fires_across_instrumented_modules(self, tmp_path):
        # the telemetry layer itself and everything it instruments
        for rel in ("src/repro/obs/trace.py",
                    "src/repro/graph/service/worker.py",
                    "src/repro/core/recall.py"):
            got = lint_snippet(tmp_path, rel, "import time\nt = time.time()\n")
            assert rule_ids(got) == ["O001"], rel

    def test_o001_silent_outside_instrumented_modules(self, tmp_path):
        got = lint_snippet(
            tmp_path, "src/repro/launch/report.py", self.WALLCLOCK
        )
        assert got == []

    def test_o001_monotonic_clocks_clean(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/train/trainer.py", """\
            import time

            def measure():
                t0 = time.perf_counter_ns()
                deadline = time.monotonic() + 5.0
                return time.perf_counter_ns() - t0, deadline
            """)
        assert got == []

    def test_o001_suppressible(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/train/trainer.py", """\
            import time
            stamp = time.time()  # repro: lint-ignore[O001]
            """)
        assert got == []


# ------------------------------------------------- suppression and baseline
class TestSuppressionAndBaseline:
    def test_inline_suppression(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np
            rng = np.random.default_rng()  # repro: lint-ignore[D001]
            """)
        assert got == []

    def test_comment_line_suppresses_next_line(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np
            # repro: lint-ignore[D001]
            rng = np.random.default_rng()
            """)
        assert got == []

    def test_suppression_is_rule_scoped(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np
            rng = np.random.default_rng()  # repro: lint-ignore[D003]
            """)
        assert rule_ids(got) == ["D001"]

    def test_wildcard_suppression(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/foo.py", """\
            import numpy as np
            rng = np.random.default_rng()  # repro: lint-ignore[*]
            """)
        assert got == []

    def test_clean_file_zero_findings(self, tmp_path):
        got = lint_snippet(tmp_path, "src/repro/train/trainer.py", """\
            import jax
            import numpy as np

            def step(fn, params, batch):
                dev = jax.device_put(batch)
                return fn(params, dev)

            def make_rng(seed, part):
                return np.random.default_rng([seed, part])
            """)
        assert got == []

    def test_baseline_masks_only_recorded_findings(self, tmp_path):
        rel = "src/repro/foo.py"
        findings = lint_snippet(tmp_path, rel, """\
            import numpy as np
            rng = np.random.default_rng()
            """)
        bl_path = tmp_path / core.BASELINE_FILE
        write_baseline(bl_path, findings)
        # the recorded finding no longer counts as new...
        assert new_findings(findings, load_baseline(bl_path)) == []
        # ...surviving line drift (fingerprints are line-number free)...
        findings2 = lint_snippet(tmp_path, rel, """\
            import numpy as np

            # an unrelated edit above the finding
            rng = np.random.default_rng()
            """)
        assert new_findings(findings2, load_baseline(bl_path)) == []
        # ...but a second, unrecorded violation does
        findings3 = lint_snippet(tmp_path, rel, """\
            import numpy as np
            rng = np.random.default_rng()
            rng2 = np.random.default_rng(7)  # constant seeds are D002-clean
            other = np.random.default_rng()
            """)
        new = new_findings(findings3, load_baseline(bl_path))
        assert rule_ids(new) == ["D001"]


# -------------------------------------------------------------- repo status
class TestRepoIsClean:
    def test_no_findings_beyond_baseline(self):
        findings = run_lint(REPO)
        baseline = load_baseline(REPO / core.BASELINE_FILE)
        fresh = new_findings(findings, baseline)
        assert fresh == [], "\n".join(f.render() for f in fresh)

    def test_hot_path_modules_have_empty_baseline(self):
        """The acceptance bar: hot-path/kernel findings are FIXED, never
        baselined or suppressed."""
        baseline = load_baseline(REPO / core.BASELINE_FILE)
        import fnmatch

        guarded = core.HOT_PATH_GLOBS + (core.KERNEL_GLOB,)
        for (rule, path, _ctx, _snip) in baseline:
            assert not any(fnmatch.fnmatch(path, g) for g in guarded), (
                f"baselined {rule} in guarded module {path}"
            )
        for g in guarded:
            for f in REPO.glob(g):
                assert "lint-ignore" not in f.read_text(), (
                    f"suppression comment in guarded module {f}"
                )


# ------------------------------------------------------- runtime sanitizer
class TestTransferSanitizer:
    def test_guard_blocks_implicit_h2d(self):
        import jax

        from repro.lint.sanitizer import transfer_sanitizer

        f = jax.jit(lambda x: x + 1)
        f(jax.device_put(np.ones(4)))  # compile outside the guard
        with pytest.raises(Exception, match="Disallowed host-to-device"):
            with transfer_sanitizer(True):
                f(np.ones(4))

    def test_explicit_device_put_stays_legal(self):
        import jax

        from repro.lint.sanitizer import host_scalar, transfer_sanitizer

        f = jax.jit(lambda x: x.sum())
        f(jax.device_put(np.ones(4)))
        with transfer_sanitizer(True):
            out = f(jax.device_put(np.ones(4)))
        assert host_scalar(out) == 4.0

    def test_disabled_guard_is_noop(self):
        import jax

        from repro.lint.sanitizer import transfer_sanitizer

        f = jax.jit(lambda x: x + 1)
        with transfer_sanitizer(False):
            f(np.ones(4))


class TestTrainerUnderGuard:
    """The trainer's step loop dispatches under the guard by default; both
    sampling backends must train green with it enabled."""

    @pytest.mark.parametrize("backend", ["host", "fused"])
    def test_short_train_green(self, toy_ds, make_model_cfg, backend):
        from repro.graph import DistributedGraphEngine
        from repro.sampling import EgoConfig, PairConfig, PipelineConfig
        from repro.train import Graph4RecTrainer, TrainerConfig
        from repro.walk import WalkConfig

        g = toy_ds.graph
        pc = PipelineConfig(
            walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
            pair=PairConfig(win_size=2),
            ego=EgoConfig(relations=["u2click2i", "i2click2u"], fanouts=[4, 3]),
            batch_pairs=64, walks_per_round=16,
        )
        eng = DistributedGraphEngine(g, num_partitions=2)
        tr = Graph4RecTrainer(
            toy_ds, eng, make_model_cfg(g), pc,
            TrainerConfig(num_steps=4, log_every=0, eval_at_end=False,
                          sampling_backend=backend, sanitize_transfers=True),
        )
        res = tr.train()
        assert len(res.losses) == 4
        assert np.all(np.isfinite(res.losses))
