"""Paper Fig. 3/4 (RQ6): pre-training + parameter warm start.

Pre-train sparse embeddings with the (fast) walk-based model, inherit them
into GNN training, and compare recall trajectories against a cold start at
equal GNN budget. Expectation (paper): warm start reaches better recall in
less training time.
"""
from __future__ import annotations

import time

from benchmarks.common import dataset, emit, fmt_recall, trainer


def run(quick: bool = True) -> None:
    ds = dataset("toy" if quick else "tmall")
    pre_steps = 150 if quick else 500
    gnn_steps = 60 if quick else 200

    # stage 1: metapath2vec pre-training (cheap pairs, no ego sampling)
    walk_tr = trainer(ds, gnn_type=None, steps=pre_steps)
    t0 = time.perf_counter()
    walk_res = walk_tr.train()
    pre_dt = time.perf_counter() - t0
    emit("warmstart/pretrain-metapath2vec", pre_dt / pre_steps * 1e6,
         fmt_recall(walk_res.eval_history[-1]))

    for warm in (False, True):
        tr = trainer(ds, gnn_type="lightgcn", steps=gnn_steps)
        params = tr.init_params()
        if warm:
            params = dict(params)
            params["emb/node"] = walk_res.params["emb/node"]
        t0 = time.perf_counter()
        res = tr.train(params)
        dt = time.perf_counter() - t0
        emit(f"warmstart/gnn-{'warm' if warm else 'cold'}",
             dt / gnn_steps * 1e6, fmt_recall(res.eval_history[-1]))


if __name__ == "__main__":
    run()
