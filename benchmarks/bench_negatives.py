"""Paper Table 6 (RQ4): in-batch vs random negative sampling.

The paper reports ~4x faster training at equal recall for in-batch
negatives. Random negatives cost extra data input (negative ids + their
side info + their ego graphs when a GNN is used) — exactly the traffic the
engine's request counters expose.
"""
from __future__ import annotations

import time

from benchmarks.common import dataset, emit, fmt_recall, trainer


def run(quick: bool = True) -> None:
    ds = dataset("toy" if quick else "rec15")
    steps = 100 if quick else 300
    rows = {}
    for mode in ("random", "inbatch"):
        tr = trainer(ds, gnn_type="lightgcn", steps=steps, neg_mode=mode)
        t0 = time.perf_counter()
        res = tr.train()
        dt = time.perf_counter() - t0
        ev = res.eval_history[-1]
        rows[mode] = dt
        reqs = tr.engine.stats.neighbor_requests
        emit(f"negatives/{mode}", dt / steps * 1e6,
             f"{fmt_recall(ev)} engine_requests={reqs}")
    emit("negatives/speedup", 0.0,
         f"inbatch_is_{rows['random'] / rows['inbatch']:.2f}x_faster")


if __name__ == "__main__":
    run()
