"""Perf-regression gate: fresh quick-arm run vs the committed BENCH numbers.

The three end-to-end regressions fixed in PR 7 sat unnoticed for four PRs
because nothing *watched* the committed benchmark JSONs. This gate does:

    make bench-check            # run fresh quick arms, compare, exit 0/1
    python benchmarks/regression.py --compare fresh.json   # pure compare

Mechanics:

- A fresh quick run of the cheap arms (``--arms step,recall`` by default:
  ``pipeline_throughput`` and ``retrieval_bench``) lands in an in-memory
  dict — the committed ``BENCH_throughput.json`` / ``BENCH_recall.json``
  are never rewritten by the gate.
- Both sides are flattened to dotted metric paths and compared over the
  *intersection* (the committed files hold sections the quick arms don't
  produce; those are out of scope for the gate, their pins live in
  ``tests/test_attribution.py``).
- Every leaf is classified **direction-aware** by its name: throughput-
  like metrics (``*qps``, ``pairs_per_sec*``, ``speedup*`` — including
  ``ivf_speedup_median_vs_chunked`` — ``recall*``,
  ``steps_per_sec*``, ``saturation``) regress by going *down*;
  latency/time-like metrics (``*_us``/``*_ms``/``*_s``/``*_ns``,
  ``wall_*``, ``overhead``) regress by going *up*. Config and count
  leaves (``steps``, ``nlist``, ``*_bytes``, ...) are ignored. Moving in
  the *good* direction is never a finding.
- Tolerance bands are relative and deliberately generous (default
  ``--tolerance 0.5``): quick arms on shared hosts are noisy, and the
  gate exists to catch the 2x cliffs that previously shipped, not 5%
  drift. Determinism-grade metrics (``ivf_recall_at_k``) get a tighter
  band via ``TOLERANCE_OVERRIDES``.
- Findings are fingerprinted (``direction:metric-path`` — value-free, so
  a baseline survives re-measurement) against ``bench_baseline.json``,
  the same accept-current-state mechanism as ``lint_baseline.json``:
  ``--write-baseline`` accepts today's findings, the committed baseline
  stays empty, and CI runs the gate report-only on PRs / enforced on main
  (``.github/workflows/ci.yml``).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_BASELINE_PATH = os.path.join(_ROOT, "bench_baseline.json")
_COMMITTED = ("BENCH_throughput.json", "BENCH_recall.json")

HIGHER_BETTER = "higher-better"
LOWER_BETTER = "lower-better"

# Leaf-name classification, first match wins. Ignores come first so that
# e.g. `chunked_temp_bytes` never falls through to the `*_s` timing rule.
_IGNORE = re.compile(
    r"(^quick$|^dataset$|^steps$|^count$|^dim$|^k$|^reps$|^prefetch$"
    r"|^num_|^workers$|^partitions$|^batch_nodes$|^driver_threads$"
    r"|^item_chunk$|^auto_plan_prefetch$|nlist|nprobe|_lpad$|_bytes$|^memory"
    r"|^trace_events$|^frac_of_wall$|_items$|_rounds$|^engine_backend$"
    r"|^sampling$)"
)
_HIGHER = re.compile(
    r"(qps$|^pairs_per_sec|^speedup|speedup_median|^recall|_recall_at_k$"
    r"|^steps_per_sec|^saturation$|^device_speedup)"
)
_LOWER = re.compile(
    r"(_us$|_ms$|_ns$|_s$|^overhead$|^wall_|latency|^per_call_us$)"
)

# metric-path regex -> relative tolerance (checked before the default)
TOLERANCE_OVERRIDES: Tuple[Tuple[str, float], ...] = (
    (r"ivf_recall_at_k$", 0.10),  # seeded k-means: near-deterministic
)
DEFAULT_TOLERANCE = 0.5


def classify(leaf: str) -> Optional[str]:
    """Direction of a metric leaf name, or None for config/count leaves."""
    if _IGNORE.search(leaf):
        return None
    if _HIGHER.search(leaf):
        return HIGHER_BETTER
    if _LOWER.search(leaf):
        return LOWER_BETTER
    return None


def flatten(obj, prefix: str = "") -> Dict[str, float]:
    """Numeric leaves of a nested benchmark dict as dotted paths."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for key, val in obj.items():
            path = f"{prefix}.{key}" if prefix else str(key)
            out.update(flatten(val, path))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)
    return out


def tolerance_for(path: str, default: float = DEFAULT_TOLERANCE) -> float:
    for pat, tol in TOLERANCE_OVERRIDES:
        if re.search(pat, path):
            return tol
    return default


def compare(
    committed: Dict, fresh: Dict, tolerance: float = DEFAULT_TOLERANCE
) -> List[Dict]:
    """Direction-aware findings over the metric intersection.

    A finding means: the fresh value moved in the *bad* direction by more
    than the band — ``fresh < committed*(1-tol)`` for higher-better,
    ``fresh > committed*(1+tol)`` for lower-better.
    """
    ref = flatten(committed)
    cur = flatten(fresh)
    findings: List[Dict] = []
    for path in sorted(set(ref) & set(cur)):
        direction = classify(path.rsplit(".", 1)[-1])
        if direction is None:
            continue
        want, got = ref[path], cur[path]
        tol = tolerance_for(path, tolerance)
        if want == 0:
            continue  # ratio undefined; ratio-pin metrics are never 0
        bad = (
            got < want * (1.0 - tol)
            if direction == HIGHER_BETTER
            else got > want * (1.0 + tol)
        )
        if bad:
            worse = "fell" if direction == HIGHER_BETTER else "rose"
            findings.append({
                "metric": path,
                "direction": direction,
                "committed": want,
                "fresh": got,
                "ratio": round(got / want, 4),
                "tolerance": tol,
                "message": (
                    f"{path} ({direction}) {worse} beyond the {tol:.0%} "
                    f"band: committed {want:g} -> fresh {got:g} "
                    f"({got / want:.2f}x)"
                ),
            })
    return findings


def fingerprint(finding: Dict) -> str:
    """Value-free identity: survives re-measurement, dies on recovery."""
    return f"{finding['direction']}:{finding['metric']}"


def load_baseline(path: str = _BASELINE_PATH) -> set:
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return set(data.get("findings", []))


def write_baseline(findings: List[Dict], path: str = _BASELINE_PATH) -> None:
    payload = {
        "findings": sorted({fingerprint(f) for f in findings}),
        "version": 1,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def run_fresh_arms(arms: List[str], quick: bool = True) -> Dict:
    """Run the requested quick arms into a private dict (never the
    committed JSONs — this is a measurement, not a refresh)."""
    results: Dict = {}
    if "step" in arms:
        from bench_throughput import pipeline_throughput

        pipeline_throughput(quick, results)
    if "recall" in arms:
        from bench_recall import retrieval_bench

        retrieval_bench(quick, results)
    return results


def load_committed(paths) -> Dict:
    merged: Dict = {}
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(_ROOT, p)
        if os.path.exists(full):
            with open(full) as f:
                merged.update(json.load(f))
    return merged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arms", default="step,recall",
                    help="comma list of fresh quick arms: step,recall")
    ap.add_argument("--compare", metavar="FRESH.json", default=None,
                    help="compare this results JSON instead of running arms")
    ap.add_argument("--against", default=",".join(_COMMITTED),
                    help="comma list of committed benchmark JSONs")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="default relative tolerance band (0.5 = 50%%)")
    ap.add_argument("--baseline", default=_BASELINE_PATH)
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline")
    ap.add_argument("--json", metavar="OUT.json", default=None,
                    help="write the full report (fresh values + findings)")
    args = ap.parse_args(argv)

    committed = load_committed(args.against.split(","))
    if not committed:
        print(f"bench-check: no committed benchmarks at {args.against}")
        return 2
    if args.compare:
        with open(args.compare) as f:
            fresh = json.load(f)
    else:
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        fresh = run_fresh_arms([a.strip() for a in args.arms.split(",") if a])

    findings = compare(committed, fresh, tolerance=args.tolerance)
    compared = sorted(
        p for p in (set(flatten(committed)) & set(flatten(fresh)))
        if classify(p.rsplit(".", 1)[-1]) is not None
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"fresh": fresh, "findings": findings,
                       "compared": compared}, f, indent=1)
            f.write("\n")
    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"bench-check: baseline written ({len(findings)} findings)")
        return 0

    baseline = load_baseline(args.baseline)
    new = [f for f in findings if fingerprint(f) not in baseline]
    old = len(findings) - len(new)
    print(
        f"bench-check: {len(compared)} direction-aware metrics compared, "
        f"{len(findings)} findings ({old} baselined)"
    )
    for f in new:
        print(f"  REGRESSION {f['message']}")
    if new:
        print(
            "bench-check: FAIL — re-measure on an idle host; if the new "
            "numbers are real and intended, refresh the committed BENCH "
            "JSONs (or --write-baseline to accept temporarily)"
        )
        return 1
    print("bench-check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
