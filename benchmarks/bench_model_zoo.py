"""Paper Tables 3/4 (RQ1/RQ2): recall of the model zoo under one pipeline.

Walk-based (DeepWalk ~ homogeneous walk, metapath2vec ~ heterogeneous walk)
vs the GNN zoo (GraphSAGE mean/sum, LightGCN, GAT, GIN, NGCF, GATNE), all
trained by the same five-stage pipeline on the synthetic dataset.
"""
from __future__ import annotations

import time

from benchmarks.common import dataset, emit, fmt_recall, trainer

ZOO = [
    ("deepwalk(walk)", dict(gnn_type=None)),
    ("metapath2vec(walk)", dict(gnn_type=None)),
    ("graphsage-mean", dict(gnn_type="sage-mean")),
    ("graphsage-sum", dict(gnn_type="sage-sum")),
    ("lightgcn", dict(gnn_type="lightgcn")),
    ("gat", dict(gnn_type="gat")),
    ("gin", dict(gnn_type="gin")),
    ("ngcf", dict(gnn_type="ngcf")),
    ("gatne", dict(gnn_type="lightgcn", relation_agg="gatne")),
]


def run(quick: bool = True) -> None:
    ds = dataset("toy" if quick else "retailrocket")
    steps = 120 if quick else 400
    for name, kw in ZOO:
        tr = trainer(ds, steps=steps, **kw)
        t0 = time.perf_counter()
        res = tr.train()
        dt = time.perf_counter() - t0
        ev = res.eval_history[-1]
        emit(f"zoo/{name}", dt / steps * 1e6, fmt_recall(ev))


if __name__ == "__main__":
    run()
