"""Ablation: the α residual against over-smoothing (paper §3.5, Eq. 3).

h_v = α·h⁰ + (1-α)·Σ_r φ_r h_{v,r}: α=0 is a vanilla GNN (prone to
over-smoothing as depth grows), α=1 degenerates to the walk-based embedding.
The paper adopts the PPR-flavored residual as its default; this ablation
shows the recall surface over α.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import dataset, emit, fmt_recall, trainer


def run(quick: bool = True) -> None:
    ds = dataset("toy" if quick else "tmall")
    steps = 100 if quick else 300
    for alpha in (0.0, 0.15, 0.5, 1.0):
        tr = trainer(ds, gnn_type="lightgcn", steps=steps)
        tr.model_cfg = dataclasses.replace(
            tr.model_cfg,
            gnn=dataclasses.replace(tr.model_cfg.gnn, alpha=alpha),
        )
        # rebuild the jitted step with the new config
        tr._grad_step = __import__("jax").jit(tr._make_grad_step())
        t0 = time.perf_counter()
        res = tr.train()
        dt = time.perf_counter() - t0
        emit(f"alpha/{alpha}", dt / steps * 1e6, fmt_recall(res.eval_history[-1]))


if __name__ == "__main__":
    run()
