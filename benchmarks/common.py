"""Shared benchmark harness: dataset/trainer builders + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per measured
configuration) so ``python -m benchmarks.run`` output is machine-parsable;
``derived`` carries the benchmark-specific metric (recall, speedup, ops).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core import Graph4RecConfig, HeteroGNNConfig
from repro.embedding import EmbeddingConfig, SlotSpec
from repro.graph import DistributedGraphEngine, SPECS, generate
from repro.sampling import EgoConfig, PairConfig, PipelineConfig
from repro.train import Graph4RecTrainer, TrainerConfig
from repro.walk import WalkConfig

RELS = ("u2click2i", "i2click2u")


def dataset(name: str = "toy", seed: int = 0):
    return generate(SPECS[name], seed=seed)


def trainer(
    ds,
    gnn_type: Optional[str] = "lightgcn",  # None -> walk-based
    steps: int = 150,
    side_info: bool = False,
    neg_mode: str = "inbatch",
    order: str = "walk_ego_pair",
    relation_agg: str = "uniform",
    dim: int = 32,
    batch_pairs: int = 256,
    num_negatives: int = 5,
    seed: int = 0,
    num_partitions: int = 4,
    prefetch_batches: Optional[int] = 2,
    sync_every_step: bool = False,
    eval_at_end: bool = True,
    engine_build: str = "vectorized",
    slot_mode: str = "bag",
    sparse_updates: bool = True,
    # Benchmarks pin their arms explicitly by default; pass auto_backend=True
    # (plus prefetch_batches=None / sampling_backend="auto") for the
    # calibrated-selection arm.
    auto_backend: bool = False,
    sparse_min_rows: int = 32768,
    engine_backend: str = "inproc",
    num_engine_workers: int = 2,
    engine_local_threshold: int = 8192,
    sampling_backend: str = "host",
    sanitize_transfers: bool = True,
    attribution: bool = False,
    telemetry=None,
    health=None,
) -> Graph4RecTrainer:
    g = ds.graph
    slots = (
        (SlotSpec("slot0", 64, 3), SlotSpec("slot1", 64, 3)) if side_info else ()
    )
    walk_based = gnn_type is None
    loss = "inbatch_softmax" if neg_mode == "inbatch" else "neg_sampling"
    mc = Graph4RecConfig(
        embedding=EmbeddingConfig(num_nodes=g.num_nodes, dim=dim, slots=slots),
        gnn=None if walk_based else HeteroGNNConfig(
            gnn_type=gnn_type, num_relations=2, num_layers=2, dim=dim,
            relation_agg=relation_agg),
        fanouts=() if walk_based else (4, 3),
        relations=RELS,
        use_side_info=side_info,
        slot_mode=slot_mode,
        loss=loss,
    )
    pc = PipelineConfig(
        walk=WalkConfig(metapaths=["u2click2i - i2click2u"], walk_len=6),
        pair=PairConfig(win_size=2, neg_mode=neg_mode, num_negatives=num_negatives),
        ego=None if walk_based else EgoConfig(relations=list(RELS), fanouts=[4, 3]),
        order=order, batch_pairs=batch_pairs, walks_per_round=64,
    )
    # mp backend: pass the bare graph so adjacency is partitioned once,
    # straight into shared memory (no unused in-process partition copies)
    eng = (
        g
        if engine_backend == "mp"
        else DistributedGraphEngine(
            g, num_partitions=num_partitions, build=engine_build
        )
    )
    return Graph4RecTrainer(
        ds, eng, mc, pc,
        TrainerConfig(num_steps=steps, log_every=0, eval_max_users=128,
                      sparse_lr=1.0, seed=seed,
                      prefetch_batches=prefetch_batches,
                      sync_every_step=sync_every_step,
                      sparse_updates=sparse_updates,
                      auto_backend=auto_backend,
                      sparse_min_rows=sparse_min_rows,
                      eval_at_end=eval_at_end,
                      engine_backend=engine_backend,
                      num_engine_workers=num_engine_workers,
                      engine_local_threshold=engine_local_threshold,
                      num_engine_partitions=num_partitions,
                      sampling_backend=sampling_backend,
                      sanitize_transfers=sanitize_transfers,
                      attribution=attribution,
                      telemetry=telemetry,
                      health=health),
    )


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def fmt_recall(ev: Dict[str, float]) -> str:
    return (f"icf={ev['icf']:.4f} ucf={ev['ucf']:.4f} u2i={ev['u2i']:.4f}")
