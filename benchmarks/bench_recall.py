"""Recall-serving benchmark: brute force vs chunked top-k vs IVF by scale.

The retrieval stage is what the paper's §4.2 experiments (and any serving
deployment) actually pay for, so this bench measures the three
implementations of the same U2I-style retrieval — history-excluded top-k
over an item table — at 10k / 100k / 1M items (plus a 10M arm with
``--full``):

- ``seed``: the seed evaluation path — materialize the full (Q, I) score
  matrix and run a per-row numpy argpartition loop. O(Q·I) memory.
- ``chunked``: jitted streaming top-k (repro.retrieval.chunked_topk) —
  O(Q·chunk) memory, the exact production path.
- ``pallas``: the fused kernel, measured at the smallest arm only (it runs
  in interpret mode on CPU; TPU timing comes from the roofline, not here).
- ``ivf``: the device-resident quantized ANN index (int8 codes, packed CSR
  inverted lists, exact re-rank), with its measured recall vs the exact
  result and its per-rep speedup over ``chunked``.

The corpus is a **mixture of gaussians** (items scattered around shared
centers, queries drawn near the same centers): the geometry trained
embeddings actually have — users cluster by taste, items by genre — and
the regime a coarse partition exists for. An isotropic gaussian corpus has
no cell structure at all: every cell holds near-neighbors of every query,
so recall 0.95 forces probing ~a third of the table and *no* partition
scheme can beat the dense GEMM (docs/retrieval.md works the numbers). The
earlier isotropic version of this bench is how an always-losing IVF went
unnoticed: it measured a workload the index was never for.

Arms are measured INTERLEAVED per rep and speedups are per-rep ratios
(median reported) — same methodology as bench-engine, for the same reason:
on shared hosts absolute throughput drifts, ratios of back-to-back runs
don't. Results merge into ``BENCH_recall.json`` at the repo root (pinned
by tests/test_attribution.py, gated by benchmarks/regression.py). The
compiled chunked program's temp-buffer footprint (from XLA's
memory_analysis) is recorded per arm — flat across item counts, which is
the "no full similarity matrix" claim in machine-checkable form.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

if __package__ in (None, ""):  # `python benchmarks/bench_recall.py`
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

import numpy as np

from benchmarks.common import emit

_JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_recall.json")

K = 100
DIM = 32
EXCLUDE_W = 16

QUICK_SIZES = (10_000, 100_000, 1_000_000)
FULL_SIZES = QUICK_SIZES + (10_000_000,)

# Per-arm IVF tuning (docs/retrieval.md derives the trade-offs). nlist
# tracks sqrt-ish growth so lists stay short; nprobe is the recall knob;
# balance_factor 1.25 keeps lpad (the fixed gather width) near the mean
# list length. The 10M arm drops the exact f32 table from device memory
# (keep_exact_device=False: only the ~320 MB of int8 codes stay resident)
# and re-ranks on host.
_IVF_ARMS: Dict[int, Dict] = {
    10_000: dict(nlist=128, nprobe=8, kmeans_iters=6, train_size=0),
    100_000: dict(nlist=512, nprobe=12, kmeans_iters=6, train_size=65_536),
    1_000_000: dict(nlist=2048, nprobe=12, kmeans_iters=4, train_size=131_072),
    10_000_000: dict(nlist=4096, nprobe=16, kmeans_iters=3,
                     train_size=262_144, keep_exact_device=False),
}
_BALANCE = 1.25


def clustered_corpus(rng: np.random.Generator, I: int, Q: int, d: int = DIM):
    """Mixture-of-gaussians item table + queries near the same centers."""
    C = int(max(16, min(1024, I // 2048)))
    centers = rng.normal(size=(C, d)).astype(np.float32) * 3.0
    it = (centers[rng.integers(0, C, I)]
          + rng.normal(size=(I, d)).astype(np.float32))
    q = (centers[rng.integers(0, C, Q)]
         + 0.5 * rng.normal(size=(Q, d)).astype(np.float32))
    return it, q


def seed_topk_loop(q: np.ndarray, it: np.ndarray, k: int,
                   exclude: np.ndarray) -> np.ndarray:
    """The seed's evaluation pattern: full score matrix + per-row
    argpartition loop (core/recall.py before this subsystem existed)."""
    sim = q @ it.T
    rows = np.repeat(np.arange(len(q)), exclude.shape[1])
    cols = exclude.reshape(-1)
    valid = cols >= 0
    sim[rows[valid], cols[valid]] = -np.inf
    out = np.empty((len(q), k), dtype=np.int64)
    for r in range(len(q)):
        row = sim[r]
        idx = np.argpartition(-row, k - 1)[:k]
        out[r] = idx[np.argsort(-row[idx])]
    return out


def chunked_temp_bytes(Q: int, I: int, item_chunk: int) -> int:
    """Temp-buffer bytes of the compiled streaming-top-k program."""
    import jax.numpy as jnp

    from repro.retrieval.topk import _chunked_topk_scan

    chunk = min(item_chunk, I)
    Ip = -(-I // chunk) * chunk
    lowered = _chunked_topk_scan.lower(
        jnp.zeros((Q, DIM), jnp.float32),
        jnp.zeros((Ip // chunk, chunk, DIM), jnp.float32),
        jnp.zeros((Q, EXCLUDE_W), jnp.int32),
        k=K, chunk=chunk, num_items=I,
    )
    return int(lowered.compile().memory_analysis().temp_size_in_bytes)


def _ivf_config(I: int):
    from repro.retrieval import IVFConfig

    kw = _IVF_ARMS.get(I) or dict(
        nlist=max(16, min(2048, I // 500)), nprobe=12, kmeans_iters=4,
        train_size=min(I, 131_072),
    )
    return IVFConfig(balance_factor=_BALANCE, seed=0, **kw)


def retrieval_bench(
    quick: bool = True,
    results: Optional[Dict] = None,
    sizes: Optional[Sequence[int]] = None,
) -> None:
    from repro.retrieval import IVFIndex, chunked_topk

    sizes = tuple(sizes) if sizes else (QUICK_SIZES if quick else FULL_SIZES)
    base_reps = 3 if quick else 5
    rng = np.random.default_rng(0)
    # merge-update: a partial --sizes run refreshes only its own arms
    out_all: Dict[str, Dict] = dict(
        (results or {}).get("retrieval", {}), k=K, dim=DIM
    )
    for I in sizes:
        Q = 32 if I >= 10_000_000 else (
            64 if I >= 1_000_000 else (256 if quick else 512)
        )
        reps = min(base_reps, 3) if I >= 10_000_000 else base_reps
        item_chunk = 16384
        it, q = clustered_corpus(rng, I, Q)
        ex = rng.integers(0, I, size=(Q, EXCLUDE_W)).astype(np.int32)
        ivf_cfg = _ivf_config(I)
        t0 = time.perf_counter()
        index = IVFIndex.build(it, ivf_cfg)
        build_s = time.perf_counter() - t0

        def run_seed():
            return seed_topk_loop(q, it, K, ex)

        def run_chunked():
            return chunked_topk(q, it, K, exclude=ex, item_chunk=item_chunk)[1]

        def run_ivf():
            return index.search(q, K, exclude=ex)[1]

        exact = run_chunked()  # warm + reference result
        run_ivf()
        run_seed()
        times: Dict[str, List[float]] = {"seed": [], "chunked": [], "ivf": []}
        for _ in range(reps):
            for name, fn in (("seed", run_seed), ("chunked", run_chunked),
                             ("ivf", run_ivf)):
                t0 = time.perf_counter()
                fn()
                times[name].append(time.perf_counter() - t0)
        ivf_ids = run_ivf()
        ivf_recall = float(np.mean([
            len(set(a.tolist()) & set(b.tolist())) / K
            for a, b in zip(exact, ivf_ids)
        ]))
        ratios = sorted(s / c for s, c in zip(times["seed"], times["chunked"]))
        med_speedup = ratios[len(ratios) // 2]
        ivf_ratios = sorted(
            c / v for c, v in zip(times["chunked"], times["ivf"])
        )
        ivf_speedup = ivf_ratios[len(ivf_ratios) // 2]
        arm: Dict = {"num_queries": Q, "item_chunk": item_chunk}
        for name in times:
            best = min(times[name])
            arm[f"{name}_qps"] = round(Q / best, 1)
            emit(f"recall/I{I}/{name}", best / Q * 1e6,
                 f"queries_per_sec={Q / best:.1f}")
        arm["chunked_speedup_median_vs_seed"] = round(med_speedup, 3)
        arm["ivf_speedup_median_vs_chunked"] = round(ivf_speedup, 3)
        arm["ivf_recall_at_k"] = round(ivf_recall, 4)
        arm["ivf_build_s"] = round(build_s, 3)
        arm["ivf_nlist"] = index.config.nlist
        arm["ivf_nprobe"] = index.config.nprobe
        arm["ivf_lpad"] = index.lpad
        arm["ivf_spilled_items"] = index.spilled_items
        arm["chunked_temp_bytes"] = chunked_temp_bytes(Q, I, item_chunk)
        emit(f"recall/I{I}/speedup", 0.0, f"chunked_vs_seed={med_speedup:.2f}x")
        emit(f"recall/I{I}/ivf", 0.0,
             f"recall={ivf_recall:.3f} build_s={build_s:.2f} "
             f"speedup_vs_chunked={ivf_speedup:.2f}x")
        out_all[f"I{I}"] = arm
        del it, q, index

    # pallas arm (interpret mode on CPU): correctness-path timing, smallest
    # size only — the lowered program is what runs on TPU, wall clock isn't
    I = 4096
    it = rng.normal(size=(I, DIM)).astype(np.float32)
    q = rng.normal(size=(64, DIM)).astype(np.float32)
    from repro.retrieval import chunked_topk as _ct

    _ct(q, it, K, item_chunk=1024, backend="pallas")  # warm
    t0 = time.perf_counter()
    _ct(q, it, K, item_chunk=1024, backend="pallas")
    pallas_s = time.perf_counter() - t0
    emit("recall/pallas_interpret", pallas_s / 64 * 1e6, f"I={I}")
    out_all["pallas_interpret_I4096_qps"] = round(64 / pallas_s, 1)
    if results is not None:
        results["retrieval"] = out_all


def eval_e2e_bench(quick: bool = True, results: Dict = None) -> None:
    """End-to-end evaluate_recall (U2I) on a synthetic 100k-item table:
    the device path vs the numpy oracle, interleaved."""
    from repro.core.recall import evaluate_recall

    I, U, d = 100_000, 512, DIM
    rng = np.random.default_rng(1)
    ue = rng.normal(size=(U, d)).astype(np.float32)
    ie = rng.normal(size=(I, d)).astype(np.float32)
    train = np.stack([rng.integers(0, U, 4096), rng.integers(0, I, 4096)], 1)
    evalp = np.stack([rng.integers(0, U, 1024), rng.integers(0, I, 1024)], 1)
    kw = dict(top_k=K, strategies=("u2i",), item_chunk=16384)
    evaluate_recall(ue, ie, train, evalp, method="device", **kw)  # warm jit
    reps = 3 if quick else 5
    times = {"bruteforce": [], "device": []}
    for _ in range(reps):
        for method in times:
            t0 = time.perf_counter()
            evaluate_recall(ue, ie, train, evalp, method=method, **kw)
            times[method].append(time.perf_counter() - t0)
    ratios = sorted(b / d for b, d in zip(times["bruteforce"], times["device"]))
    med = ratios[len(ratios) // 2]
    for m in times:
        emit(f"recall_eval/I{I}/{m}", min(times[m]) * 1e6,
             f"evals_per_sec={1 / min(times[m]):.2f}")
    emit(f"recall_eval/I{I}/speedup", 0.0, f"device_vs_bruteforce={med:.2f}x")
    if results is not None:
        results["eval_u2i_100k"] = {
            "num_users": U, "num_items": I,
            "bruteforce_s": round(min(times["bruteforce"]), 3),
            "device_s": round(min(times["device"]), 3),
            "device_speedup_median": round(med, 3),
        }


def run(
    quick: bool = True,
    sizes: Optional[Sequence[int]] = None,
    out: Optional[str] = None,
) -> Dict:
    try:
        with open(_JSON_PATH) as f:
            results = json.load(f)
    except (OSError, ValueError):
        results = {}
    results["quick"] = quick
    retrieval_bench(quick, results, sizes=sizes)
    if sizes is None:  # explicit --sizes runs are arm smokes, skip e2e
        eval_e2e_bench(quick, results)
    with open(out or _JSON_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    grp = ap.add_mutually_exclusive_group()
    grp.add_argument("--quick", action="store_true", default=True,
                     help="fewer reps/queries, no 10M arm (default)")
    grp.add_argument("--full", action="store_true",
                     help="more reps + the 10M-item arm")
    ap.add_argument("--sizes", type=int, nargs="+", default=None,
                    help="run only these item-count arms (merge-updates "
                         "the JSON; skips the e2e eval arm)")
    ap.add_argument("--out", default=None,
                    help="write results here instead of BENCH_recall.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(quick=not args.full, sizes=args.sizes, out=args.out)
