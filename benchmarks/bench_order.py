"""Paper Table 7 (RQ5): sample-generation order — Walk,Pair,Ego vs
Walk,Ego,Pair.

Ego-first reduces ego samplings per path from O(wL) to O(L) at a small
diversity (recall) cost. We report wall-clock, the engine's neighbor-request
counter (the communication the paper optimizes), and recall.
"""
from __future__ import annotations

import time

from benchmarks.common import dataset, emit, fmt_recall, trainer


def run(quick: bool = True) -> None:
    ds = dataset("toy" if quick else "tmall")
    steps = 100 if quick else 300
    wall = {}
    for order, tag in (("walk_pair_ego", "pair-first"),
                       ("walk_ego_pair", "ego-first")):
        tr = trainer(ds, gnn_type="lightgcn", steps=steps, order=order)
        t0 = time.perf_counter()
        res = tr.train()
        dt = time.perf_counter() - t0
        wall[order] = dt
        pipe_ops = None
        emit(
            f"order/{tag}", dt / steps * 1e6,
            f"{fmt_recall(res.eval_history[-1])} "
            f"engine_requests={tr.engine.stats.neighbor_requests} "
            f"cross_partition={tr.engine.stats.cross_partition_requests}",
        )
    emit("order/speedup", 0.0,
         f"ego_first_is_{wall['walk_pair_ego'] / wall['walk_ego_pair']:.2f}x_faster")


if __name__ == "__main__":
    run()
